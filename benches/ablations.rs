//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. Algorithm-1 parameter sensitivity (`thresh`, `step`, `burnin`),
//! 2. delay-model sensitivity (exponential vs heavy-tailed vs bimodal),
//! 3. Theorem-1 oracle vs Algorithm-1 heuristic (how much does knowing
//!    the system parameters buy?).
//!
//! Run: `cargo bench --bench ablations`

use adasgd::bench_harness::section;
use adasgd::coding::{run_coded_gd, CodedConfig, CodingScheme, FrcScheme};
use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::grad::NativeBackend;
use adasgd::master::{run_fastest_k, MasterConfig};
use adasgd::policy::{
    AdaptivePflug, BoundOptimal, FixedK, KPolicy, PflugParams, VarianceTest,
    VarianceTestParams,
};
use adasgd::model::LinRegProblem;
use adasgd::stats::OrderStats;
use adasgd::straggler::*;
use adasgd::theory::{BoundParams, ErrorBound};

fn run(
    ds: &SyntheticDataset,
    problem: &LinRegProblem,
    delays: &dyn DelayModel,
    policy: &mut dyn KPolicy,
    max_time: f64,
    seed: u64,
) -> (f64, usize) {
    let mut backend = NativeBackend::new(Shards::partition(ds, 50));
    let cfg = MasterConfig {
        eta: 5e-4,
        momentum: 0.0,
        max_iterations: 1_000_000,
        max_time,
        seed,
        record_stride: 50,
    };
    let r = run_fastest_k(
        &mut backend,
        delays,
        policy,
        &vec![0.0f32; problem.d()],
        &cfg,
        &mut |w| problem.error(w),
    );
    let final_k = r.k_changes.last().map(|&(_, _, k)| k).unwrap_or(0);
    (r.recorder.min_error().unwrap(), final_k)
}

fn main() {
    let ds = SyntheticDataset::generate(SyntheticConfig::default(), 0);
    let problem = LinRegProblem::new(&ds);
    let exp = ExponentialDelays::new(1.0);
    let budget = 2500.0;

    section("ablation 1 — Algorithm-1 parameter sensitivity (t <= 2500)");
    println!(
        "{:>8} {:>6} {:>8} {:>14} {:>8}",
        "thresh", "step", "burnin", "min error", "final k"
    );
    for thresh in [2i64, 10, 40] {
        for step in [5usize, 10, 20] {
            for burnin in [50u64, 200, 800] {
                let mut p = AdaptivePflug::new(50, PflugParams {
                    k0: 10,
                    step,
                    thresh,
                    burnin,
                    k_max: 40,
                });
                let (err, final_k) =
                    run(&ds, &problem, &exp, &mut p, budget, 0);
                println!(
                    "{thresh:>8} {step:>6} {burnin:>8} {err:>14.4e} {final_k:>8}"
                );
            }
        }
    }
    println!(
        "(robust region: min error varies little across thresh/step — \
         burnin mostly gates how early switching can begin)"
    );

    section("ablation 2 — delay-model sensitivity (adaptive vs fixed)");
    let models: Vec<Box<dyn DelayModel>> = vec![
        Box::new(ExponentialDelays::new(1.0)),
        Box::new(ParetoDelays::new(0.5, 2.2)),
        Box::new(WeibullDelays::new(1.0, 0.7)),
        Box::new(BimodalDelays::new(1.0, 5, 8.0, 0.05)),
        Box::new(ShiftedExponentialDelays::new(0.5, 2.0)),
    ];
    println!(
        "{:<44} {:>13} {:>13} {:>13}",
        "model", "fixed k=10", "fixed k=40", "adaptive"
    );
    for m in &models {
        let os = OrderStats::monte_carlo(m.as_ref(), 50, 2000, 5);
        let budget_m = budget * os.mean(40) / 1.57;
        let (e10, _) =
            run(&ds, &problem, m.as_ref(), &mut FixedK::new(10), budget_m, 1);
        let (e40, _) =
            run(&ds, &problem, m.as_ref(), &mut FixedK::new(40), budget_m, 1);
        let mut ap = AdaptivePflug::new(50, PflugParams::default());
        let (ea, _) = run(&ds, &problem, m.as_ref(), &mut ap, budget_m, 1);
        println!(
            "{:<44} {:>13.4e} {:>13.4e} {:>13.4e}",
            m.name(),
            e10,
            e40,
            ea
        );
    }

    section("ablation 3 — Theorem-1 oracle vs Algorithm-1 heuristic");
    // Oracle needs the system parameters; estimate them the way the paper
    // does (L, c from the data spectrum scale; sigma2 from shard-gradient
    // spread at w0; f0_err measured).
    let f0 = problem.error(&vec![0.0f32; problem.d()]);
    let params = BoundParams {
        eta: 5e-4,
        l: 3.0e3,
        c: 8.0,
        sigma2: 1.0e7,
        s: 40,
        f0_err: f0,
    };
    let bound = ErrorBound::new(params, OrderStats::exponential(50, 1.0));
    let mut oracle = BoundOptimal::new(&bound);
    println!(
        "  oracle switch times (first 6): {:?}",
        oracle
            .times()
            .iter()
            .take(6)
            .map(|t| (t * 10.0).round() / 10.0)
            .collect::<Vec<_>>()
    );
    let (e_oracle, k_oracle) =
        run(&ds, &problem, &exp, &mut oracle, budget, 2);
    let mut heuristic = AdaptivePflug::new(50, PflugParams {
        k0: 1,
        step: 5,
        thresh: 10,
        burnin: 200,
        k_max: 50,
    });
    let (e_pflug, k_pflug) =
        run(&ds, &problem, &exp, &mut heuristic, budget, 2);
    println!(
        "  bound-optimal (needs eta,L,c,sigma2,F*): min error {e_oracle:.4e} (k -> {k_oracle})"
    );
    println!(
        "  adaptive-pflug (parameter-oblivious)   : min error {e_pflug:.4e} (k -> {k_pflug})"
    );
    println!(
        "  => the oblivious heuristic should be within a small factor of \
         the oracle — that is the paper's practical claim."
    );

    section("ablation 4 — detector swap: Pflug sign test vs variance plateau");
    let mut pflug = AdaptivePflug::new(50, PflugParams::default());
    let (e_sign, _) = run(&ds, &problem, &exp, &mut pflug, budget, 3);
    let mut vt = VarianceTest::new(50, VarianceTestParams::default());
    let (e_var, _) = run(&ds, &problem, &exp, &mut vt, budget, 3);
    println!("  pflug sign test    : min error {e_sign:.4e}");
    println!("  variance plateau   : min error {e_var:.4e}");
    println!("  (both detectors should land in the same error decade)");

    section("ablation 5 — redundancy (coded GD) vs ignoring stragglers");
    // The §I.A comparison: fractional-repetition gradient coding gets the
    // EXACT gradient from n-r+1 responses at r x compute; fastest-k gets a
    // noisy gradient from k cheap responses.
    for r in [1usize, 2, 5] {
        let shards = Shards::partition(&ds, 50);
        let scheme = FrcScheme::new(50, r).expect("r divides 50");
        let mut backend = NativeBackend::new(shards);
        let cfg = CodedConfig {
            eta: 5e-4,
            max_iterations: 1_000_000,
            max_time: budget,
            seed: 4,
            record_stride: 50,
            r,
        };
        let run = run_coded_gd(
            &mut backend,
            &exp,
            &scheme,
            &vec![0.0f32; problem.d()],
            &cfg,
            &mut |w| problem.error(w),
        );
        println!(
            "  coded r={r}: waits for fastest {} of 50, {:>5} iters, min error {:.4e}",
            scheme.recovery_threshold(),
            run.iterations,
            run.recorder.min_error().unwrap()
        );
    }
    let mut ap = AdaptivePflug::new(50, PflugParams::default());
    let (ea, _) = run(&ds, &problem, &exp, &mut ap, budget, 4);
    println!("  adaptive fastest-k (no redundancy):       min error {ea:.4e}");
    println!(
        "  (coded r>1 trades exactness for r x compute; adaptive matches \
         it without redundancy — the paper's positioning)"
    );

    section("ablation 6 — correlated (Markov) stragglers");
    let markov = MarkovDelays::new(1.0, 0.05, 0.2, 8.0, 11);
    let os = OrderStats::monte_carlo(&markov, 50, 2000, 13);
    let budget_m = budget * os.mean(40) / 1.57;
    let (e10m, _) =
        run(&ds, &problem, &markov, &mut FixedK::new(10), budget_m, 5);
    let (e40m, _) =
        run(&ds, &problem, &markov, &mut FixedK::new(40), budget_m, 5);
    let mut apm = AdaptivePflug::new(50, PflugParams::default());
    let (eam, _) = run(&ds, &problem, &markov, &mut apm, budget_m, 5);
    println!(
        "  {:<40} k=10 {:.4e}  k=40 {:.4e}  adaptive {:.4e}",
        markov.name(),
        e10m,
        e40m,
        eam
    );
}
