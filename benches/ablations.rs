//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. Algorithm-1 parameter sensitivity (`thresh`, `step`, `burnin`),
//! 2. delay-model sensitivity (exponential vs heavy-tailed vs bimodal),
//! 3. Theorem-1 oracle vs Algorithm-1 heuristic (how much does knowing
//!    the system parameters buy?).
//!
//! The parameter and delay-model grids fan out over
//! `sweep::SweepExecutor::map` (`--jobs N`, 0 = all cores) — each cell
//! builds its own delay model and policy from its index, so the numbers
//! are identical for every worker count. `--smoke` shrinks the grids.
//!
//! Run: `cargo bench --bench ablations [-- --jobs N --smoke]`

use adasgd::bench_harness::{section, BenchArgs};
use adasgd::coding::{run_coded_gd, CodedConfig, CodingScheme, FrcScheme};
use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::grad::NativeBackend;
use adasgd::master::{run_fastest_k, MasterConfig};
use adasgd::model::LinRegProblem;
use adasgd::policy::{
    AdaptivePflug, BoundOptimal, FixedK, KPolicy, PflugParams, VarianceTest,
    VarianceTestParams,
};
use adasgd::stats::OrderStats;
use adasgd::straggler::*;
use adasgd::sweep::SweepExecutor;
use adasgd::theory::{BoundParams, ErrorBound};
use std::sync::Arc;

fn run(
    ds: &SyntheticDataset,
    problem: &LinRegProblem,
    delays: &dyn DelayModel,
    policy: &mut dyn KPolicy,
    max_time: f64,
    seed: u64,
) -> (f64, usize) {
    let mut backend = NativeBackend::new(Shards::partition(ds, 50));
    let cfg = MasterConfig {
        eta: 5e-4,
        momentum: 0.0,
        max_iterations: 1_000_000,
        max_time,
        seed,
        record_stride: 50,
        intra_jobs: 1,
    };
    let r = run_fastest_k(
        &mut backend,
        delays,
        policy,
        &vec![0.0f32; problem.d()],
        &cfg,
        &mut |w| problem.error(w),
    );
    let final_k = r.k_changes.last().map(|&(_, _, k)| k).unwrap_or(0);
    (r.recorder.min_error().unwrap(), final_k)
}

/// The delay-model zoo for ablation 2, built by index so sweep cells can
/// construct their own copy (trait objects are not shared across jobs).
fn model_zoo(i: usize) -> Box<dyn DelayModel> {
    match i {
        0 => Box::new(ExponentialDelays::new(1.0)),
        1 => Box::new(ParetoDelays::new(0.5, 2.2)),
        2 => Box::new(WeibullDelays::new(1.0, 0.7)),
        3 => Box::new(BimodalDelays::new(1.0, 5, 8.0, 0.05)),
        _ => Box::new(ShiftedExponentialDelays::new(0.5, 2.0)),
    }
}

fn main() {
    let args = BenchArgs::from_env();
    let exec = SweepExecutor::new(args.jobs);
    let ds = Arc::new(SyntheticDataset::generate(
        SyntheticConfig::default(),
        0,
    ));
    let problem = Arc::new(LinRegProblem::new(&ds));
    let exp = ExponentialDelays::new(1.0);
    let budget = if args.smoke { 300.0 } else { 2500.0 };

    section(&format!(
        "ablation 1 — Algorithm-1 parameter sensitivity (t <= {budget}, \
         jobs={})",
        exec.jobs()
    ));
    println!(
        "{:>8} {:>6} {:>8} {:>14} {:>8}",
        "thresh", "step", "burnin", "min error", "final k"
    );
    let (threshes, steps, burnins): (Vec<i64>, Vec<usize>, Vec<u64>) =
        if args.smoke {
            (vec![10], vec![10], vec![50, 200])
        } else {
            (vec![2, 10, 40], vec![5, 10, 20], vec![50, 200, 800])
        };
    let cells: Vec<(i64, usize, u64)> = threshes
        .iter()
        .flat_map(|&thresh| {
            steps.iter().flat_map(move |&step| {
                burnins.iter().map(move |&burnin| (thresh, step, burnin))
            })
        })
        .collect();
    let rows = {
        let ds = Arc::clone(&ds);
        let problem = Arc::clone(&problem);
        let cells = cells.clone();
        exec.map(cells.len(), move |i| {
            let (thresh, step, burnin) = cells[i];
            let mut p = AdaptivePflug::new(50, PflugParams {
                k0: 10,
                step,
                thresh,
                burnin,
                k_max: 40,
            });
            let exp = ExponentialDelays::new(1.0);
            run(&ds, &problem, &exp, &mut p, budget, 0)
        })
    };
    for ((thresh, step, burnin), (err, final_k)) in cells.iter().zip(&rows) {
        println!(
            "{thresh:>8} {step:>6} {burnin:>8} {err:>14.4e} {final_k:>8}"
        );
    }
    println!(
        "(robust region: min error varies little across thresh/step — \
         burnin mostly gates how early switching can begin)"
    );

    section("ablation 2 — delay-model sensitivity (adaptive vs fixed)");
    let n_models = if args.smoke { 2 } else { 5 };
    println!(
        "{:<44} {:>13} {:>13} {:>13}",
        "model", "fixed k=10", "fixed k=40", "adaptive"
    );
    let model_rows = {
        let ds = Arc::clone(&ds);
        let problem = Arc::clone(&problem);
        exec.map(n_models, move |i| {
            let m = model_zoo(i);
            let os = OrderStats::monte_carlo(m.as_ref(), 50, 2000, 5);
            let budget_m = budget * os.mean(40) / 1.57;
            let (e10, _) = run(
                &ds,
                &problem,
                m.as_ref(),
                &mut FixedK::new(10),
                budget_m,
                1,
            );
            let (e40, _) = run(
                &ds,
                &problem,
                m.as_ref(),
                &mut FixedK::new(40),
                budget_m,
                1,
            );
            let mut ap = AdaptivePflug::new(50, PflugParams::default());
            let (ea, _) =
                run(&ds, &problem, m.as_ref(), &mut ap, budget_m, 1);
            (m.name().to_string(), e10, e40, ea)
        })
    };
    for (name, e10, e40, ea) in &model_rows {
        println!("{name:<44} {e10:>13.4e} {e40:>13.4e} {ea:>13.4e}");
    }

    section("ablation 3 — Theorem-1 oracle vs Algorithm-1 heuristic");
    // Oracle needs the system parameters; estimate them the way the paper
    // does (L, c from the data spectrum scale; sigma2 from shard-gradient
    // spread at w0; f0_err measured).
    let f0 = problem.error(&vec![0.0f32; problem.d()]);
    let params = BoundParams {
        eta: 5e-4,
        l: 3.0e3,
        c: 8.0,
        sigma2: 1.0e7,
        s: 40,
        f0_err: f0,
    };
    let bound = ErrorBound::new(params, OrderStats::exponential(50, 1.0));
    let mut oracle = BoundOptimal::new(&bound);
    println!(
        "  oracle switch times (first 6): {:?}",
        oracle
            .times()
            .iter()
            .take(6)
            .map(|t| (t * 10.0).round() / 10.0)
            .collect::<Vec<_>>()
    );
    let (e_oracle, k_oracle) =
        run(&ds, &problem, &exp, &mut oracle, budget, 2);
    let mut heuristic = AdaptivePflug::new(50, PflugParams {
        k0: 1,
        step: 5,
        thresh: 10,
        burnin: 200,
        k_max: 50,
    });
    let (e_pflug, k_pflug) =
        run(&ds, &problem, &exp, &mut heuristic, budget, 2);
    println!(
        "  bound-optimal (needs eta,L,c,sigma2,F*): min error {e_oracle:.4e} (k -> {k_oracle})"
    );
    println!(
        "  adaptive-pflug (parameter-oblivious)   : min error {e_pflug:.4e} (k -> {k_pflug})"
    );
    println!(
        "  => the oblivious heuristic should be within a small factor of \
         the oracle — that is the paper's practical claim."
    );

    section("ablation 4 — detector swap: Pflug sign test vs variance plateau");
    let mut pflug = AdaptivePflug::new(50, PflugParams::default());
    let (e_sign, _) = run(&ds, &problem, &exp, &mut pflug, budget, 3);
    let mut vt = VarianceTest::new(50, VarianceTestParams::default());
    let (e_var, _) = run(&ds, &problem, &exp, &mut vt, budget, 3);
    println!("  pflug sign test    : min error {e_sign:.4e}");
    println!("  variance plateau   : min error {e_var:.4e}");
    println!("  (both detectors should land in the same error decade)");

    section("ablation 5 — redundancy (coded GD) vs ignoring stragglers");
    // The §I.A comparison: fractional-repetition gradient coding gets the
    // EXACT gradient from n-r+1 responses at r x compute; fastest-k gets a
    // noisy gradient from k cheap responses. One executor cell per r.
    let coded_rows = {
        let ds = Arc::clone(&ds);
        let problem = Arc::clone(&problem);
        let rs = [1usize, 2, 5];
        exec.map(rs.len(), move |i| {
            let r = rs[i];
            let shards = Shards::partition(&ds, 50);
            let scheme = FrcScheme::new(50, r).expect("r divides 50");
            let mut backend = NativeBackend::new(shards);
            let cfg = CodedConfig {
                eta: 5e-4,
                max_iterations: 1_000_000,
                max_time: budget,
                seed: 4,
                record_stride: 50,
                r,
            };
            let exp = ExponentialDelays::new(1.0);
            let run = run_coded_gd(
                &mut backend,
                &exp,
                &scheme,
                &vec![0.0f32; problem.d()],
                &cfg,
                &mut |w| problem.error(w),
            );
            (
                r,
                scheme.recovery_threshold(),
                run.iterations,
                run.recorder.min_error().unwrap(),
            )
        })
    };
    for (r, threshold, iters, err) in &coded_rows {
        println!(
            "  coded r={r}: waits for fastest {threshold} of 50, {iters:>5} \
             iters, min error {err:.4e}"
        );
    }
    let mut ap = AdaptivePflug::new(50, PflugParams::default());
    let (ea, _) = run(&ds, &problem, &exp, &mut ap, budget, 4);
    println!("  adaptive fastest-k (no redundancy):       min error {ea:.4e}");
    println!(
        "  (coded r>1 trades exactness for r x compute; adaptive matches \
         it without redundancy — the paper's positioning)"
    );

    section("ablation 6 — correlated (Markov) stragglers");
    let markov = MarkovDelays::new(1.0, 0.05, 0.2, 8.0, 11);
    let os = OrderStats::monte_carlo(&markov, 50, 2000, 13);
    let budget_m = budget * os.mean(40) / 1.57;
    let (e10m, _) =
        run(&ds, &problem, &markov, &mut FixedK::new(10), budget_m, 5);
    let (e40m, _) =
        run(&ds, &problem, &markov, &mut FixedK::new(40), budget_m, 5);
    let mut apm = AdaptivePflug::new(50, PflugParams::default());
    let (eam, _) = run(&ds, &problem, &markov, &mut apm, budget_m, 5);
    println!(
        "  {:<40} k=10 {:.4e}  k=40 {:.4e}  adaptive {:.4e}",
        markov.name(),
        e10m,
        e40m,
        eam
    );
}
