//! Bench + regeneration harness for **Fig. 1 / Example 1**: the Lemma-1
//! bound for k = 1..5 and the Theorem-1 adaptive envelope.
//!
//! Prints the same series the paper plots (error at sampled times per k,
//! plus the adaptive envelope and the switching times), then times the
//! theory computations.
//!
//! Run: `cargo bench --bench fig1_bound`

use adasgd::bench_harness::{section, Bencher};
use adasgd::stats::OrderStats;
use adasgd::theory::{
    adaptive_envelope, switching_times, BoundParams, ErrorBound,
};

fn main() {
    section("Fig. 1 — bound curves (paper Example 1)");
    let bound = ErrorBound::new(
        BoundParams::example1(),
        OrderStats::exponential(5, 5.0),
    );
    let ts: Vec<f64> = (0..=14).map(|i| i as f64 * 1000.0).collect();
    print!("{:>8}", "t");
    for k in 1..=5 {
        print!(" {:>12}", format!("k={k}"));
    }
    println!(" {:>12}", "adaptive");
    let env = adaptive_envelope(&bound, &ts);
    for (i, &t) in ts.iter().enumerate() {
        print!("{t:>8.0}");
        for k in 1..=5 {
            print!(" {:>12.4e}", bound.eval(k, t));
        }
        println!(" {:>12.4e}", env[i]);
    }

    section("Theorem-1 switching times");
    for s in switching_times(&bound) {
        println!(
            "  t_{} = {:>8.1}   (error at switch: {:.4e})",
            s.k_next - 1,
            s.time,
            s.error
        );
    }

    section("timings");
    let b = Bencher::micro();
    println!(
        "{}",
        b.run("switching_times(n=5)", || {
            std::hint::black_box(switching_times(&bound));
        })
        .summary()
    );
    let big = ErrorBound::new(
        BoundParams::example1(),
        OrderStats::exponential(500, 5.0),
    );
    println!(
        "{}",
        b.run("switching_times(n=500)", || {
            std::hint::black_box(switching_times(&big));
        })
        .summary()
    );
    let query: Vec<f64> = (0..10_000).map(|i| i as f64).collect();
    println!(
        "{}",
        b.run("adaptive_envelope(10k points)", || {
            std::hint::black_box(adaptive_envelope(&bound, &query));
        })
        .summary()
    );
}
