//! Bench + regeneration harness for **Fig. 1 / Example 1**: the Lemma-1
//! bound for k = 1..5 and the Theorem-1 adaptive envelope.
//!
//! Prints the same series the paper plots (error at sampled times per k,
//! plus the adaptive envelope and the switching times), then times the
//! theory computations. The per-k bound curves come from
//! `coordinator::fig1_jobs`, i.e. through the sweep executor
//! (`--jobs N`, 0 = all cores; identical numbers for every N).
//!
//! Run: `cargo bench --bench fig1_bound [-- --jobs N --smoke]`

use adasgd::bench_harness::{section, BenchArgs, Bencher};
use adasgd::coordinator::fig1_jobs;
use adasgd::stats::OrderStats;
use adasgd::theory::{
    adaptive_envelope, switching_times, BoundParams, ErrorBound,
};

fn main() {
    let args = BenchArgs::from_env();
    section("Fig. 1 — bound curves (paper Example 1)");
    // 15 grid points over [0, 14000]: exactly the 1000-spaced probe rows
    // the original table printed.
    let out = fig1_jobs(15, args.jobs);
    print!("{:>8}", "t");
    for k in 1..=5 {
        print!(" {:>12}", format!("k={k}"));
    }
    println!(" {:>12}", "adaptive");
    for (i, env) in out.adaptive.samples().iter().enumerate() {
        print!("{:>8.0}", env.time);
        for rec in &out.fixed {
            print!(" {:>12.4e}", rec.samples()[i].error);
        }
        println!(" {:>12.4e}", env.error);
    }

    section("Theorem-1 switching times");
    for line in &out.summary {
        println!("  {line}");
    }

    if args.smoke {
        println!("\n(smoke mode: skipping the micro-benchmarks)");
        return;
    }

    section("timings");
    let bound = ErrorBound::new(
        BoundParams::example1(),
        OrderStats::exponential(5, 5.0),
    );
    let b = Bencher::micro();
    println!(
        "{}",
        b.run("switching_times(n=5)", || {
            std::hint::black_box(switching_times(&bound));
        })
        .summary()
    );
    let big = ErrorBound::new(
        BoundParams::example1(),
        OrderStats::exponential(500, 5.0),
    );
    println!(
        "{}",
        b.run("switching_times(n=500)", || {
            std::hint::black_box(switching_times(&big));
        })
        .summary()
    );
    let query: Vec<f64> = (0..10_000).map(|i| i as f64).collect();
    println!(
        "{}",
        b.run("adaptive_envelope(10k points)", || {
            std::hint::black_box(adaptive_envelope(&bound, &query));
        })
        .summary()
    );
}
