//! Bench + regeneration harness for **Fig. 2**: adaptive fastest-k
//! (Algorithm 1, k: 10→40) vs non-adaptive fixed k ∈ {10, 20, 30, 40};
//! n = 50 workers, exp(1) delays, η = 5·10⁻⁴, §V.A synthetic data.
//!
//! Prints the paper's series (error vs wall-clock per policy), the
//! time-to-error comparison the paper quotes (adaptive ≈ t=2000 vs fixed
//! k=40 ≈ t=6000 for the same error), then times a full simulation. The
//! five runs execute in parallel through `coordinator::fig2_jobs` /
//! `sweep::SweepExecutor` (`--jobs N`, 0 = all cores — byte-identical
//! output either way); `--smoke` shrinks the horizon for CI.
//!
//! Run: `cargo bench --bench fig2_adaptive_vs_fixed [-- --jobs N --smoke]`

use adasgd::bench_harness::{section, BenchArgs, Bencher};
use adasgd::coordinator::fig2_jobs;
use adasgd::metrics::write_csv;

fn main() {
    let args = BenchArgs::from_env();
    let max_time = if args.smoke { 400.0 } else { 6500.0 };
    section(&format!(
        "Fig. 2 — error vs wall-clock (n=50, exp(1), eta=5e-4, T={max_time})"
    ));
    let out = fig2_jobs(0, max_time, args.jobs);

    // Print a downsampled table of the series (what the paper plots).
    let probe_ts: Vec<f64> = if args.smoke {
        vec![100.0, 200.0, 400.0]
    } else {
        vec![250.0, 500.0, 1000.0, 2000.0, 4000.0, 6000.0]
    };
    print!("{:>8}", "t");
    for r in &out.runs {
        print!(" {:>22}", r.label.chars().take(22).collect::<String>());
    }
    println!();
    for &t in &probe_ts {
        print!("{t:>8.0}");
        for r in &out.runs {
            match r.error_at(t) {
                Some(e) => print!(" {e:>22.4e}"),
                None => print!(" {:>22}", "-"),
            }
        }
        println!();
    }
    println!();
    for line in &out.summary {
        println!("  {line}");
    }

    // The paper's headline comparison: time to reach the k=40 floor level.
    section("time-to-error (the paper's t=2000 vs t=6000 claim)");
    let k40 = out.runs.iter().find(|r| r.label.contains("k=40")).unwrap();
    let adaptive =
        out.runs.iter().find(|r| r.label.contains("adaptive")).unwrap();
    let target = k40.last().unwrap().error * 1.5;
    println!("  target error level: {target:.4e} (1.5x the k=40 floor)");
    for r in &out.runs {
        match r.time_to_error(target) {
            Some(t) => println!("  {:<28} reaches it at t = {t:>7.0}", r.label),
            None => println!("  {:<28} never reaches it", r.label),
        }
    }
    let speedup = k40.time_to_error(target).unwrap_or(f64::NAN)
        / adaptive.time_to_error(target).unwrap_or(f64::NAN);
    println!("  adaptive speedup over fixed k=40: {speedup:.2}x (paper: ~3x)");

    let refs: Vec<&adasgd::metrics::Recorder> = out.runs.iter().collect();
    write_csv(std::path::Path::new("results/bench_fig2.csv"), &refs).ok();
    println!("  series written to results/bench_fig2.csv");

    section("simulation throughput");
    let b = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 };
    let bench_t = if args.smoke { 200.0 } else { 1000.0 };
    // Timed at jobs=1 on purpose: this entry tracks *engine* throughput
    // across commits, so it must not vary with the host's core count.
    println!(
        "{}",
        b.run(
            &format!("fig2 adaptive run to t={bench_t:.0} (jobs=1)"),
            move || {
                let out = fig2_jobs(1, bench_t, 1);
                std::hint::black_box(out.runs.len());
            }
        )
        .summary()
    );
}
