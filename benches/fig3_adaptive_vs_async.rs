//! Bench + regeneration harness for **Fig. 3**: adaptive fastest-k
//! (Algorithm 1, k: 1→36 by 5) vs fully asynchronous SGD; η = 2·10⁻⁴.
//!
//! Includes the stability ablation the substitution note in DESIGN.md
//! documents: undamped async at the paper's parameters diverges
//! (η·λ_max·staleness ≈ 30), damped async converges but above adaptive.
//!
//! The two figure runs execute in parallel through
//! `coordinator::fig3_jobs` / the sweep executor (`--jobs N`, 0 = all
//! cores; byte-identical output); `--smoke` shrinks the horizon for CI.
//!
//! Run: `cargo bench --bench fig3_adaptive_vs_async [-- --jobs N --smoke]`

use adasgd::async_sgd::{run_async, AsyncConfig};
use adasgd::bench_harness::{section, BenchArgs, Bencher};
use adasgd::coordinator::fig3_jobs;
use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::grad::NativeBackend;
use adasgd::metrics::write_csv;
use adasgd::model::LinRegProblem;
use adasgd::straggler::ExponentialDelays;

fn main() {
    let args = BenchArgs::from_env();
    let max_time = if args.smoke { 300.0 } else { 2500.0 };
    section(&format!(
        "Fig. 3 — adaptive fastest-k vs asynchronous SGD (eta=2e-4, \
         T={max_time})"
    ));
    let out = fig3_jobs(0, max_time, args.jobs);
    let probe_ts: Vec<f64> = if args.smoke {
        vec![100.0, 200.0, 300.0]
    } else {
        vec![100.0, 250.0, 500.0, 1000.0, 1500.0, 2500.0]
    };
    print!("{:>8}", "t");
    for r in &out.runs {
        print!(" {:>22}", r.label.chars().take(22).collect::<String>());
    }
    println!();
    for &t in &probe_ts {
        print!("{t:>8.0}");
        for r in &out.runs {
            match r.error_at(t) {
                Some(e) => print!(" {e:>22.4e}"),
                None => print!(" {:>22}", "-"),
            }
        }
        println!();
    }
    for line in &out.summary {
        println!("  {line}");
    }
    let refs: Vec<&adasgd::metrics::Recorder> = out.runs.iter().collect();
    write_csv(std::path::Path::new("results/bench_fig3.csv"), &refs).ok();

    section("async stability ablation (the DESIGN.md substitution)");
    let ds = SyntheticDataset::generate(SyntheticConfig::default(), 0);
    let problem = LinRegProblem::new(&ds);
    let delays = ExponentialDelays::new(1.0);
    let (abl_updates, abl_time) =
        if args.smoke { (5_000, 200.0) } else { (60_000, 1200.0) };
    for (label, damping) in
        [("undamped (paper params, raw)", false), ("staleness-damped", true)]
    {
        let mut backend = NativeBackend::new(Shards::partition(&ds, 50));
        let cfg = AsyncConfig {
            eta: 2e-4,
            max_updates: abl_updates,
            max_time: abl_time,
            seed: 0,
            record_stride: 200,
            staleness_damping: damping,
            intra_jobs: 1,
        };
        let run = run_async(
            &mut backend,
            &delays,
            &vec![0.0f32; 100],
            &cfg,
            &mut |w| problem.error(w),
        );
        println!(
            "  {:<32} diverged={:<5} mean staleness {:>5.1}  min error {:.4e}",
            label,
            run.diverged,
            run.mean_staleness,
            run.recorder.min_error().unwrap()
        );
    }

    if args.smoke {
        println!("\n(smoke mode: skipping the throughput benchmark)");
        return;
    }

    section("async engine throughput");
    let b = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 };
    println!(
        "{}",
        b.run("async 20k updates (n=50)", || {
            let mut backend = NativeBackend::new(Shards::partition(&ds, 50));
            let cfg = AsyncConfig {
                eta: 2e-4,
                max_updates: 20_000,
                max_time: 0.0,
                seed: 1,
                record_stride: 100_000,
                staleness_damping: true,
                intra_jobs: 1,
            };
            let run = run_async(
                &mut backend,
                &delays,
                &vec![0.0f32; 100],
                &cfg,
                &mut |w| problem.error(w),
            );
            std::hint::black_box(run.updates);
        })
        .summary()
    );
}
