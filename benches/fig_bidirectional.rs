//! Bidirectional link sweep: downlink scheme × ingress capacity × k-policy.
//!
//! Fig-2 setup (n = 50, exp(1) compute delays, η = 5·10⁻⁴, §V.A data)
//! with the uplink fixed at the `fig_comm_tradeoff` operating point
//! (dense, 400 B per virtual-time unit) and the *new* axes swept:
//!
//! * **downlink** — free dense full-model broadcast vs priced dense vs
//!   compressed model deltas (top-k / QSGD with a master-side residual)
//!   over a 400 B/t downlink, and
//! * **ingress** — unlimited (independent uploads, the PR-1 model) vs a
//!   shared master NIC the k accepted uploads serialize through.
//!
//! The point the sweep makes: with fat models and large k the
//! uplink-only model *understates* the round time exactly where
//! adaptive-k matters most — finite ingress punishes large fixed k, and
//! compressed delta broadcast buys back most of the downlink cost.
//!
//! One `sweep::SweepGrid` declaration, executed in parallel by
//! `sweep::SweepExecutor` (`--jobs N`, 0 = all cores; byte-identical
//! output). `--smoke` shrinks the grid for CI.
//!
//! Run: `cargo bench --bench fig_bidirectional [-- --jobs N --smoke]`

use adasgd::bench_harness::{section, BenchArgs};
use adasgd::config::{
    CommSpec, CompressorSpec, DelaySpec, ExperimentConfig, PolicySpec,
    WorkloadSpec,
};
use adasgd::policy::PflugParams;
use adasgd::sweep::{edit, write_sweep_csv, CfgEdit, SweepExecutor, SweepGrid};

const UP_BANDWIDTH: f64 = 400.0; // bytes per virtual-time unit
const DOWN_BANDWIDTH: f64 = 400.0;

fn base(seed: u64, smoke: bool) -> ExperimentConfig {
    let (n, m, d, max_time) =
        if smoke { (10, 200, 10, 200.0) } else { (50, 2000, 100, 4000.0) };
    ExperimentConfig {
        label: String::new(),
        n,
        eta: 5e-4,
        max_iterations: 200_000,
        max_time,
        seed,
        record_stride: 25,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 4 * n / 5 },
        workload: WorkloadSpec::LinReg { m, d },
        comm: CommSpec {
            bandwidth: UP_BANDWIDTH,
            ..Default::default()
        },
        coding: None,
        jobs: 0,
        intra_jobs: 1,
        trace: None,
        fastpath: false,
    }
}

/// Downlink axis: free dense is the PR-1 baseline; the rest price the
/// broadcast (compressed schemes broadcast model deltas).
fn downlink_axis() -> Vec<(String, CfgEdit)> {
    let priced = |c: &mut ExperimentConfig, scheme: CompressorSpec| {
        c.comm.downlink = scheme;
        c.comm.down_bandwidth = DOWN_BANDWIDTH;
    };
    vec![
        ("downfree".into(), edit(|c| c.comm.downlink = CompressorSpec::Dense)),
        ("downdense".into(), edit(move |c| priced(c, CompressorSpec::Dense))),
        (
            "downtopk10".into(),
            edit(move |c| priced(c, CompressorSpec::TopK { frac: 0.1 })),
        ),
        (
            "downqsgd4".into(),
            edit(move |c| priced(c, CompressorSpec::Qsgd { levels: 4 })),
        ),
    ]
}

#[path = "sweep_axes.rs"]
mod sweep_axes;
use sweep_axes::ingress_axis;

fn policy_axis(n: usize) -> Vec<(String, CfgEdit)> {
    let k = 4 * n / 5;
    vec![
        (format!("k={k}"), edit(move |c| c.policy = PolicySpec::Fixed { k })),
        (
            "adaptive".into(),
            edit(move |c| {
                c.policy = PolicySpec::Adaptive(PflugParams {
                    k0: n / 5,
                    step: n / 5,
                    thresh: 10,
                    burnin: 200,
                    k_max: k,
                })
            }),
        ),
    ]
}

fn main() {
    let args = BenchArgs::from_env();
    let seed = 0u64;
    let cfg0 = base(seed, args.smoke);
    let n = cfg0.n;
    let k_big = 4 * n / 5;
    section(&format!(
        "bidirectional sweep: downlink x ingress x policy (n={n}, exp(1), \
         uplink dense {UP_BANDWIDTH} B/t, T={}, jobs={})",
        cfg0.max_time,
        SweepExecutor::new(args.jobs).jobs()
    ));

    let specs = SweepGrid::new(cfg0)
        .axis("downlink", downlink_axis())
        .axis("ingress", ingress_axis())
        .axis("policy", policy_axis(n))
        .build();
    let outs = SweepExecutor::new(args.jobs)
        .run(&specs)
        .expect("bidirectional sweep");

    println!(
        "{:<28} {:>12} {:>8} {:>13} {:>13} {:>9}",
        "downlink/ingress/policy", "min error", "iters", "bytes_up",
        "bytes_down", "t_end"
    );
    for (spec, out) in specs.iter().zip(&outs) {
        println!(
            "{:<28} {:>12.4e} {:>8} {:>13} {:>13} {:>9.0}",
            spec.label,
            out.recorder.min_error().unwrap_or(f64::NAN),
            out.steps,
            out.bytes_sent,
            out.bytes_down,
            out.total_time
        );
    }

    // Invariant spot-check: at the same policy and downlink, finite
    // ingress must complete strictly fewer iterations in the same
    // time budget than unlimited ingress (every round is longer).
    section("congestion sanity: finite ingress completes fewer rounds");
    let steps_of = |label: &str| {
        specs
            .iter()
            .position(|s| s.label == label)
            .map(|i| outs[i].steps)
            .expect("labelled run")
    };
    let free = steps_of(&format!("downfree/ing-inf/k={k_big}"));
    let congested = steps_of(&format!("downfree/ing4k/k={k_big}"));
    if congested < free {
        println!(
            "  OK: ing4k ran {congested} rounds vs {free} unlimited \
             (shared ingress stretches every k={k_big} round)"
        );
    } else {
        println!(
            "  WARNING: expected fewer rounds under finite ingress; got \
             {congested} vs {free}"
        );
    }

    // Headline: wall-clock to the free-downlink k=large floor.
    section("time-to-error at the free-downlink fixed-k floor");
    let baseline_label = format!("downfree/ing-inf/k={k_big}");
    let baseline = specs
        .iter()
        .position(|s| s.label == baseline_label)
        .map(|i| &outs[i].recorder)
        .expect("baseline run");
    let target = baseline.min_error().unwrap() * 1.5;
    println!("  target error: {target:.4e}");
    let base_t = baseline.time_to_error(target);
    for out in &outs {
        let r = &out.recorder;
        match r.time_to_error(target) {
            Some(t) => {
                let speedup = base_t.map(|bt| bt / t).unwrap_or(f64::NAN);
                println!(
                    "  {:<28} t = {t:>7.0}   ({speedup:.2}x vs baseline)",
                    r.label
                );
            }
            None => println!("  {:<28} never reaches it", r.label),
        }
    }

    let out_path = std::path::Path::new("results/bench_bidirectional.csv");
    match write_sweep_csv(out_path, &specs, &outs) {
        Ok(()) => println!("  series written to {}", out_path.display()),
        Err(e) => println!("  (csv not written: {e})"),
    }
}
