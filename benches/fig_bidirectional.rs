//! Bidirectional link sweep: downlink scheme × ingress capacity × k-policy.
//!
//! Fig-2 setup (n = 50, exp(1) compute delays, η = 5·10⁻⁴, §V.A data)
//! with the uplink fixed at the `fig_comm_tradeoff` operating point
//! (dense, 400 B per virtual-time unit) and the *new* axes swept:
//!
//! * **downlink** — free dense full-model broadcast vs priced dense vs
//!   compressed model deltas (top-k / QSGD with a master-side residual)
//!   over a 400 B/t downlink, and
//! * **ingress** — unlimited (independent uploads, the PR-1 model) vs a
//!   shared master NIC the k accepted uploads serialize through.
//!
//! The point the sweep makes: with fat models and large k the
//! uplink-only model *understates* the round time exactly where
//! adaptive-k matters most — finite ingress punishes large fixed k, and
//! compressed delta broadcast buys back most of the downlink cost.
//!
//! Run: `cargo bench --bench fig_bidirectional`

use adasgd::bench_harness::section;
use adasgd::config::{
    CommSpec, CompressorSpec, DelaySpec, ExperimentConfig, PolicySpec,
    WorkloadSpec,
};
use adasgd::coordinator::run_experiment;
use adasgd::metrics::{write_csv, Recorder};
use adasgd::policy::PflugParams;

const UP_BANDWIDTH: f64 = 400.0; // bytes per virtual-time unit
const DOWN_BANDWIDTH: f64 = 400.0;
const MAX_TIME: f64 = 4000.0;

fn base(seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        label: String::new(),
        n: 50,
        eta: 5e-4,
        max_iterations: 200_000,
        max_time: MAX_TIME,
        seed,
        record_stride: 25,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 40 },
        workload: WorkloadSpec::LinReg { m: 2000, d: 100 },
        comm: CommSpec::default(),
        coding: None,
    }
}

/// (label, downlink scheme, downlink bandwidth): free dense is the PR-1
/// baseline; the rest price the broadcast.
fn downlinks() -> Vec<(&'static str, CompressorSpec, f64)> {
    vec![
        ("downfree", CompressorSpec::Dense, 0.0),
        ("downdense", CompressorSpec::Dense, DOWN_BANDWIDTH),
        (
            "downtopk10",
            CompressorSpec::TopK { frac: 0.1 },
            DOWN_BANDWIDTH,
        ),
        (
            "downqsgd4",
            CompressorSpec::Qsgd { levels: 4 },
            DOWN_BANDWIDTH,
        ),
    ]
}

/// (label, shared master-ingress capacity): 0 = unlimited.
fn ingresses() -> Vec<(&'static str, f64)> {
    vec![("ing-inf", 0.0), ("ing4k", 4000.0)]
}

fn policies() -> Vec<(&'static str, PolicySpec)> {
    vec![
        ("k=40", PolicySpec::Fixed { k: 40 }),
        (
            "adaptive",
            PolicySpec::Adaptive(PflugParams {
                k0: 10,
                step: 10,
                thresh: 10,
                burnin: 200,
                k_max: 40,
            }),
        ),
    ]
}

fn main() {
    let seed = 0u64;
    section(&format!(
        "bidirectional sweep: downlink x ingress x policy (n=50, exp(1), \
         uplink dense {UP_BANDWIDTH} B/t, T={MAX_TIME})"
    ));

    let mut runs: Vec<Recorder> = Vec::new();
    let mut rows = Vec::new();
    for (dname, downlink, down_bw) in downlinks() {
        for (iname, ingress_bw) in ingresses() {
            for (pname, policy) in policies() {
                let mut cfg = base(seed);
                cfg.label = format!("{dname}/{iname}/{pname}");
                cfg.policy = policy;
                cfg.comm = CommSpec {
                    bandwidth: UP_BANDWIDTH,
                    downlink: downlink.clone(),
                    down_bandwidth: down_bw,
                    ingress_bw,
                    ..Default::default()
                };
                let out = run_experiment(&cfg).expect("sweep run");
                rows.push((
                    cfg.label.clone(),
                    out.recorder.min_error().unwrap_or(f64::NAN),
                    out.steps,
                    out.bytes_sent,
                    out.bytes_down,
                    out.total_time,
                ));
                runs.push(out.recorder);
            }
        }
    }

    println!(
        "{:<28} {:>12} {:>8} {:>13} {:>13} {:>9}",
        "downlink/ingress/policy", "min error", "iters", "bytes_up",
        "bytes_down", "t_end"
    );
    for (label, min_err, steps, up, down, t_end) in &rows {
        println!(
            "{label:<28} {min_err:>12.4e} {steps:>8} {up:>13} {down:>13} \
             {t_end:>9.0}"
        );
    }

    // Invariant spot-check: at the same policy and downlink, finite
    // ingress must complete strictly fewer iterations in the same
    // time budget than unlimited ingress (every round is longer).
    section("congestion sanity: finite ingress completes fewer rounds");
    let steps_of = |label: &str| {
        rows.iter()
            .find(|r| r.0 == label)
            .map(|r| r.2)
            .expect("labelled run")
    };
    let free = steps_of("downfree/ing-inf/k=40");
    let congested = steps_of("downfree/ing4k/k=40");
    if congested < free {
        println!(
            "  OK: ing4k ran {congested} rounds vs {free} unlimited \
             (shared ingress stretches every k=40 round)"
        );
    } else {
        println!(
            "  WARNING: expected fewer rounds under finite ingress; got \
             {congested} vs {free}"
        );
    }

    // Headline: wall-clock to the free-downlink k=40 floor.
    section("time-to-error at the free-downlink k=40 floor");
    let baseline = runs
        .iter()
        .find(|r| r.label == "downfree/ing-inf/k=40")
        .expect("baseline run");
    let target = baseline.min_error().unwrap() * 1.5;
    println!("  target error: {target:.4e}");
    let base_t = baseline.time_to_error(target);
    for r in &runs {
        match r.time_to_error(target) {
            Some(t) => {
                let speedup = base_t.map(|bt| bt / t).unwrap_or(f64::NAN);
                println!(
                    "  {:<28} t = {t:>7.0}   ({speedup:.2}x vs baseline)",
                    r.label
                );
            }
            None => println!("  {:<28} never reaches it", r.label),
        }
    }

    let refs: Vec<&Recorder> = runs.iter().collect();
    write_csv(std::path::Path::new("results/bench_bidirectional.csv"), &refs)
        .ok();
    println!("  series written to results/bench_bidirectional.csv");
}
