//! Gradient-coding sweep: scheme × replication × k-policy × ingress,
//! with communication pricing enabled.
//!
//! Fig-2 setup (n = 50, exp(1) compute delays, η = 5·10⁻⁴, §V.A data)
//! with a priced uplink (dense, 400 B per virtual-time unit) so coded
//! and uncoded rounds contend on the same clock. Swept axes:
//!
//! * **scheme** — frc (grouped repetition), cyclic (windows), bernoulli
//!   (random r-regular placement),
//! * **r** — replication 2 and 5 (r× compute, r−1 stragglers tolerated),
//! * **k-policy** — the wait target: fixed at the recovery threshold
//!   n−r+1 (classic coded GD), fixed at the decodability floor n/r
//!   (pure "first decodable responder set"), or adaptive (Pflug),
//! * **ingress** — unlimited vs a shared 4 kB/t master NIC.
//!
//! The trade-off on display (§I.A of the paper): coded rounds apply the
//! *exact* gradient but pay r× compute and, under finite ingress, ship
//! n/r-to-threshold messages per round; the uncoded adaptive baseline
//! accepts gradient noise for cheaper rounds. The decodability floor
//! shows how much of the classic threshold wait is slack.
//!
//! Run: `cargo bench --bench fig_coding`

use adasgd::bench_harness::section;
use adasgd::config::{
    CodingSchemeSpec, CodingSpec, CommSpec, DelaySpec, ExperimentConfig,
    PolicySpec, WorkloadSpec,
};
use adasgd::coordinator::run_experiment;
use adasgd::metrics::{write_csv_with_header, Recorder};
use adasgd::policy::PflugParams;

const N: usize = 50;
const UP_BANDWIDTH: f64 = 400.0; // bytes per virtual-time unit
const MAX_TIME: f64 = 1200.0;

fn base(seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        label: String::new(),
        n: N,
        eta: 5e-4,
        max_iterations: 200_000,
        max_time: MAX_TIME,
        seed,
        record_stride: 25,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: N },
        workload: WorkloadSpec::LinReg { m: 2000, d: 100 },
        comm: CommSpec {
            bandwidth: UP_BANDWIDTH,
            ..Default::default()
        },
        coding: None,
    }
}

fn schemes() -> Vec<CodingSchemeSpec> {
    vec![
        CodingSchemeSpec::Frc,
        CodingSchemeSpec::Cyclic,
        CodingSchemeSpec::Bernoulli,
    ]
}

/// (label, policy) for a given replication factor.
fn policies(r: usize) -> Vec<(String, PolicySpec)> {
    let threshold = N - r + 1;
    let floor = N / r;
    vec![
        (format!("fix-thr{threshold}"), PolicySpec::Fixed { k: threshold }),
        (format!("fix-floor{floor}"), PolicySpec::Fixed { k: floor }),
        (
            "adaptive".to_string(),
            PolicySpec::Adaptive(PflugParams {
                k0: floor,
                step: 5,
                thresh: 10,
                burnin: 200,
                k_max: N,
            }),
        ),
    ]
}

fn ingresses() -> Vec<(&'static str, f64)> {
    vec![("ing-inf", 0.0), ("ing4k", 4000.0)]
}

fn main() {
    let seed = 0u64;
    section(&format!(
        "coding sweep: scheme x r x k-policy x ingress (n={N}, exp(1), \
         uplink dense {UP_BANDWIDTH} B/t, T={MAX_TIME})"
    ));

    let mut runs: Vec<Recorder> = Vec::new();
    let mut meta: Vec<String> = Vec::new();
    let mut rows = Vec::new();

    // Uncoded adaptive fastest-k baseline on the same priced uplink.
    {
        let mut cfg = base(seed);
        cfg.label = "uncoded/adaptive".into();
        cfg.policy = PolicySpec::Adaptive(PflugParams {
            k0: 10,
            step: 10,
            thresh: 10,
            burnin: 200,
            k_max: N,
        });
        let out = run_experiment(&cfg).expect("baseline run");
        rows.push((
            cfg.label.clone(),
            out.recorder.min_error().unwrap_or(f64::NAN),
            out.steps,
            out.bytes_sent,
            out.total_time,
        ));
        runs.push(out.recorder);
        meta.push(format!("{}: coding=none", cfg.label));
    }

    for scheme in schemes() {
        for r in [2usize, 5] {
            for (pname, policy) in policies(r) {
                for (iname, ingress_bw) in ingresses() {
                    let mut cfg = base(seed);
                    cfg.label = format!("{scheme}-r{r}/{pname}/{iname}");
                    cfg.policy = policy.clone();
                    cfg.comm.ingress_bw = ingress_bw;
                    cfg.coding = Some(CodingSpec { scheme, r });
                    let out = run_experiment(&cfg).expect("sweep run");
                    rows.push((
                        cfg.label.clone(),
                        out.recorder.min_error().unwrap_or(f64::NAN),
                        out.steps,
                        out.bytes_sent,
                        out.total_time,
                    ));
                    runs.push(out.recorder);
                    meta.push(format!(
                        "{}: coding: scheme={scheme} r={r}",
                        cfg.label
                    ));
                }
            }
        }
    }

    println!(
        "{:<34} {:>12} {:>8} {:>13} {:>9}",
        "scheme-r/policy/ingress", "min error", "iters", "bytes_up", "t_end"
    );
    for (label, min_err, steps, up, t_end) in &rows {
        println!(
            "{label:<34} {min_err:>12.4e} {steps:>8} {up:>13} {t_end:>9.0}"
        );
    }

    // Invariant spot-checks.
    section("sanity: the decodability floor is never slower than the \
             threshold wait");
    let steps_of = |label: &str| {
        rows.iter()
            .find(|row| row.0 == label)
            .map(|row| row.2)
            .expect("labelled run")
    };
    let thr = steps_of("frc-r2/fix-thr49/ing-inf");
    let floor = steps_of("frc-r2/fix-floor25/ing-inf");
    if floor >= thr {
        println!(
            "  OK: frc r=2 floor target ran {floor} rounds vs {thr} at \
             the threshold (every round decodes no later)"
        );
    } else {
        println!(
            "  WARNING: floor target ran fewer rounds ({floor} vs {thr})"
        );
    }

    section("time-to-error vs the uncoded adaptive baseline");
    let baseline = runs
        .iter()
        .find(|r| r.label == "uncoded/adaptive")
        .expect("baseline");
    let target = baseline.min_error().unwrap() * 1.5;
    println!("  target error: {target:.4e}");
    let base_t = baseline.time_to_error(target);
    for r in &runs {
        match r.time_to_error(target) {
            Some(t) => {
                let speedup = base_t.map(|bt| bt / t).unwrap_or(f64::NAN);
                println!(
                    "  {:<34} t = {t:>7.0}   ({speedup:.2}x vs baseline)",
                    r.label
                );
            }
            None => println!("  {:<34} never reaches it", r.label),
        }
    }

    let refs: Vec<&Recorder> = runs.iter().collect();
    write_csv_with_header(
        std::path::Path::new("results/bench_coding.csv"),
        &refs,
        &meta,
    )
    .ok();
    println!("  series written to results/bench_coding.csv");
}
