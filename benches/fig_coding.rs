//! Gradient-coding sweep: scheme × replication × k-policy × ingress,
//! with communication pricing enabled.
//!
//! Fig-2 setup (n = 50, exp(1) compute delays, η = 5·10⁻⁴, §V.A data)
//! with a priced uplink (dense, 400 B per virtual-time unit) so coded
//! and uncoded rounds contend on the same clock. Swept axes:
//!
//! * **scheme** — frc (grouped repetition), cyclic (windows), bernoulli
//!   (random r-regular placement),
//! * **r** — replication 2 and 5 (r× compute, r−1 stragglers tolerated),
//! * **k-policy** — the wait target: fixed at the recovery threshold
//!   n−r+1 (classic coded GD), fixed at the decodability floor n/r
//!   (pure "first decodable responder set"), or adaptive (Pflug),
//! * **ingress** — unlimited vs a shared 4 kB/t master NIC.
//!
//! The trade-off on display (§I.A of the paper): coded rounds apply the
//! *exact* gradient but pay r× compute and, under finite ingress, ship
//! n/r-to-threshold messages per round; the uncoded adaptive baseline
//! accepts gradient noise for cheaper rounds. The decodability floor
//! shows how much of the classic threshold wait is slack.
//!
//! The grid (plus the uncoded baseline spec) executes in parallel
//! through `sweep::SweepExecutor` (`--jobs N`, 0 = all cores;
//! byte-identical output). `--smoke` shrinks the grid for CI.
//!
//! Run: `cargo bench --bench fig_coding [-- --jobs N --smoke]`

use adasgd::bench_harness::{section, BenchArgs};
use adasgd::config::{
    CodingSchemeSpec, CodingSpec, CommSpec, DelaySpec, ExperimentConfig,
    PolicySpec, WorkloadSpec,
};
use adasgd::policy::PflugParams;
use adasgd::sweep::{
    edit, write_sweep_csv, CfgEdit, RunSpec, SweepExecutor, SweepGrid,
};

const UP_BANDWIDTH: f64 = 400.0; // bytes per virtual-time unit

fn base(seed: u64, smoke: bool) -> ExperimentConfig {
    let (n, m, d, max_time) =
        if smoke { (10, 200, 10, 120.0) } else { (50, 2000, 100, 1200.0) };
    ExperimentConfig {
        label: String::new(),
        n,
        eta: 5e-4,
        max_iterations: 200_000,
        max_time,
        seed,
        record_stride: 25,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: n },
        workload: WorkloadSpec::LinReg { m, d },
        comm: CommSpec { bandwidth: UP_BANDWIDTH, ..Default::default() },
        coding: None,
        jobs: 0,
        intra_jobs: 1,
        trace: None,
        fastpath: false,
    }
}

/// One combined (scheme × r) axis, so a cell's `CodingSpec` is set
/// whole — no cross-axis backfill with silent defaults. The `/` in the
/// value labels keeps the joined cell labels identical to a two-axis
/// split ("frc/r2/fix-thr/ing-inf").
fn coding_axis() -> Vec<(String, CfgEdit)> {
    let mut values = Vec::new();
    for scheme in [
        CodingSchemeSpec::Frc,
        CodingSchemeSpec::Cyclic,
        CodingSchemeSpec::Bernoulli,
    ] {
        for r in [2usize, 5] {
            values.push((
                format!("{scheme}/r{r}"),
                edit(move |c: &mut ExperimentConfig| {
                    c.coding = Some(CodingSpec { scheme, r })
                }),
            ));
        }
    }
    values
}

/// Wait-target axis (depends on n and r, so it reads both from the cfg;
/// declare it *after* the coding axis — the edits assert that).
fn policy_axis() -> Vec<(String, CfgEdit)> {
    let r_of = |c: &ExperimentConfig| {
        c.coding
            .as_ref()
            .expect("policy axis must come after the coding axis")
            .r
    };
    let threshold = move |c: &ExperimentConfig| c.n - r_of(c) + 1;
    let floor = move |c: &ExperimentConfig| c.n / r_of(c);
    vec![
        (
            "fix-thr".into(),
            edit(move |c| {
                let k = threshold(c);
                c.policy = PolicySpec::Fixed { k };
            }),
        ),
        (
            "fix-floor".into(),
            edit(move |c| {
                let k = floor(c);
                c.policy = PolicySpec::Fixed { k };
            }),
        ),
        (
            "adaptive".into(),
            edit(move |c| {
                let k0 = floor(c);
                let k_max = c.n;
                c.policy = PolicySpec::Adaptive(PflugParams {
                    k0,
                    step: 5,
                    thresh: 10,
                    burnin: 200,
                    k_max,
                })
            }),
        ),
    ]
}

#[path = "sweep_axes.rs"]
mod sweep_axes;
use sweep_axes::ingress_axis;

fn main() {
    let args = BenchArgs::from_env();
    let seed = 0u64;
    let cfg0 = base(seed, args.smoke);
    let n = cfg0.n;
    section(&format!(
        "coding sweep: scheme x r x k-policy x ingress (n={n}, exp(1), \
         uplink dense {UP_BANDWIDTH} B/t, T={}, jobs={})",
        cfg0.max_time,
        SweepExecutor::new(args.jobs).jobs()
    ));

    // Uncoded adaptive fastest-k baseline on the same priced uplink,
    // prepended to the coded grid as spec 0.
    let mut baseline = cfg0.clone();
    baseline.label = "uncoded/adaptive".into();
    baseline.policy = PolicySpec::Adaptive(PflugParams {
        k0: n / 5,
        step: n / 5,
        thresh: 10,
        burnin: 200,
        k_max: n,
    });
    let mut specs = vec![RunSpec::from_config(0, baseline)];
    let grid = SweepGrid::new(cfg0)
        .axis("coding", coding_axis())
        .axis("policy", policy_axis())
        .axis("ingress", ingress_axis())
        .build();
    specs.extend(grid.into_iter().map(|mut s| {
        s.index += 1;
        s
    }));

    let outs =
        SweepExecutor::new(args.jobs).run(&specs).expect("coding sweep");

    println!(
        "{:<34} {:>12} {:>8} {:>13} {:>9}",
        "scheme/r/policy/ingress", "min error", "iters", "bytes_up", "t_end"
    );
    for (spec, out) in specs.iter().zip(&outs) {
        println!(
            "{:<34} {:>12.4e} {:>8} {:>13} {:>9.0}",
            spec.label,
            out.recorder.min_error().unwrap_or(f64::NAN),
            out.steps,
            out.bytes_sent,
            out.total_time
        );
    }

    // Invariant spot-checks.
    section("sanity: the decodability floor is never slower than the \
             threshold wait");
    let steps_of = |label: &str| {
        specs
            .iter()
            .position(|s| s.label == label)
            .map(|i| outs[i].steps)
            .expect("labelled run")
    };
    let thr = steps_of("frc/r2/fix-thr/ing-inf");
    let floor = steps_of("frc/r2/fix-floor/ing-inf");
    if floor >= thr {
        println!(
            "  OK: frc r=2 floor target ran {floor} rounds vs {thr} at \
             the threshold (every round decodes no later)"
        );
    } else {
        println!(
            "  WARNING: floor target ran fewer rounds ({floor} vs {thr})"
        );
    }

    section("time-to-error vs the uncoded adaptive baseline");
    let baseline_rec = &outs[0].recorder;
    let target = baseline_rec.min_error().unwrap() * 1.5;
    println!("  target error: {target:.4e}");
    let base_t = baseline_rec.time_to_error(target);
    for out in &outs {
        let r = &out.recorder;
        match r.time_to_error(target) {
            Some(t) => {
                let speedup = base_t.map(|bt| bt / t).unwrap_or(f64::NAN);
                println!(
                    "  {:<34} t = {t:>7.0}   ({speedup:.2}x vs baseline)",
                    r.label
                );
            }
            None => println!("  {:<34} never reaches it", r.label),
        }
    }

    let out_path = std::path::Path::new("results/bench_coding.csv");
    match write_sweep_csv(out_path, &specs, &outs) {
        Ok(()) => println!("  series written to {}", out_path.display()),
        Err(e) => println!("  (csv not written: {e})"),
    }
}
