//! Communication trade-off sweep: compression scheme × k-policy.
//!
//! Fig-2 setup (n = 50, exp(1) compute delays, η = 5·10⁻⁴, §V.A data)
//! over a *finite* uplink — 400 B per virtual-time unit with 0.05 latency
//! per message — so a dense 416-byte gradient costs ≈1.1 time units per
//! iteration while a 10% top-k message costs ≈0.29. The sweep shows the
//! axis the compute-only model cannot: with bytes priced, compressed
//! schemes reach the dense run's error floor in *less* wall-clock, and
//! the adaptive policy composes with any scheme.
//!
//! Run: `cargo bench --bench fig_comm_tradeoff`

use adasgd::bench_harness::section;
use adasgd::config::{
    CommSpec, CompressorSpec, DelaySpec, ExperimentConfig, PolicySpec,
    WorkloadSpec,
};
use adasgd::coordinator::run_experiment;
use adasgd::metrics::{write_csv, Recorder};
use adasgd::policy::PflugParams;

const BANDWIDTH: f64 = 400.0; // bytes per virtual-time unit
const LATENCY: f64 = 0.05;
const MAX_TIME: f64 = 6500.0;

fn base(seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        label: String::new(),
        n: 50,
        eta: 5e-4,
        max_iterations: 200_000,
        max_time: MAX_TIME,
        seed,
        record_stride: 25,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 40 },
        workload: WorkloadSpec::LinReg { m: 2000, d: 100 },
        comm: CommSpec::default(),
        coding: None,
    }
}

fn schemes() -> Vec<(&'static str, CompressorSpec)> {
    vec![
        ("dense", CompressorSpec::Dense),
        ("topk10", CompressorSpec::TopK { frac: 0.1 }),
        ("randk10", CompressorSpec::RandK { frac: 0.1 }),
        ("qsgd4", CompressorSpec::Qsgd { levels: 4 }),
    ]
}

fn policies() -> Vec<(&'static str, PolicySpec)> {
    vec![
        ("k=10", PolicySpec::Fixed { k: 10 }),
        ("k=40", PolicySpec::Fixed { k: 40 }),
        (
            "adaptive",
            PolicySpec::Adaptive(PflugParams {
                k0: 10,
                step: 10,
                thresh: 10,
                burnin: 200,
                k_max: 40,
            }),
        ),
    ]
}

fn main() {
    let seed = 0u64;
    section(&format!(
        "comm trade-off: scheme x k-policy (n=50, exp(1), uplink {BANDWIDTH} B/t + {LATENCY} lat, T={MAX_TIME})"
    ));

    let mut runs: Vec<Recorder> = Vec::new();
    let mut rows = Vec::new();
    for (sname, scheme) in schemes() {
        for (pname, policy) in policies() {
            let mut cfg = base(seed);
            cfg.label = format!("{sname}/{pname}");
            cfg.policy = policy;
            cfg.comm = CommSpec {
                scheme: scheme.clone(),
                error_feedback: true,
                bandwidth: BANDWIDTH,
                latency: LATENCY,
                ..Default::default()
            };
            let out = run_experiment(&cfg).expect("sweep run");
            rows.push((
                cfg.label.clone(),
                out.recorder.min_error().unwrap_or(f64::NAN),
                out.steps,
                out.bytes_sent,
                out.total_time,
            ));
            runs.push(out.recorder);
        }
    }

    println!(
        "{:<18} {:>12} {:>9} {:>14} {:>10}",
        "scheme/policy", "min error", "iters", "bytes", "t_end"
    );
    for (label, min_err, steps, bytes, t_end) in &rows {
        println!(
            "{label:<18} {min_err:>12.4e} {steps:>9} {bytes:>14} {t_end:>10.0}"
        );
    }

    // Headline: wall-clock to reach 1.5x the dense/k=40 floor.
    section("time-to-error at the dense k=40 floor (the paper's metric, comm-priced)");
    let dense_k40 = runs
        .iter()
        .find(|r| r.label == "dense/k=40")
        .expect("dense/k=40 run");
    let target = dense_k40.min_error().unwrap() * 1.5;
    println!("  target error: {target:.4e}");
    let dense_t = dense_k40.time_to_error(target);
    for r in &runs {
        match r.time_to_error(target) {
            Some(t) => {
                let speedup = dense_t.map(|dt| dt / t).unwrap_or(f64::NAN);
                println!(
                    "  {:<18} t = {t:>7.0}   ({speedup:.2}x vs dense/k=40)",
                    r.label
                );
            }
            None => println!("  {:<18} never reaches it", r.label),
        }
    }

    // The claim the sweep exists to check: at least one compressed scheme
    // strictly beats dense wall-clock at the same policy.
    let topk_k40 = runs
        .iter()
        .find(|r| r.label == "topk10/k=40")
        .and_then(|r| r.time_to_error(target));
    match (dense_t, topk_k40) {
        (Some(dt), Some(tt)) if tt < dt => println!(
            "\n  OK: topk10/k=40 reaches the target {:.2}x faster than dense/k=40",
            dt / tt
        ),
        (dt, tt) => println!(
            "\n  WARNING: expected topk10 < dense at k=40; got dense={dt:?}, topk={tt:?}"
        ),
    }

    let refs: Vec<&Recorder> = runs.iter().collect();
    write_csv(std::path::Path::new("results/bench_comm_tradeoff.csv"), &refs)
        .ok();
    println!("  series written to results/bench_comm_tradeoff.csv");
}
