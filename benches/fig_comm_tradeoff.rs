//! Communication trade-off sweep: compression scheme × k-policy.
//!
//! Fig-2 setup (n = 50, exp(1) compute delays, η = 5·10⁻⁴, §V.A data)
//! over a *finite* uplink — 400 B per virtual-time unit with 0.05 latency
//! per message — so a dense 416-byte gradient costs ≈1.1 time units per
//! iteration while a 10% top-k message costs ≈0.29. The sweep shows the
//! axis the compute-only model cannot: with bytes priced, compressed
//! schemes reach the dense run's error floor in *less* wall-clock, and
//! the adaptive policy composes with any scheme.
//!
//! The grid is a `sweep::SweepGrid` declaration executed in parallel by
//! `sweep::SweepExecutor` (`--jobs N`, 0 = all cores — output is
//! byte-identical for every N). `--smoke` shrinks the grid to a
//! seconds-long end-to-end pass; CI runs exactly that
//! (`cargo bench --bench fig_comm_tradeoff -- --smoke --jobs 2`).
//!
//! Run: `cargo bench --bench fig_comm_tradeoff [-- --jobs N --smoke]`

use adasgd::bench_harness::{section, BenchArgs};
use adasgd::config::{
    CommSpec, CompressorSpec, DelaySpec, ExperimentConfig, PolicySpec,
    WorkloadSpec,
};
use adasgd::policy::PflugParams;
use adasgd::sweep::{edit, write_sweep_csv, CfgEdit, SweepExecutor, SweepGrid};

const BANDWIDTH: f64 = 400.0; // bytes per virtual-time unit
const LATENCY: f64 = 0.05;

/// Scenario scale: the paper-sized grid, or a tiny smoke grid that
/// exercises the same path end-to-end in seconds.
struct Scale {
    n: usize,
    m: usize,
    d: usize,
    max_time: f64,
    k_small: usize,
    k_large: usize,
}

impl Scale {
    fn new(smoke: bool) -> Self {
        if smoke {
            Self { n: 10, m: 200, d: 10, max_time: 150.0, k_small: 2, k_large: 8 }
        } else {
            Self { n: 50, m: 2000, d: 100, max_time: 6500.0, k_small: 10, k_large: 40 }
        }
    }
}

fn base(seed: u64, s: &Scale) -> ExperimentConfig {
    ExperimentConfig {
        label: String::new(),
        n: s.n,
        eta: 5e-4,
        max_iterations: 200_000,
        max_time: s.max_time,
        seed,
        record_stride: 25,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: s.k_large },
        workload: WorkloadSpec::LinReg { m: s.m, d: s.d },
        comm: CommSpec {
            error_feedback: true,
            bandwidth: BANDWIDTH,
            latency: LATENCY,
            ..Default::default()
        },
        coding: None,
        jobs: 0,
        intra_jobs: 1,
        trace: None,
        fastpath: false,
    }
}

fn scheme_axis() -> Vec<(String, CfgEdit)> {
    vec![
        ("dense".into(), edit(|c| c.comm.scheme = CompressorSpec::Dense)),
        (
            "topk10".into(),
            edit(|c| c.comm.scheme = CompressorSpec::TopK { frac: 0.1 }),
        ),
        (
            "randk10".into(),
            edit(|c| c.comm.scheme = CompressorSpec::RandK { frac: 0.1 }),
        ),
        (
            "qsgd4".into(),
            edit(|c| c.comm.scheme = CompressorSpec::Qsgd { levels: 4 }),
        ),
    ]
}

fn policy_axis(s: &Scale) -> Vec<(String, CfgEdit)> {
    let (k_small, k_large) = (s.k_small, s.k_large);
    vec![
        (
            format!("k={k_small}"),
            edit(move |c| c.policy = PolicySpec::Fixed { k: k_small }),
        ),
        (
            format!("k={k_large}"),
            edit(move |c| c.policy = PolicySpec::Fixed { k: k_large }),
        ),
        (
            "adaptive".into(),
            edit(move |c| {
                c.policy = PolicySpec::Adaptive(PflugParams {
                    k0: k_small,
                    step: k_small,
                    thresh: 10,
                    burnin: 200,
                    k_max: k_large,
                })
            }),
        ),
    ]
}

fn main() {
    let args = BenchArgs::from_env();
    let scale = Scale::new(args.smoke);
    let seed = 0u64;
    section(&format!(
        "comm trade-off: scheme x k-policy (n={}, exp(1), uplink \
         {BANDWIDTH} B/t + {LATENCY} lat, T={}, jobs={})",
        scale.n,
        scale.max_time,
        SweepExecutor::new(args.jobs).jobs()
    ));

    let specs = SweepGrid::new(base(seed, &scale))
        .axis("scheme", scheme_axis())
        .axis("policy", policy_axis(&scale))
        .build();
    let outs = SweepExecutor::new(args.jobs)
        .run(&specs)
        .expect("comm trade-off sweep");

    println!(
        "{:<18} {:>12} {:>9} {:>14} {:>10}",
        "scheme/policy", "min error", "iters", "bytes", "t_end"
    );
    for (spec, out) in specs.iter().zip(&outs) {
        println!(
            "{:<18} {:>12.4e} {:>9} {:>14} {:>10.0}",
            spec.label,
            out.recorder.min_error().unwrap_or(f64::NAN),
            out.steps,
            out.bytes_sent,
            out.total_time
        );
    }

    // Headline: wall-clock to reach 1.5x the dense/k=large floor.
    section("time-to-error at the dense k=large floor (the paper's metric, comm-priced)");
    let dense_label = format!("dense/k={}", scale.k_large);
    let dense_k40 = specs
        .iter()
        .position(|s| s.label == dense_label)
        .map(|i| &outs[i].recorder)
        .expect("dense/k=large run");
    let target = dense_k40.min_error().unwrap() * 1.5;
    println!("  target error: {target:.4e}");
    let dense_t = dense_k40.time_to_error(target);
    for out in &outs {
        let r = &out.recorder;
        match r.time_to_error(target) {
            Some(t) => {
                let speedup = dense_t.map(|dt| dt / t).unwrap_or(f64::NAN);
                println!(
                    "  {:<18} t = {t:>7.0}   ({speedup:.2}x vs {dense_label})",
                    r.label
                );
            }
            None => println!("  {:<18} never reaches it", r.label),
        }
    }

    // The claim the sweep exists to check: at least one compressed scheme
    // strictly beats dense wall-clock at the same policy.
    let topk_label = format!("topk10/k={}", scale.k_large);
    let topk_k40 = specs
        .iter()
        .position(|s| s.label == topk_label)
        .and_then(|i| outs[i].recorder.time_to_error(target));
    match (dense_t, topk_k40) {
        (Some(dt), Some(tt)) if tt < dt => println!(
            "\n  OK: {topk_label} reaches the target {:.2}x faster than {dense_label}",
            dt / tt
        ),
        (dt, tt) => println!(
            "\n  WARNING: expected topk10 < dense at k={}; got dense={dt:?}, topk={tt:?}",
            scale.k_large
        ),
    }

    let out_path = std::path::Path::new("results/bench_comm_tradeoff.csv");
    match write_sweep_csv(out_path, &specs, &outs) {
        Ok(()) => println!("  series written to {}", out_path.display()),
        Err(e) => println!("  (csv not written: {e})"),
    }
}
