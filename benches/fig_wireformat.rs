//! Wire-format sweep: message framing × compression scheme.
//!
//! The byte model of `comm::WireFormat` is itself a design axis: 2-byte
//! (`u16`) coordinate indices address any `d ≤ 65536` at half the index
//! cost, and 2-byte (f16) values halve the payload at ~3 decimal digits
//! of precision — with the value loss *modelled* (survivors round
//! through f16 on the way to the master; error feedback recovers the
//! residual). This sweep runs the Fig-2 setup (n = 50, exp(1) compute
//! delays, d = 100) over a finite uplink and compares, per scheme:
//!
//! * `f32/u32` — the default framing (4-byte values, 4-byte indices),
//! * `f32/u16` — compact indices (sparse schemes only benefit),
//! * `f16/u32` — half-precision values,
//! * `f16/u16` — both.
//!
//! The point: for top-k at 10% density the index stream is half the
//! message, so `u16` indices buy almost as much wall-clock as halving
//! the values — and the two together beat QSGD's 4-level packing on
//! time-to-error while staying a trivial encoder.
//!
//! Run: `cargo bench --bench fig_wireformat`

use adasgd::bench_harness::section;
use adasgd::comm::{
    CommChannel, Compressor, Dense, LinkModel, QuantizeQsgd, RandK, TopK,
    WireFormat,
};
use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::grad::NativeBackend;
use adasgd::master::{run_fastest_k_comm, MasterConfig};
use adasgd::metrics::{write_csv, Recorder};
use adasgd::model::LinRegProblem;
use adasgd::policy::FixedK;
use adasgd::straggler::ExponentialDelays;
use std::path::Path;

const N: usize = 50;
const D: usize = 100;
const K: usize = 40;
const BANDWIDTH: f64 = 400.0; // bytes per virtual-time unit
const MAX_TIME: f64 = 3000.0;

/// (label, wire format) — the four framing corners.
fn wires() -> Vec<(&'static str, WireFormat)> {
    vec![
        ("f32-u32", WireFormat::default()),
        ("f32-u16", WireFormat::default().compact_indices()),
        ("f16-u32", WireFormat::default().f16_values()),
        ("f16-u16", WireFormat::default().compact_indices().f16_values()),
    ]
}

/// (label, compressor for a given wire, error feedback). QSGD rides
/// along as the packing-based comparator: only its norm scalar feels
/// the value width (the per-coordinate payload is already sub-byte).
fn schemes(
    wire: WireFormat,
) -> Vec<(&'static str, Box<dyn Compressor>, bool)> {
    vec![
        ("dense", Box::new(Dense::with_wire(wire)), false),
        ("topk10", Box::new(TopK::with_wire(0.1, wire)), true),
        ("randk10", Box::new(RandK::with_wire(0.1, wire)), true),
        ("qsgd4", Box::new(QuantizeQsgd::with_wire(4, wire)), true),
    ]
}

fn main() {
    let seed = 0u64;
    section(&format!(
        "wire-format sweep: framing x scheme (n={N}, d={D}, k={K}, \
         uplink {BANDWIDTH} B/t, T={MAX_TIME})"
    ));

    let ds = SyntheticDataset::generate(
        SyntheticConfig { m: 2000, d: D, ..Default::default() },
        seed,
    );
    let problem = LinRegProblem::new(&ds);

    let mut runs: Vec<Recorder> = Vec::new();
    let mut rows = Vec::new();
    for (wname, wire) in wires() {
        for (sname, compressor, feedback) in schemes(wire) {
            let msg_bytes = compressor.encoded_bytes(D);
            let mut backend =
                NativeBackend::new(Shards::partition(&ds, N));
            let delays = ExponentialDelays::new(1.0);
            let mut policy = FixedK::new(K);
            let mut channel = CommChannel::new(
                compressor,
                LinkModel::uniform(N, BANDWIDTH, 0.0),
                feedback,
            );
            let cfg = MasterConfig {
                eta: 5e-4,
                max_iterations: 200_000,
                max_time: MAX_TIME,
                seed,
                record_stride: 25,
                ..Default::default()
            };
            let run = run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &vec![0.0f32; D],
                &cfg,
                &mut |w| problem.error(w),
            );
            let label = format!("{sname}/{wname}");
            let mut recorder = run.recorder;
            recorder.label = label.clone();
            rows.push((
                label,
                msg_bytes,
                recorder.min_error().unwrap_or(f64::NAN),
                run.iterations,
                run.bytes_sent,
                run.total_time,
            ));
            runs.push(recorder);
        }
    }

    println!(
        "{:<18} {:>9} {:>12} {:>8} {:>13} {:>9}",
        "scheme/wire", "msg B", "min error", "iters", "bytes_up", "t_end"
    );
    for (label, msg, min_err, iters, up, t_end) in &rows {
        println!(
            "{label:<18} {msg:>9} {min_err:>12.4e} {iters:>8} {up:>13} \
             {t_end:>9.0}"
        );
    }

    // Exact byte accounting spot-checks (the sweep's whole point).
    section("framing arithmetic: exact encoded sizes");
    let dflt = WireFormat::default();
    println!(
        "  dense d={D}: {} B (f32) vs {} B (f16)",
        dflt.dense(D),
        dflt.f16_values().dense(D)
    );
    println!(
        "  topk 10% of d={D}: {} B (f32/u32) vs {} B (f32/u16) vs {} B \
         (f16/u16)",
        dflt.sparse(10),
        dflt.compact_indices().sparse(10),
        dflt.compact_indices().f16_values().sparse(10)
    );
    assert_eq!(dflt.sparse(10), 16 + 10 * 8);
    assert_eq!(dflt.compact_indices().sparse(10), 16 + 10 * 6);
    assert_eq!(dflt.compact_indices().f16_values().sparse(10), 16 + 10 * 4);
    assert_eq!(dflt.f16_values().dense(D), 16 + 2 * D as u64);

    // Sanity: in a fixed time budget, smaller frames mean more
    // iterations for the same scheme.
    section("smaller frames complete more rounds in the budget");
    let iters_of = |label: &str| {
        rows.iter().find(|r| r.0 == label).map(|r| r.3).unwrap()
    };
    let full = iters_of("topk10/f32-u32");
    let compact = iters_of("topk10/f16-u16");
    println!("  topk10: {full} iters (f32/u32) -> {compact} (f16/u16)");
    assert!(
        compact > full,
        "compact framing must buy iterations: {compact} vs {full}"
    );

    let refs: Vec<&Recorder> = runs.iter().collect();
    let out = Path::new("results/fig_wireformat.csv");
    match write_csv(out, &refs) {
        Ok(()) => println!("\n  series written to {}", out.display()),
        Err(e) => println!("\n  (csv not written: {e})"),
    }
}
