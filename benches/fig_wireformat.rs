//! Wire-format sweep: message framing × compression scheme.
//!
//! The byte model of `comm::WireFormat` is itself a design axis: 2-byte
//! (`u16`) coordinate indices address any `d ≤ 65536` at half the index
//! cost, and 2-byte (f16) values halve the payload at ~3 decimal digits
//! of precision — with the value loss *modelled* (survivors round
//! through f16 on the way to the master; error feedback recovers the
//! residual). This sweep runs the Fig-2 setup (n = 50, exp(1) compute
//! delays, d = 100) over a finite uplink and compares, per scheme:
//!
//! * `f32/u32` — the default framing (4-byte values, 4-byte indices),
//! * `f32/u16` — compact indices (sparse schemes only benefit),
//! * `f16/u32` — half-precision values,
//! * `f16/u16` — both.
//!
//! The point: for top-k at 10% density the index stream is half the
//! message, so `u16` indices buy almost as much wall-clock as halving
//! the values — and the two together beat QSGD's 4-level packing on
//! time-to-error while staying a trivial encoder.
//!
//! Custom `WireFormat` channels are not an `ExperimentConfig` axis, so
//! the 16 runs go through `sweep::SweepExecutor::map` — the same
//! order-preserving parallel fan-out the config sweeps use (`--jobs N`,
//! 0 = all cores; byte-identical output). `--smoke` shrinks the horizon
//! for CI.
//!
//! Run: `cargo bench --bench fig_wireformat [-- --jobs N --smoke]`

use adasgd::bench_harness::{section, BenchArgs};
use adasgd::comm::{
    CommChannel, Compressor, Dense, LinkModel, QuantizeQsgd, RandK, TopK,
    WireFormat,
};
use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::grad::NativeBackend;
use adasgd::master::{run_fastest_k_comm, MasterConfig};
use adasgd::metrics::{write_csv, Recorder};
use adasgd::model::LinRegProblem;
use adasgd::policy::FixedK;
use adasgd::straggler::ExponentialDelays;
use adasgd::sweep::SweepExecutor;
use std::path::Path;
use std::sync::Arc;

const N: usize = 50;
const D: usize = 100;
const K: usize = 40;
const BANDWIDTH: f64 = 400.0; // bytes per virtual-time unit

/// (label, wire format) — the four framing corners.
fn wires() -> Vec<(&'static str, WireFormat)> {
    vec![
        ("f32-u32", WireFormat::default()),
        ("f32-u16", WireFormat::default().compact_indices()),
        ("f16-u32", WireFormat::default().f16_values()),
        ("f16-u16", WireFormat::default().compact_indices().f16_values()),
    ]
}

/// (label, compressor for a given wire, error feedback). QSGD rides
/// along as the packing-based comparator: only its norm scalar feels
/// the value width (the per-coordinate payload is already sub-byte).
fn schemes(
    wire: WireFormat,
) -> Vec<(&'static str, Box<dyn Compressor>, bool)> {
    vec![
        ("dense", Box::new(Dense::with_wire(wire)), false),
        ("topk10", Box::new(TopK::with_wire(0.1, wire)), true),
        ("randk10", Box::new(RandK::with_wire(0.1, wire)), true),
        ("qsgd4", Box::new(QuantizeQsgd::with_wire(4, wire)), true),
    ]
}

/// One sweep cell's results (everything the report prints).
struct Cell {
    label: String,
    msg_bytes: u64,
    recorder: Recorder,
    iterations: u64,
    bytes_sent: u64,
    total_time: f64,
}

fn main() {
    let args = BenchArgs::from_env();
    let max_time = if args.smoke { 300.0 } else { 3000.0 };
    let seed = 0u64;
    section(&format!(
        "wire-format sweep: framing x scheme (n={N}, d={D}, k={K}, \
         uplink {BANDWIDTH} B/t, T={max_time}, jobs={})",
        SweepExecutor::new(args.jobs).jobs()
    ));

    let ds = Arc::new(SyntheticDataset::generate(
        SyntheticConfig { m: 2000, d: D, ..Default::default() },
        seed,
    ));
    // Normal-equations build + solve happen once; cells share the handle.
    let problem = Arc::new(LinRegProblem::new(&ds));

    // Flattened (wire x scheme) grid; each cell is a pure function of
    // its index, executed order-preserving by the sweep executor.
    let grid: Vec<(String, usize, usize)> = wires()
        .iter()
        .enumerate()
        .flat_map(|(wi, (wname, wire))| {
            schemes(*wire)
                .iter()
                .enumerate()
                .map(|(si, (sname, _, _))| {
                    (format!("{sname}/{wname}"), wi, si)
                })
                .collect::<Vec<_>>()
        })
        .collect();

    let cells: Vec<Cell> = {
        let ds = Arc::clone(&ds);
        let problem = Arc::clone(&problem);
        let grid = grid.clone();
        SweepExecutor::new(args.jobs).map(grid.len(), move |i| {
            let (label, wi, si) = grid[i].clone();
            let wire = wires()[wi].1;
            let (_, compressor, feedback) = schemes(wire).swap_remove(si);
            let msg_bytes = compressor.encoded_bytes(D);
            let mut backend = NativeBackend::new(Shards::partition(&ds, N));
            let delays = ExponentialDelays::new(1.0);
            let mut policy = FixedK::new(K);
            let mut channel = CommChannel::new(
                compressor,
                LinkModel::uniform(N, BANDWIDTH, 0.0),
                feedback,
            );
            let cfg = MasterConfig {
                eta: 5e-4,
                max_iterations: 200_000,
                max_time,
                seed,
                record_stride: 25,
                ..Default::default()
            };
            let run = run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &vec![0.0f32; D],
                &cfg,
                &mut |w| problem.error(w),
            );
            let mut recorder = run.recorder;
            recorder.label = label.clone();
            Cell {
                label,
                msg_bytes,
                recorder,
                iterations: run.iterations,
                bytes_sent: run.bytes_sent,
                total_time: run.total_time,
            }
        })
    };

    println!(
        "{:<18} {:>9} {:>12} {:>8} {:>13} {:>9}",
        "scheme/wire", "msg B", "min error", "iters", "bytes_up", "t_end"
    );
    for c in &cells {
        println!(
            "{:<18} {:>9} {:>12.4e} {:>8} {:>13} {:>9.0}",
            c.label,
            c.msg_bytes,
            c.recorder.min_error().unwrap_or(f64::NAN),
            c.iterations,
            c.bytes_sent,
            c.total_time
        );
    }

    // Exact byte accounting spot-checks (the sweep's whole point).
    section("framing arithmetic: exact encoded sizes");
    let dflt = WireFormat::default();
    println!(
        "  dense d={D}: {} B (f32) vs {} B (f16)",
        dflt.dense(D),
        dflt.f16_values().dense(D)
    );
    println!(
        "  topk 10% of d={D}: {} B (f32/u32) vs {} B (f32/u16) vs {} B \
         (f16/u16)",
        dflt.sparse(10),
        dflt.compact_indices().sparse(10),
        dflt.compact_indices().f16_values().sparse(10)
    );
    assert_eq!(dflt.sparse(10), 16 + 10 * 8);
    assert_eq!(dflt.compact_indices().sparse(10), 16 + 10 * 6);
    assert_eq!(dflt.compact_indices().f16_values().sparse(10), 16 + 10 * 4);
    assert_eq!(dflt.f16_values().dense(D), 16 + 2 * D as u64);

    // Sanity: in a fixed time budget, smaller frames mean more
    // iterations for the same scheme.
    section("smaller frames complete more rounds in the budget");
    let iters_of = |label: &str| {
        cells.iter().find(|c| c.label == label).map(|c| c.iterations).unwrap()
    };
    let full = iters_of("topk10/f32-u32");
    let compact = iters_of("topk10/f16-u16");
    println!("  topk10: {full} iters (f32/u32) -> {compact} (f16/u16)");
    // At the smoke horizon the margin is a handful of rounds; only hold
    // the full-scale run to the strict ordering.
    assert!(
        args.smoke || compact > full,
        "compact framing must buy iterations: {compact} vs {full}"
    );

    let refs: Vec<&Recorder> = cells.iter().map(|c| &c.recorder).collect();
    let out = Path::new("results/fig_wireformat.csv");
    match write_csv(out, &refs) {
        Ok(()) => println!("\n  series written to {}", out.display()),
        Err(e) => println!("\n  (csv not written: {e})"),
    }
}
