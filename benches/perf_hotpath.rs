//! Whole-stack hot-path microbenchmarks — the §Perf measurement harness.
//!
//! L3: fastest-k selection, master-iteration throughput, event queue,
//! sweep-executor fan-out, and large-d rounds at
//! `intra_jobs ∈ {1, 4, all}` (the intra-round fork–join speedup with
//! its byte-identical trajectory). L3↔RT (with `--features pjrt`):
//! PJRT execute latency (persistent-buffer vs literal upload).
//! L1-analog: native fused partial gradient (the Rust mirror of the
//! Pallas kernel's single-pass structure) and the column-panel
//! blocked `gemv_t` against its row-walk reference.
//!
//! Besides the text report, every timed entry lands in
//! `results/BENCH_hotpath.json` (name, median, p10/p90, mean, samples) —
//! the machine-readable perf trajectory CI and future optimisation PRs
//! diff against. `--jobs` is deliberately ignored here: the sweep
//! fan-out section times the fixed pair jobs=1 vs jobs=0 so its two
//! entries stay comparable across runs.
//!
//! Run: `cargo bench --bench perf_hotpath [-- --smoke]
//! [--baseline BENCH_hotpath.json] [--update-snapshot]`

use adasgd::bench_harness::{
    fmt_duration, print_baseline_deltas, section, BenchArgs, BenchResult,
    Bencher,
};
use adasgd::config::{
    DelaySpec, ExperimentConfig, PolicySpec, WorkloadSpec,
};
use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::engine::{
    EngineConfig, EngineCore, FastpathGather, RngStreams, RoundEngine,
};
use adasgd::grad::{GradBackend, NativeBackend};
use adasgd::linalg::{
    gemm, gemv, gemv_t_blocked, gemv_t_rowwalk, Matrix,
};
use adasgd::comm::{CommChannel, IngressModel, LinkModel, TopK};
use adasgd::master::{
    fastest_k_select, run_fastest_k, run_fastest_k_comm_traced, MasterConfig,
};
use adasgd::model::LinRegProblem;
use adasgd::policy::FixedK;
use adasgd::rng::{Pcg64, Rng};
use adasgd::sim::EventQueue;
use adasgd::stats::{ClassOrderSampler, OrderStatSampler};
use adasgd::straggler::ExponentialDelays;
use adasgd::sweep::{RunSpec, SweepExecutor};

/// Print an entry's one-line summary and keep it for the JSON report.
fn emit(report: &mut Vec<BenchResult>, r: BenchResult) {
    println!("{}", r.summary());
    report.push(r);
}

/// A tiny but non-trivial experiment for the executor fan-out entry.
fn sweep_spec(i: usize, iters: u64) -> RunSpec {
    RunSpec::from_config(i, ExperimentConfig {
        label: format!("hotpath-cell{i}"),
        n: 10,
        eta: 1e-3,
        max_iterations: iters,
        max_time: 0.0,
        seed: i as u64,
        record_stride: 1_000_000, // no eval in the timed loop
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 5 },
        workload: WorkloadSpec::LinReg { m: 200, d: 10 },
        comm: Default::default(),
        coding: None,
        jobs: 0,
        intra_jobs: 1,
        trace: None,
        fastpath: false,
    })
}

/// Synthetic million-shard backend for the fastpath entry: the gradient
/// is an O(d) function of `(shard, w)`, so the entry prices the round
/// mechanics (arrival sampling, identity selection, transmit and
/// accumulate) rather than dataset construction — a million real one-row
/// shards would measure the allocator instead of the engine.
struct SyntheticRoundBackend {
    n: usize,
    d: usize,
}

impl GradBackend for SyntheticRoundBackend {
    fn partial_grad(&mut self, shard: usize, w: &[f32], out: &mut [f32]) {
        let s = (shard % 251) as f32 * 1e-4;
        for (o, wv) in out.iter_mut().zip(w) {
            *o = 0.5 * wv + s;
        }
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn n_shards(&self) -> usize {
        self.n
    }

    fn name(&self) -> &'static str {
        "synthetic-round"
    }
}

fn main() {
    let args = BenchArgs::from_env();
    let mut report: Vec<BenchResult> = Vec::new();
    let micro = if args.smoke {
        Bencher { warmup_iters: 5, samples: 8, iters_per_sample: 10 }
    } else {
        Bencher::micro()
    };
    let ds = SyntheticDataset::generate(SyntheticConfig::default(), 0);
    let shards = Shards::partition(&ds, 50);

    section("L3 — fastest-k selection (n=50)");
    let mut rng = Pcg64::seed(1);
    let delays: Vec<f64> = (0..50).map(|_| rng.next_f64()).collect();
    let mut idx = Vec::with_capacity(50);
    for k in [1usize, 10, 25, 49, 50] {
        let r = micro.run(&format!("select k={k} of 50"), || {
            std::hint::black_box(fastest_k_select(&delays, k, &mut idx));
        });
        emit(&mut report, r);
    }

    section("L3 — event queue (async engine core)");
    let r = micro.run("schedule+pop 1000 events", || {
        let mut q = EventQueue::new();
        for i in 0..1000 {
            q.schedule_at((i * 7 % 1000) as f64, i);
        }
        while q.pop().is_some() {}
    });
    emit(&mut report, r);

    section("native kernels (Rust mirror of the Pallas structure)");
    let x40 = shards.x[0].clone();
    let w: Vec<f32> = (0..100).map(|i| i as f32 * 0.1).collect();
    let mut out = vec![0.0f32; 100];
    let mut backend = NativeBackend::new(shards.clone());
    let r = micro.run("partial_grad shard (s=40, d=100)", || {
        backend.partial_grad(0, &w, &mut out);
        std::hint::black_box(&out);
    });
    emit(&mut report, r);
    let mut resid = vec![0.0f32; 40];
    let r = micro.run("gemv 40x100", || {
        gemv(1.0, &x40, &w, 0.0, &mut resid);
        std::hint::black_box(&resid);
    });
    emit(&mut report, r);
    let a = Matrix::zeros(256, 256);
    let b = Matrix::zeros(256, 256);
    let mut c = Matrix::zeros(256, 256);
    let slow = Bencher { warmup_iters: 2, samples: 10, iters_per_sample: 3 };
    let r = slow.run("gemm 256^3 (setup path)", || {
        gemm(1.0, &a, &b, 0.0, &mut c);
        std::hint::black_box(&c);
    });
    let flops = 2.0 * 256f64.powi(3);
    println!(
        "{}   ({:.2} GFLOP/s)",
        r.summary(),
        flops / r.median() / 1e9
    );
    report.push(r);

    section("gemv_t — column-panel blocking vs row-walk");
    // The acceptance pair: at the fig-2 shard shape (40x100 — one
    // panel) blocking must cost nothing, and at a panel-spanning d the
    // blocked walk keeps the y panel cache-resident across rows. Both
    // paths are bitwise-identical; this only prices the loop order.
    let mut krng = Pcg64::seed(17);
    let mut fill = |m: &mut Matrix| {
        for v in m.as_mut_slice() {
            *v = krng.next_f64() as f32 - 0.5;
        }
    };
    let mut x_fig2 = Matrix::zeros(40, 100);
    fill(&mut x_fig2);
    let mut x_wide = Matrix::zeros(40, 8192);
    fill(&mut x_wide);
    let r40: Vec<f32> = (0..40).map(|i| (i as f32) * 0.07 - 1.0).collect();
    let mut yt = vec![0.0f32; 8192];
    for (shape, x_t, dlen) in
        [("40x100 (fig-2 shard)", &x_fig2, 100usize), ("40x8192", &x_wide, 8192)]
    {
        let r = micro.run(&format!("gemv_t {shape} row-walk"), || {
            gemv_t_rowwalk(0.025, x_t, &r40, 0.0, &mut yt[..dlen]);
            std::hint::black_box(&yt);
        });
        emit(&mut report, r);
        let r = micro.run(&format!("gemv_t {shape} blocked"), || {
            gemv_t_blocked(0.025, x_t, &r40, 0.0, &mut yt[..dlen]);
            std::hint::black_box(&yt);
        });
        emit(&mut report, r);
    }

    section("intra-round parallelism — large-d fastest-k rounds");
    // The tentpole pair: identical rounds (same seed, byte-identical
    // trajectory) at intra_jobs = 1 / 4 / all-cores. The k responders'
    // partial gradients land in per-responder arena slices in parallel
    // and reduce in fixed responder order; merge/apply loops split into
    // fixed column blocks. d is large enough that one round is kernel-
    // dominated, which is the regime intra_jobs exists for.
    let em = ExponentialDelays::new(1.0);
    let big_d = 32_768usize;
    let big = SyntheticDataset::generate(
        SyntheticConfig { m: 128, d: big_d, ..Default::default() },
        11,
    );
    let big_shards = Shards::partition(&big, 8);
    let big_rounds: u64 = if args.smoke { 5 } else { 30 };
    let w0_big = vec![0.0f32; big_d];
    let bi = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 };
    for (tag, ij) in [
        ("intra_jobs=1", 1usize),
        ("intra_jobs=4", 4),
        ("intra_jobs=0 (all cores)", 0),
    ] {
        let cfg = MasterConfig {
            eta: 1e-4,
            momentum: 0.0,
            max_iterations: big_rounds,
            max_time: 0.0,
            seed: 5,
            record_stride: 1_000_000, // no eval in the timed loop
            intra_jobs: ij,
        };
        // Construct outside the timed closure: cloning the 16 MiB
        // dataset would otherwise dilute the kernel speedup.
        let mut backend = NativeBackend::new(big_shards.clone());
        let r = bi.run(
            &format!("{big_rounds} rounds @ n=8 k=4 d=32768, {tag}"),
            || {
                let mut policy = FixedK::new(4);
                let run = run_fastest_k(
                    &mut backend,
                    &em,
                    &mut policy,
                    &w0_big,
                    &cfg,
                    &mut |_w| 0.0,
                );
                std::hint::black_box(run.iterations);
            },
        );
        println!(
            "{}   ({} per round)",
            r.summary(),
            fmt_duration(r.median() / big_rounds as f64)
        );
        report.push(r);
    }

    section("master loop end-to-end (native, n=50, fig-2 shapes)");
    let problem = LinRegProblem::new(&ds);
    let loop_iters: u64 = if args.smoke { 200 } else { 2000 };
    for k in [10usize, 40] {
        let b = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 };
        let r = b.run(&format!("{loop_iters} iterations @ k={k}"), || {
            let mut backend = NativeBackend::new(shards.clone());
            let mut policy = FixedK::new(k);
            let cfg = MasterConfig {
                eta: 5e-4,
                momentum: 0.0,
                max_iterations: loop_iters,
                max_time: 0.0,
                seed: 3,
                record_stride: 1_000_000, // no eval in the timed loop
                intra_jobs: 1,
            };
            let run = run_fastest_k(
                &mut backend,
                &em,
                &mut policy,
                &vec![0.0f32; 100],
                &cfg,
                &mut |w| problem.error(w),
            );
            std::hint::black_box(run.iterations);
        });
        println!(
            "{}   ({} per iteration)",
            r.summary(),
            fmt_duration(r.median() / loop_iters as f64)
        );
        report.push(r);
    }

    section("sweep executor — parallel experiment fan-out (8 specs)");
    // The sweep layer's hot path: fan 8 independent tiny experiments out
    // and reassemble in order. jobs=1 is the sequential reference; the
    // parallel entry shows the thread-pool speedup on the same grid.
    let cell_iters: u64 = if args.smoke { 200 } else { 1000 };
    let specs: Vec<RunSpec> =
        (0..8).map(|i| sweep_spec(i, cell_iters)).collect();
    let b = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 };
    for (tag, jobs) in [("jobs=1", 1usize), ("jobs=0 (all cores)", 0)] {
        let exec = SweepExecutor::new(jobs);
        let specs = specs.clone();
        let name = format!("sweep 8x{cell_iters}-iter specs, {tag}");
        let r = b.run(&name, move || {
            let outs = exec.run(&specs).expect("hotpath sweep");
            std::hint::black_box(outs.len());
        });
        emit(&mut report, r);
    }

    section("event trace — record overhead + binary codec (n=50)");
    // Observability must be near-free: the tracing-off entry is the
    // baseline the tracing-on entry is diffed against (same seed, same
    // trajectory — the trace is the only difference), and the codec
    // entries price (de)serializing the recorded event stream.
    let trace_iters: u64 = if args.smoke { 100 } else { 1000 };
    let trace_cfg = MasterConfig {
        eta: 5e-4,
        momentum: 0.0,
        max_iterations: trace_iters,
        max_time: 0.0,
        seed: 3,
        record_stride: 1_000_000, // no eval in the timed loop
        intra_jobs: 1,
    };
    let bt = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 };
    for (tag, on) in [("off", false), ("on", true)] {
        let r = bt.run(
            &format!("{trace_iters}-iter run @ k=10, tracing {tag}"),
            || {
                let mut backend = NativeBackend::new(shards.clone());
                let mut policy = FixedK::new(10);
                let mut channel = CommChannel::dense(50);
                let run = run_fastest_k_comm_traced(
                    &mut backend,
                    &em,
                    &mut policy,
                    &mut channel,
                    &vec![0.0f32; 100],
                    &trace_cfg,
                    &mut |w| problem.error(w),
                    on,
                );
                std::hint::black_box(run.iterations);
            },
        );
        emit(&mut report, r);
    }
    // One untimed traced run yields the event stream the codec entries
    // chew on.
    let trace = {
        let mut backend = NativeBackend::new(shards.clone());
        let mut policy = FixedK::new(10);
        let mut channel = CommChannel::dense(50);
        run_fastest_k_comm_traced(
            &mut backend,
            &em,
            &mut policy,
            &mut channel,
            &vec![0.0f32; 100],
            &trace_cfg,
            &mut |w| problem.error(w),
            true,
        )
        .trace
        .expect("traced run must carry its trace")
    };
    let encoded = trace.to_bytes();
    println!(
        "  ({} events, {} bytes encoded)",
        trace.len(),
        encoded.len()
    );
    let bc = Bencher { warmup_iters: 2, samples: 10, iters_per_sample: 3 };
    let r = bc.run("trace encode (to_bytes)", || {
        std::hint::black_box(trace.to_bytes().len());
    });
    emit(&mut report, r);
    let r = bc.run("trace decode (from_bytes)", || {
        let t = adasgd::trace::Trace::from_bytes(&encoded)
            .expect("round-trip decode");
        std::hint::black_box(t.len());
    });
    emit(&mut report, r);

    section("engine fastpath — order-statistics rounds (n=10^6, k=10^3)");
    // The tentpole measurement: full synchronous fastest-k rounds at a
    // million workers. A fastpath round is O(k + k·d): sample the k
    // fastest arrival times directly (Rényi spacings), draw k worker
    // identities, gather exactly those k gradients. The exhaustive
    // gather's per-round core at the same scale — draw all n delays,
    // select the k fastest — is timed separately below; a full
    // exhaustive *engine* round at n = 10^6 would additionally run a
    // million partial_grad + transmit calls per round, which is exactly
    // the cost the fastpath exists to avoid and is not benchable inside
    // the smoke budget.
    const HUGE_N: usize = 1_000_000;
    const HUGE_K: usize = 1_000;
    let d_huge = 8usize;
    let fp_rounds: u64 = if args.smoke { 20 } else { 200 };
    let w0_huge = vec![0.1f32; d_huge];
    let bf = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 };
    let r = bf.run(
        &format!("fastpath {fp_rounds} rounds @ n=10^6 k=10^3 (+setup)"),
        || {
            let mut backend =
                SyntheticRoundBackend { n: HUGE_N, d: d_huge };
            let mut policy = FixedK::new(HUGE_K);
            let sampler = OrderStatSampler::exponential(HUGE_N, 1.0);
            let mut channel = CommChannel::dense(HUGE_N);
            let mut eval = |_w: &[f32]| 0.0;
            let cfg = EngineConfig {
                eta: 1e-3,
                momentum: 0.0,
                max_steps: fp_rounds,
                max_time: 0.0,
                seed: 7,
                record_stride: 1_000_000, // no eval in the timed loop
                intra_jobs: 1,
            };
            let core = EngineCore::new(
                "hotpath-fastpath",
                &mut channel,
                &em,
                &mut eval,
                &w0_huge,
                cfg,
                RngStreams::sync(7),
            );
            let mut gather = FastpathGather::iid(
                &mut backend,
                &mut policy,
                sampler,
                7,
            );
            let run = RoundEngine::new(core).run(&mut gather);
            std::hint::black_box(run.steps);
        },
    );
    println!(
        "{}   ({} per round incl. setup)",
        r.summary(),
        fmt_duration(r.median() / fp_rounds as f64)
    );
    report.push(r);
    // The priced heterogeneous round at the same scale: a 10^5-worker
    // slow class (10x slower delays AND a 10x slower uplink), a TopK
    // uplink priced per byte, and a finite FIFO ingress chain. The
    // downlink stays free — broadcast metering is the one O(n)-per-round
    // piece of the priced stack, so pricing it would measure the meter,
    // not the merge. Per round this is O(k · classes): two-way merge of
    // the per-class order-statistic streams (+ per-class uplink
    // constants), then the O(k) FIFO completion chain.
    const HUGE_SLOW: usize = 100_000;
    let r = bf.run(
        &format!(
            "fastpath {fp_rounds} rounds @ n=10^6 k=10^3, slow class + \
             priced TopK uplink + FIFO ingress (+setup)"
        ),
        || {
            let mut backend =
                SyntheticRoundBackend { n: HUGE_N, d: d_huge };
            let mut policy = FixedK::new(HUGE_K);
            // uniform_with_slow slows the LAST ids' uplink; keep the
            // same ids persistently delay-slow so the classes coincide.
            let link = LinkModel::uniform_with_slow(
                HUGE_N, 4096.0, 1e-4, HUGE_SLOW, 10.0,
            );
            let mut channel =
                CommChannel::new(Box::new(TopK::new(0.5)), link, false)
                    .with_ingress(IngressModel::new(2.0e7));
            let msg = channel.message_bytes(d_huge);
            let up_fast = channel.link_upload_delay(0, msg);
            let up_slow = channel.link_upload_delay(HUGE_N - 1, msg);
            let sampler = ClassOrderSampler::new(vec![
                (
                    OrderStatSampler::exponential(HUGE_N - HUGE_SLOW, 1.0),
                    up_fast,
                ),
                (OrderStatSampler::exponential(HUGE_SLOW, 0.1), up_slow),
            ]);
            let members: Vec<Vec<u32>> = vec![
                (0..(HUGE_N - HUGE_SLOW) as u32).collect(),
                ((HUGE_N - HUGE_SLOW) as u32..HUGE_N as u32).collect(),
            ];
            let mut eval = |_w: &[f32]| 0.0;
            let cfg = EngineConfig {
                eta: 1e-3,
                momentum: 0.0,
                max_steps: fp_rounds,
                max_time: 0.0,
                seed: 7,
                record_stride: 1_000_000, // no eval in the timed loop
                intra_jobs: 1,
            };
            let core = EngineCore::new(
                "hotpath-fastpath-het",
                &mut channel,
                &em,
                &mut eval,
                &w0_huge,
                cfg,
                RngStreams::sync(7),
            );
            let mut gather = FastpathGather::new(
                &mut backend,
                &mut policy,
                sampler,
                members,
                7,
            );
            let run = RoundEngine::new(core).run(&mut gather);
            std::hint::black_box((run.steps, run.bytes_sent));
        },
    );
    println!(
        "{}   ({} per round incl. setup)",
        r.summary(),
        fmt_duration(r.median() / fp_rounds as f64)
    );
    report.push(r);
    // What the exhaustive gather pays per round at the same scale,
    // before any gradient work: materialize all 10^6 delay draws and
    // select the 10^3 fastest.
    let mut xrng = Pcg64::seed(9);
    let mut all_delays = vec![0.0f64; HUGE_N];
    let mut idx_huge = Vec::with_capacity(HUGE_K);
    let r = bf.run("exhaustive core: draw 10^6 delays + select 10^3", || {
        for dly in all_delays.iter_mut() {
            *dly = -xrng.next_f64_open().ln();
        }
        std::hint::black_box(fastest_k_select(
            &all_delays,
            HUGE_K,
            &mut idx_huge,
        ));
    });
    emit(&mut report, r);

    pjrt_section(&shards, &w, &mut out, &mut report);

    let json = std::path::Path::new("results/BENCH_hotpath.json");
    match adasgd::bench_harness::write_json_report(json, &report) {
        Ok(()) => println!(
            "\n{} entries written to {}",
            report.len(),
            json.display()
        ),
        Err(e) => println!("\n(json report not written: {e})"),
    }
    if args.update_snapshot {
        // The committed perf-trajectory snapshot at the repo root —
        // rewritten in place so `--baseline BENCH_hotpath.json` diffs
        // future runs against this one.
        let snap = std::path::Path::new("BENCH_hotpath.json");
        match adasgd::bench_harness::write_json_report(snap, &report) {
            Ok(()) => println!(
                "snapshot {} rewritten with {} entries",
                snap.display(),
                report.len()
            ),
            Err(e) => println!("(snapshot not updated: {e})"),
        }
    }
    if let Some(base) = &args.baseline {
        print_baseline_deltas(std::path::Path::new(base), &report);
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_section(
    _shards: &Shards,
    _w: &[f32],
    _out: &mut [f32],
    _report: &mut Vec<BenchResult>,
) {
    section("PJRT runtime");
    println!("  skipped: build with --features pjrt (and real xla bindings)");
}

#[cfg(feature = "pjrt")]
fn pjrt_section(
    shards: &Shards,
    w: &[f32],
    out: &mut [f32],
    report: &mut Vec<BenchResult>,
) {
    use adasgd::runtime::{Runtime, XlaBackend};
    section("PJRT runtime (requires `make artifacts`)");
    match Runtime::open_default() {
        Err(e) => println!("  skipped: {e}"),
        Ok(rt) => {
            let mut xla = XlaBackend::new(&rt, shards).expect("backend");
            let b =
                Bencher { warmup_iters: 20, samples: 15, iters_per_sample: 50 };
            let r = b.run("pjrt partial_grad (persistent shard bufs)", || {
                xla.partial_grad(0, w, out);
                std::hint::black_box(&out);
            });
            println!("{}", r.summary());
            report.push(r);
            let mut all_out = vec![0.0f32; 50 * 100];
            let b2 =
                Bencher { warmup_iters: 5, samples: 15, iters_per_sample: 10 };
            if xla.all_grads(w, &mut all_out) {
                let r = b2.run("pjrt ALL 50 shard grads (batched artifact)", || {
                    xla.all_grads(w, &mut all_out);
                    std::hint::black_box(&all_out);
                });
                println!("{}", r.summary());
                report.push(r);
            }
            let exe = rt.load("linreg_grad_s40_d100").expect("load");
            let xs = shards.x[0].as_slice();
            let ys = &shards.y[0];
            let r = b.run("pjrt partial_grad (full literal upload)", || {
                let outs = exe
                    .run(&[
                        adasgd::runtime::Arg::F32(xs),
                        adasgd::runtime::Arg::F32(ys),
                        adasgd::runtime::Arg::F32(w),
                    ])
                    .expect("exec");
                std::hint::black_box(outs.len());
            });
            println!("{}", r.summary());
            report.push(r);
        }
    }
}
