//! Whole-stack hot-path microbenchmarks — the §Perf measurement harness.
//!
//! L3: fastest-k selection, master-iteration throughput, event queue.
//! L3↔RT: PJRT execute latency (persistent-buffer vs literal upload).
//! L1-analog: native fused partial gradient (the Rust mirror of the
//! Pallas kernel's single-pass structure).
//!
//! Run: `cargo bench --bench perf_hotpath`

use adasgd::bench_harness::{fmt_duration, section, Bencher};
use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::grad::{GradBackend, NativeBackend};
use adasgd::linalg::{gemm, gemv, Matrix};
use adasgd::master::{fastest_k_select, run_fastest_k, MasterConfig};
use adasgd::model::LinRegProblem;
use adasgd::policy::FixedK;
use adasgd::rng::{Pcg64, Rng};
use adasgd::runtime::{Runtime, XlaBackend};
use adasgd::sim::EventQueue;
use adasgd::straggler::ExponentialDelays;

fn main() {
    let micro = Bencher::micro();
    let ds = SyntheticDataset::generate(SyntheticConfig::default(), 0);
    let shards = Shards::partition(&ds, 50);

    section("L3 — fastest-k selection (n=50)");
    let mut rng = Pcg64::seed(1);
    let delays: Vec<f64> = (0..50).map(|_| rng.next_f64()).collect();
    let mut idx = Vec::with_capacity(50);
    for k in [1usize, 10, 25, 49, 50] {
        println!(
            "{}",
            micro
                .run(&format!("select k={k} of 50"), || {
                    std::hint::black_box(fastest_k_select(
                        &delays, k, &mut idx,
                    ));
                })
                .summary()
        );
    }

    section("L3 — event queue (async engine core)");
    println!(
        "{}",
        micro
            .run("schedule+pop 1000 events", || {
                let mut q = EventQueue::new();
                for i in 0..1000 {
                    q.schedule_at((i * 7 % 1000) as f64, i);
                }
                while q.pop().is_some() {}
            })
            .summary()
    );

    section("native kernels (Rust mirror of the Pallas structure)");
    let x40 = shards.x[0].clone();
    let w: Vec<f32> = (0..100).map(|i| i as f32 * 0.1).collect();
    let mut out = vec![0.0f32; 100];
    let mut backend = NativeBackend::new(shards.clone());
    println!(
        "{}",
        micro
            .run("partial_grad shard (s=40, d=100)", || {
                backend.partial_grad(0, &w, &mut out);
                std::hint::black_box(&out);
            })
            .summary()
    );
    let mut resid = vec![0.0f32; 40];
    println!(
        "{}",
        micro
            .run("gemv 40x100", || {
                gemv(1.0, &x40, &w, 0.0, &mut resid);
                std::hint::black_box(&resid);
            })
            .summary()
    );
    let a = Matrix::zeros(256, 256);
    let b = Matrix::zeros(256, 256);
    let mut c = Matrix::zeros(256, 256);
    let slow = Bencher { warmup_iters: 2, samples: 10, iters_per_sample: 3 };
    let r = slow.run("gemm 256^3 (setup path)", || {
        gemm(1.0, &a, &b, 0.0, &mut c);
        std::hint::black_box(&c);
    });
    let flops = 2.0 * 256f64.powi(3);
    println!(
        "{}   ({:.2} GFLOP/s)",
        r.summary(),
        flops / r.median() / 1e9
    );

    section("master loop end-to-end (native, n=50, fig-2 shapes)");
    let problem = LinRegProblem::new(&ds);
    let em = ExponentialDelays::new(1.0);
    for k in [10usize, 40] {
        let b = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 };
        let iters = 2000u64;
        let r = b.run(&format!("2000 iterations @ k={k}"), || {
            let mut backend = NativeBackend::new(shards.clone());
            let mut policy = FixedK::new(k);
            let cfg = MasterConfig {
                eta: 5e-4,
                momentum: 0.0,
                max_iterations: iters,
                max_time: 0.0,
                seed: 3,
                record_stride: 1_000_000, // no eval in the timed loop
            };
            let run = run_fastest_k(
                &mut backend,
                &em,
                &mut policy,
                &vec![0.0f32; 100],
                &cfg,
                &mut |w| problem.error(w),
            );
            std::hint::black_box(run.iterations);
        });
        println!(
            "{}   ({} per iteration)",
            r.summary(),
            fmt_duration(r.median() / iters as f64)
        );
    }

    section("PJRT runtime (requires `make artifacts`)");
    match Runtime::open_default() {
        Err(e) => println!("  skipped: {e}"),
        Ok(rt) => {
            let mut xla = XlaBackend::new(&rt, &shards).expect("backend");
            let b = Bencher { warmup_iters: 20, samples: 15, iters_per_sample: 50 };
            println!(
                "{}",
                b.run("pjrt partial_grad (persistent shard bufs)", || {
                    xla.partial_grad(0, &w, &mut out);
                    std::hint::black_box(&out);
                })
                .summary()
            );
            let mut all_out = vec![0.0f32; 50 * 100];
            let b2 = Bencher { warmup_iters: 5, samples: 15, iters_per_sample: 10 };
            if xla.all_grads(&w, &mut all_out) {
                println!(
                    "{}",
                    b2.run("pjrt ALL 50 shard grads (batched artifact)", || {
                        xla.all_grads(&w, &mut all_out);
                        std::hint::black_box(&all_out);
                    })
                    .summary()
                );
            }
            let exe = rt.load("linreg_grad_s40_d100").expect("load");
            let xs = shards.x[0].as_slice();
            let ys = &shards.y[0];
            println!(
                "{}",
                b.run("pjrt partial_grad (full literal upload)", || {
                    let outs = exe
                        .run(&[
                            adasgd::runtime::Arg::F32(xs),
                            adasgd::runtime::Arg::F32(ys),
                            adasgd::runtime::Arg::F32(&w),
                        ])
                        .expect("exec");
                    std::hint::black_box(outs.len());
                })
                .summary()
            );
        }
    }
}
