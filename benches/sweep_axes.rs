//! Axis builders shared by the figure benches.
//!
//! Bench targets are separate crates, so each bench that needs one of
//! these includes this file with `#[path = "sweep_axes.rs"] mod …` —
//! one definition for scenarios that must stay comparable across
//! figures (same capacities, same labels the result tables key on).

use adasgd::sweep::{edit, CfgEdit};

/// The shared master-ingress axis: unlimited (independent uploads) vs a
/// 4 kB/t master NIC the accepted uploads serialize through. Used by
/// both the bidirectional and coding sweeps so their "ing4k" rows model
/// the same NIC.
pub fn ingress_axis() -> Vec<(String, CfgEdit)> {
    vec![
        ("ing-inf".into(), edit(|c| c.comm.ingress_bw = 0.0)),
        ("ing4k".into(), edit(|c| c.comm.ingress_bw = 4000.0)),
    ]
}
