//! Deadline training — the paper's §I motivation: "particularly useful in
//! applications where SGD is run with a deadline, since the learning
//! algorithm would achieve the best accuracy within any time restriction."
//!
//! For a sweep of deadlines T the example reports the error each policy
//! achieves *by* T: adaptive fastest-k should be at (or near) the best
//! fixed k for every T simultaneously — no single fixed k can be.
//!
//! Run: `cargo run --release --example deadline_training`

use adasgd::prelude::*;

fn run_policy(
    ds: &SyntheticDataset,
    problem: &LinRegProblem,
    policy: &mut dyn KPolicy,
    max_time: f64,
) -> Recorder {
    let mut backend = NativeBackend::new(Shards::partition(ds, 50));
    let delays = ExponentialDelays::new(1.0);
    let cfg = MasterConfig {
        eta: 5e-4,
        momentum: 0.0,
        max_iterations: 1_000_000,
        max_time,
        seed: 1,
        record_stride: 20,
        intra_jobs: 1,
    };
    run_fastest_k(
        &mut backend,
        &delays,
        policy,
        &vec![0.0f32; problem.d()],
        &cfg,
        &mut |w| problem.error(w),
    )
    .recorder
}

fn main() {
    let ds = SyntheticDataset::generate(SyntheticConfig::default(), 1);
    let problem = LinRegProblem::new(&ds);
    let horizon = 6000.0;

    println!("running policies to t = {horizon} ...");
    let mut runs: Vec<Recorder> = Vec::new();
    for k in [10usize, 20, 40] {
        let mut p = FixedK::new(k);
        runs.push(run_policy(&ds, &problem, &mut p, horizon));
    }
    let mut adaptive = AdaptivePflug::new(50, PflugParams::default());
    runs.push(run_policy(&ds, &problem, &mut adaptive, horizon));

    let deadlines = [250.0, 500.0, 1000.0, 2000.0, 4000.0, 6000.0];
    println!("\nerror achieved by each deadline (lower is better):\n");
    print!("{:>10}", "deadline");
    for r in &runs {
        print!("  {:>18}", r.label.chars().take(18).collect::<String>());
    }
    println!();
    for &t in &deadlines {
        print!("{t:>10.0}");
        // Best error achieved at-or-before the deadline.
        for r in &runs {
            let best = r
                .samples()
                .iter()
                .take_while(|s| s.time <= t)
                .map(|s| s.error)
                .fold(f64::INFINITY, f64::min);
            print!("  {best:>18.4e}");
        }
        println!();
    }

    // Deadline regret: how much worse each policy is vs the per-deadline
    // oracle (the best policy for that specific deadline).
    println!("\nregret vs per-deadline oracle (1.0 = matches the best):");
    print!("{:>10}", "deadline");
    for r in &runs {
        print!("  {:>18}", r.label.chars().take(18).collect::<String>());
    }
    println!();
    for &t in &deadlines {
        let errs: Vec<f64> = runs
            .iter()
            .map(|r| {
                r.samples()
                    .iter()
                    .take_while(|s| s.time <= t)
                    .map(|s| s.error)
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let best = errs.iter().cloned().fold(f64::INFINITY, f64::min);
        print!("{t:>10.0}");
        for e in &errs {
            print!("  {:>18.2}", e / best);
        }
        println!();
    }
    println!(
        "\nThe adaptive column should track ~1.0 across ALL deadlines — \
         that is the error-runtime trade-off the paper optimizes."
    );
}
