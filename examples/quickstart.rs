//! Quickstart: the paper's headline experiment in ~30 lines.
//!
//! Trains linear regression on §V.A synthetic data with n = 50 simulated
//! workers under exp(1) response times, comparing non-adaptive fastest-k
//! (k = 10) against Algorithm 1 (adaptive k: 10 → 40).
//!
//! Run: `cargo run --release --example quickstart`

use adasgd::prelude::*;

fn main() {
    let n = 50;
    // Paper §V.A data: x ~ U{1..10}^d, w̄ ~ U{1..100}^d, y = <x,w̄> + N(0,1).
    let ds = SyntheticDataset::generate(SyntheticConfig::default(), 0);
    let problem = LinRegProblem::new(&ds);
    println!(
        "dataset: m={} d={}  F* = {:.4}  (noise floor)",
        problem.m(),
        problem.d(),
        problem.f_star
    );

    let delays = ExponentialDelays::new(1.0);
    let cfg = MasterConfig {
        eta: 5e-4,
        momentum: 0.0,
        max_iterations: 1_000_000,
        max_time: 3000.0,
        seed: 0,
        record_stride: 25,
        intra_jobs: 1,
    };
    let w0 = vec![0.0f32; problem.d()];

    // Non-adaptive baseline: fastest-10 of 50.
    let mut backend = NativeBackend::new(Shards::partition(&ds, n));
    let mut fixed = FixedK::new(10);
    let run_fixed = run_fastest_k(
        &mut backend, &delays, &mut fixed, &w0, &cfg,
        &mut |w| problem.error(w),
    );

    // Algorithm 1: adaptive fastest-k via the Pflug sign statistic.
    let mut backend = NativeBackend::new(Shards::partition(&ds, n));
    let mut adaptive = AdaptivePflug::new(n, PflugParams::default());
    let run_adaptive = run_fastest_k(
        &mut backend, &delays, &mut adaptive, &w0, &cfg,
        &mut |w| problem.error(w),
    );

    let plot = AsciiPlot::new("error vs wall-clock (log y)", 90, 22);
    println!(
        "{}",
        plot.render(&[&run_fixed.recorder, &run_adaptive.recorder])
    );
    println!(
        "fixed k=10   : {} iters, final error {:.3e}",
        run_fixed.iterations,
        run_fixed.recorder.last().unwrap().error
    );
    println!(
        "adaptive     : {} iters, final error {:.3e}",
        run_adaptive.iterations,
        run_adaptive.recorder.last().unwrap().error
    );
    for (j, t, k) in &run_adaptive.k_changes {
        println!("  switched to k={k} at iteration {j} (t = {t:.0})");
    }
    write_csv(
        std::path::Path::new("results/quickstart.csv"),
        &[&run_fixed.recorder, &run_adaptive.recorder],
    )
    .expect("write csv");
    println!("series written to results/quickstart.csv");
}
