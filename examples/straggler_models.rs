//! Straggler-model sensitivity — beyond the paper's iid exponential
//! assumption: does Algorithm 1 still beat fixed k when delays are
//! heavy-tailed (Pareto), sub-exponential (Weibull k>1), shifted, or
//! non-iid (persistent slow nodes)?
//!
//! Run: `cargo run --release --example straggler_models`

use adasgd::prelude::*;

fn min_error_under(
    ds: &SyntheticDataset,
    problem: &LinRegProblem,
    delays: &dyn DelayModel,
    policy: &mut dyn KPolicy,
    max_time: f64,
) -> (f64, u64) {
    let mut backend = NativeBackend::new(Shards::partition(ds, 50));
    let cfg = MasterConfig {
        eta: 5e-4,
        momentum: 0.0,
        max_iterations: 1_000_000,
        max_time,
        seed: 2,
        record_stride: 25,
        intra_jobs: 1,
    };
    let run = run_fastest_k(
        &mut backend,
        delays,
        policy,
        &vec![0.0f32; problem.d()],
        &cfg,
        &mut |w| problem.error(w),
    );
    (run.recorder.min_error().unwrap(), run.iterations)
}

fn main() {
    let ds = SyntheticDataset::generate(SyntheticConfig::default(), 2);
    let problem = LinRegProblem::new(&ds);

    let models: Vec<Box<dyn DelayModel>> = vec![
        Box::new(ExponentialDelays::new(1.0)),
        Box::new(ShiftedExponentialDelays::new(0.5, 2.0)),
        Box::new(ParetoDelays::new(0.5, 2.2)),
        Box::new(WeibullDelays::new(1.0, 0.7)),
        Box::new(BimodalDelays::new(1.0, 5, 8.0, 0.05)),
    ];
    // Give every model the same *mean-time* budget by normalizing to its
    // approximate per-iteration cost at k = 40.
    println!(
        "{:<42} {:>14} {:>14} {:>14} {:>8}",
        "delay model", "fixed k=10", "fixed k=40", "adaptive", "winner"
    );
    for model in &models {
        let os = OrderStats::monte_carlo(model.as_ref(), 50, 3000, 9);
        let budget = 2500.0 * os.mean(40) / 1.57; // scale vs exp(1)'s μ40
        let (e10, _) = min_error_under(
            &ds, &problem, model.as_ref(), &mut FixedK::new(10), budget,
        );
        let (e40, _) = min_error_under(
            &ds, &problem, model.as_ref(), &mut FixedK::new(40), budget,
        );
        let mut adaptive = AdaptivePflug::new(50, PflugParams::default());
        let (ea, iters) = min_error_under(
            &ds, &problem, model.as_ref(), &mut adaptive, budget,
        );
        let winner = if ea <= e10 && ea <= e40 {
            "adaptive"
        } else if e10 < e40 {
            "k=10"
        } else {
            "k=40"
        };
        println!(
            "{:<42} {:>14.4e} {:>14.4e} {:>14.4e} {:>8}  ({} iters)",
            model.name(),
            e10,
            e40,
            ea,
            winner,
            iters
        );
    }
    println!(
        "\nAdaptive should win (or tie) across models — the Pflug statistic \
         never looks at the delay distribution, only at gradient signs."
    );
}
