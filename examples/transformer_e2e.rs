//! END-TO-END DRIVER — trains a transformer LM for a few hundred steps
//! through the complete three-layer stack and logs the loss curve:
//!
//!   Pallas tiled matmul (L1)  →  JAX train-step graph (L2)
//!     →  HLO text artifact     →  Rust PJRT runtime
//!     →  fastest-k coordinator with Algorithm-1 adaptive k (L3)
//!
//! Data-parallel setup: each of the n simulated workers computes the LM
//! gradient of its own synthetic-corpus microbatch; the master waits for
//! the fastest k, averages, and applies. Response times are exp(1), so the
//! run exhibits exactly the straggler dynamics the paper studies — on a
//! real transformer workload rather than linear regression.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example transformer_e2e            (~300 steps)
//!   cargo run --release --example transformer_e2e -- 100     (custom)

use adasgd::master::{run_fastest_k, MasterConfig};
use adasgd::metrics::{write_csv, AsciiPlot};
use adasgd::policy::{AdaptivePflug, FixedK, PflugParams};
use adasgd::runtime::Runtime;
use adasgd::straggler::ExponentialDelays;
use adasgd::transformer::{TransformerBackend, TransformerSession};
use std::time::Instant;

fn main() {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let workers = 8usize;
    let tag = "tiny";

    let runtime = Runtime::open_default()
        .expect("artifacts missing — run `make artifacts` first");
    let session = TransformerSession::new(&runtime, tag, 0).expect("session");
    let params0 = session.init_params(0).expect("init");
    println!(
        "transformer '{tag}': {} parameters, {workers} data-parallel workers, {steps} steps",
        params0.len()
    );

    let delays = ExponentialDelays::new(1.0);
    let eval = TransformerBackend::new(&runtime, tag, workers, 0).expect("eval");
    let cfg = MasterConfig {
        eta: 0.05,
        momentum: 0.0,
        max_iterations: steps,
        max_time: 0.0,
        seed: 0,
        record_stride: (steps / 30).max(1),
        intra_jobs: 1,
    };

    // Baseline: wait for every worker (k = n) — the straggler-bound run.
    let start = Instant::now();
    let mut backend =
        TransformerBackend::new(&runtime, tag, workers, 0).expect("backend");
    let mut all = FixedK::new(workers);
    let run_all = run_fastest_k(
        &mut backend,
        &delays,
        &mut all,
        &params0,
        &cfg,
        &mut |p| eval.eval_loss(p).unwrap() as f64,
    );
    let wall_all = start.elapsed().as_secs_f64();

    // Adaptive fastest-k (Algorithm 1).
    let start = Instant::now();
    let mut backend =
        TransformerBackend::new(&runtime, tag, workers, 0).expect("backend");
    let mut adaptive = AdaptivePflug::new(
        workers,
        PflugParams { k0: 2, step: 2, thresh: 5, burnin: 20, k_max: workers },
    );
    let run_adaptive = run_fastest_k(
        &mut backend,
        &delays,
        &mut adaptive,
        &params0,
        &cfg,
        &mut |p| eval.eval_loss(p).unwrap() as f64,
    );
    let wall_adaptive = start.elapsed().as_secs_f64();

    let plot = AsciiPlot::new("LM loss vs virtual wall-clock (log y)", 90, 20);
    println!("{}", plot.render(&[&run_all.recorder, &run_adaptive.recorder]));

    let a0 = run_all.recorder.samples()[0].error;
    let a1 = run_all.recorder.last().unwrap().error;
    let b1 = run_adaptive.recorder.last().unwrap().error;
    println!(
        "k=n   : loss {a0:.4} -> {a1:.4} in virtual t = {:.1} ({wall_all:.1}s real)",
        run_all.total_time
    );
    println!(
        "adapt : loss {a0:.4} -> {b1:.4} in virtual t = {:.1} ({wall_adaptive:.1}s real)",
        run_adaptive.total_time
    );
    println!(
        "adaptive reached its final loss using {:.1}% of k=n's virtual time per step",
        100.0 * (run_adaptive.total_time / run_adaptive.iterations as f64)
            / (run_all.total_time / run_all.iterations as f64)
    );
    for (j, t, k) in &run_adaptive.k_changes {
        println!("  k -> {k} at step {j} (t = {t:.1})");
    }
    write_csv(
        std::path::Path::new("results/transformer_e2e.csv"),
        &[&run_all.recorder, &run_adaptive.recorder],
    )
    .expect("csv");
    println!("loss curves written to results/transformer_e2e.csv");
    assert!(
        b1 < a0 - 0.3,
        "e2e training must show a real loss drop ({a0:.3} -> {b1:.3})"
    );
}
