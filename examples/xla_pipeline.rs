//! Production-path demo: the paper's Fig-2 workload with *every* numeric
//! step running through AOT artifacts — per-shard gradients via the fused
//! Pallas kernel, loss evaluation via the loss artifact, and the fastest-k
//! masked-average + SGD apply via the `apply_update` artifact. The Rust
//! side never computes a gradient natively here.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example xla_pipeline

use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::master::fastest_k_select;
use adasgd::model::LinRegProblem;
use adasgd::rng::Pcg64;
use adasgd::runtime::{Runtime, XlaApplyUpdate, XlaBackend, XlaLossEval};
use adasgd::straggler::{DelayModel, ExponentialDelays};
use std::time::Instant;

fn main() {
    let (n, d, eta) = (50usize, 100usize, 5e-4f32);
    let ds = SyntheticDataset::generate(SyntheticConfig::default(), 0);
    let problem = LinRegProblem::new(&ds); // native, for F* reference only
    let shards = Shards::partition(&ds, n);

    let runtime = Runtime::open_default()
        .expect("artifacts missing — run `make artifacts` first");
    let mut grads = XlaBackend::new(&runtime, &shards).expect("grad artifact");
    let loss_eval = XlaLossEval::new(&runtime, &ds.x, &ds.y).expect("loss");
    let apply = XlaApplyUpdate::new(&runtime, n, d).expect("apply");

    let delays = ExponentialDelays::new(1.0);
    let mut rng = Pcg64::seed_stream(0, 0xFA57);
    let mut w = vec![0.0f32; d];
    let mut g_stack = vec![0.0f32; n * d];
    let mut delay_buf = vec![0.0f64; n];
    let mut idx = Vec::with_capacity(n);
    let k = 20usize;
    let iters = 400u64;

    println!("fastest-{k} of {n}, all compute through PJRT artifacts");
    let f0 = loss_eval.loss(&w).expect("loss") - problem.f_star;
    println!("initial error: {f0:.4e}");

    let start = Instant::now();
    let mut t_virtual = 0.0;
    for j in 0..iters {
        for (i, slot) in delay_buf.iter_mut().enumerate() {
            *slot = delays.sample(j, i, &mut rng);
        }
        let (x_k, _) = fastest_k_select(&delay_buf, k, &mut idx);
        t_virtual += x_k;

        // Gradient stack: fastest k rows populated, stragglers zeroed —
        // exactly the masked layout the apply_update kernel expects.
        g_stack.iter_mut().for_each(|v| *v = 0.0);
        for (row, &worker) in idx[..k].iter().enumerate() {
            let dst = &mut g_stack[row * d..(row + 1) * d];
            grads
                .try_partial_grad(worker, &w, dst)
                .expect("pjrt gradient");
        }
        apply.apply(&mut w, &g_stack, eta / k as f32).expect("pjrt apply");

        if (j + 1) % 100 == 0 {
            let err = loss_eval.loss(&w).expect("loss") - problem.f_star;
            println!(
                "iter {:>4}: error {err:.4e}  (virtual t = {t_virtual:.0})",
                j + 1
            );
        }
    }
    let wall = start.elapsed().as_secs_f64();
    let e_final = loss_eval.loss(&w).expect("loss") - problem.f_star;
    println!(
        "\n{iters} iterations in {wall:.2}s real ({:.2} ms/iter), final error {e_final:.4e}",
        1e3 * wall / iters as f64
    );
    assert!(e_final < f0 * 1e-3, "pipeline failed to train");
}
