"""AOT export: lower every L2 entry point to HLO *text* + a manifest.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Artifacts written to ``--out-dir`` (default ``../artifacts``):

  linreg_grad_s{s}_d{d}.hlo.txt      (x (s,d), y (s,1), w (d,1)) -> (g (d,1),)
  linreg_loss_m{m}_d{d}.hlo.txt      (x (m,d), y (m,1), w (d,1)) -> (F,)
  apply_update_n{n}_d{d}.hlo.txt     (w (1,d), G (n,d), scale (1,1)) -> (w',)
  transformer_grad_{tag}.hlo.txt     (params (P,), tokens (B,S+1) i32)
                                     -> (grad (P,), loss)
  transformer_step_{tag}.hlo.txt     (params, tokens, eta (1,1)) -> (params', loss)
  manifest.json                      shapes/dtypes registry for the Rust loader

Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import LARGE, TINY, TransformerConfig


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_entry(shape, dtype):
    return {"shape": list(shape), "dtype": dtype}


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name, fn, specs, outputs, meta=None):
        """Lower ``fn`` at ``specs`` and write ``<name>.hlo.txt``."""
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.entries.append({
            "name": name,
            "file": fname,
            "inputs": [_shape_entry(s.shape, s.dtype.name) for s in specs],
            "outputs": outputs,
            "meta": meta or {},
        })
        print(f"  wrote {fname} ({len(text)} chars)")

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"version": 1, "entries": self.entries}, f, indent=1)
        print(f"  wrote manifest.json ({len(self.entries)} entries)")


def export_linreg(ex: Exporter, s: int, d: int, m: int, n: int):
    """Paper workload artifacts, shape-specialized to the experiment config."""
    ex.export(
        f"linreg_grad_s{s}_d{d}",
        model.linreg_partial_grad,
        [_spec((s, d)), _spec((s, 1)), _spec((d, 1))],
        [_shape_entry((d, 1), "float32")],
        meta={"kind": "linreg_grad", "s": s, "d": d},
    )
    ex.export(
        f"linreg_grad_all_n{n}_s{s}_d{d}",
        model.linreg_grad_all,
        [_spec((n, s, d)), _spec((n, s, 1)), _spec((d, 1))],
        [_shape_entry((n, d), "float32")],
        meta={"kind": "linreg_grad_all", "n": n, "s": s, "d": d},
    )
    ex.export(
        f"linreg_loss_m{m}_d{d}",
        model.linreg_loss,
        [_spec((m, d)), _spec((m, 1)), _spec((d, 1))],
        [_shape_entry((), "float32")],
        meta={"kind": "linreg_loss", "m": m, "d": d},
    )
    ex.export(
        f"apply_update_n{n}_d{d}",
        model.fastest_k_apply,
        [_spec((1, d)), _spec((n, d)), _spec((1, 1))],
        [_shape_entry((1, d), "float32")],
        meta={"kind": "apply_update", "n": n, "d": d},
    )


def export_transformer(ex: Exporter, cfg: TransformerConfig, tag: str):
    p = model.param_count(cfg)
    tok = _spec((cfg.batch, cfg.seq_len + 1), jnp.int32)
    grad_fn = functools.partial(model.transformer_grad, cfg=cfg)
    ex.export(
        f"transformer_grad_{tag}",
        grad_fn,
        [_spec((p,)), tok],
        [_shape_entry((p,), "float32"), _shape_entry((), "float32")],
        meta={"kind": "transformer_grad", "tag": tag, "params": p,
              "vocab": cfg.vocab, "d_model": cfg.d_model,
              "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
              "d_ff": cfg.d_ff, "seq_len": cfg.seq_len, "batch": cfg.batch},
    )

    def step_fn(params, tokens, eta):
        return model.transformer_step(params, tokens, eta[0, 0], cfg)

    ex.export(
        f"transformer_step_{tag}",
        step_fn,
        [_spec((p,)), tok, _spec((1, 1))],
        [_shape_entry((p,), "float32"), _shape_entry((), "float32")],
        meta={"kind": "transformer_step", "tag": tag, "params": p,
              "batch": cfg.batch, "seq_len": cfg.seq_len,
              "vocab": cfg.vocab},
    )


def export_transformer_init(ex: Exporter, cfg: TransformerConfig, tag: str):
    """Deterministic param init as an artifact so Rust never needs numpy."""
    p = model.param_count(cfg)

    def init_fn(seed):
        key = jax.random.PRNGKey(seed[0])
        return model.init_params(cfg, key)

    ex.export(
        f"transformer_init_{tag}",
        init_fn,
        [_spec((1,), jnp.int32)],
        [_shape_entry((p,), "float32")],
        meta={"kind": "transformer_init", "tag": tag, "params": p},
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Paper Fig-2/3 defaults: m=2000 rows, d=100 features, n=50 workers.
    ap.add_argument("--m", type=int, default=2000)
    ap.add_argument("--d", type=int, default=100)
    ap.add_argument("--n", type=int, default=50)
    ap.add_argument("--transformer", choices=["tiny", "large", "both", "none"],
                    default="tiny")
    args = ap.parse_args()

    assert args.m % args.n == 0, "n must divide m (horizontal partition)"
    s = args.m // args.n

    ex = Exporter(args.out_dir)
    print(f"[aot] linreg artifacts (s={s}, d={args.d}, m={args.m}, n={args.n})")
    export_linreg(ex, s, args.d, args.m, args.n)

    if args.transformer in ("tiny", "both"):
        print(f"[aot] transformer tiny ({model.param_count(TINY):,} params)")
        export_transformer(ex, TINY, "tiny")
        export_transformer_init(ex, TINY, "tiny")
    if args.transformer in ("large", "both"):
        # ~100M-param config: compile-only proof that the artifact path
        # scales; the e2e example trains the tiny config on CPU.
        print(f"[aot] transformer large ({model.param_count(LARGE):,} params)")
        export_transformer(ex, LARGE, "large")
        export_transformer_init(ex, LARGE, "large")

    ex.finish()


if __name__ == "__main__":
    main()
