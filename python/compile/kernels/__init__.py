"""L1 — Pallas kernels (build-time only).

Every kernel here is lowered with ``interpret=True``: the CPU PJRT plugin
that the Rust runtime embeds cannot execute Mosaic custom-calls, so the
interpret path (which lowers to plain HLO ops) is the correctness +
interchange target. The TPU structure (BlockSpec tiling for VMEM, MXU-shaped
matmul blocks, fused single-pass accumulation) is kept so the same kernels
re-target real TPUs by flipping ``interpret=False``.

Kernels:
  - ``matmul``       — general tiled matmul with f32 accumulation (custom_vjp
                       so it is differentiable from L2 model code).
  - ``linreg_grad``  — the paper's hot spot: fused per-shard partial gradient
                       g = X^T (X w - y) / s, one pass over X.
  - ``apply_update`` — masked-average fastest-k SGD apply:
                       w' = w - step_scale * sum_rows(G).
``ref.py`` holds the pure-jnp oracles pytest checks against.
"""

from .matmul import matmul
from .linreg_grad import linreg_grad
from .linreg_loss import linreg_loss
from .apply_update import apply_update

__all__ = ["matmul", "linreg_grad", "linreg_loss", "apply_update"]
