"""Masked-average fastest-k SGD apply kernel.

The master receives the fastest ``k`` partial gradients of an iteration.
``k`` varies at run time but HLO shapes are static, so the Rust coordinator
zero-pads the gradient stack to a fixed ``(n, d)`` buffer and passes
``step_scale = eta / k`` as a scalar. The kernel fuses the reduction and
the parameter update:

    w' = w - step_scale * sum_rows(G)

Grid walks column-blocks of ``G`` so arbitrarily large ``d`` (e.g. a flat
transformer parameter vector) streams through VMEM ``(n, bd)`` at a time.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _apply_update_kernel(w_ref, g_ref, scale_ref, o_ref):
    # scale_ref is a (1, 1) scalar block broadcast to every grid step.
    s = scale_ref[0, 0]
    o_ref[...] = w_ref[...] - s * jnp.sum(g_ref[...], axis=0, keepdims=True)


def _col_block(d: int, want: int) -> int:
    b = min(d, want)
    while d % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def apply_update(w, g, step_scale, bd: int = 4096, interpret: bool = True):
    """Fused fastest-k average + SGD step.

    Args:
      w: ``(1, d)`` f32 current model (row layout).
      g: ``(n, d)`` f32 gradient stack, rows ``k..n-1`` zeroed by the caller.
      step_scale: ``(1, 1)`` f32 scalar, ``eta / k``.
      bd: column-block size (clamped to a divisor of ``d``).

    Returns:
      ``(1, d)`` f32 updated model.
    """
    n, d = g.shape
    assert w.shape == (1, d), w.shape
    assert step_scale.shape == (1, 1), step_scale.shape
    bd = _col_block(d, bd)
    grid = (d // bd,)
    return pl.pallas_call(
        _apply_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd), lambda i: (0, i)),
            pl.BlockSpec((n, bd), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(w, g, step_scale)
