"""Fused per-shard partial-gradient kernel — the paper's compute hot spot.

Worker ``i`` holds a shard ``S_i = [X_i | y_i]`` with ``s = m/n`` rows and
must produce the partial gradient of the l2 loss (paper Eq. (2)):

    g_i = (1/s) * X_i^T (X_i w - y_i)

A naive two-op implementation reads ``X_i`` from HBM twice (once for the
residual ``X w - y``, once for the transpose product). This kernel fuses
both into a single pass: the grid walks row-blocks of ``X_i``; each step
keeps one ``(bs, d)`` block resident in VMEM, computes its residual slice
on the MXU, immediately contracts it back (``X_b^T r_b``) while the block
is still resident, and accumulates into the output block (which maps to the
same ``(d, 1)`` VMEM buffer for every grid step). ``X`` HBM traffic: 1x.

VMEM per step (f32 words): ``bs*d`` (X block) + ``bs`` (y) + ``d`` (w)
+ ``d`` (acc). For the paper's Fig-2 shard (s=40, d=100) the whole shard
fits in one block; the tiling matters for the larger e2e shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linreg_grad_kernel(x_ref, y_ref, w_ref, g_ref, *, n_blocks: int,
                        inv_s: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    xb = x_ref[...]                       # (bs, d) resident block
    # Residual slice on the MXU: (bs, d) @ (d, 1).
    r = jnp.dot(xb, w_ref[...], preferred_element_type=jnp.float32) - y_ref[...]
    # Contract back while xb is still in VMEM: (d, bs) @ (bs, 1).
    g_ref[...] += jnp.dot(xb.T, r, preferred_element_type=jnp.float32)

    @pl.when(i == n_blocks - 1)
    def _scale():
        g_ref[...] *= inv_s


def _row_block(s: int, want: int) -> int:
    b = min(s, want)
    while s % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def linreg_grad(x, y, w, bs: int = 256, interpret: bool = True):
    """Partial gradient ``X^T (X w - y) / s`` for one shard.

    Args:
      x: ``(s, d)`` f32 shard of data rows.
      y: ``(s, 1)`` f32 shard labels.
      w: ``(d, 1)`` f32 current model.
      bs: row-block size (clamped to a divisor of ``s``).

    Returns:
      ``(d, 1)`` f32 partial gradient.
    """
    s, d = x.shape
    assert y.shape == (s, 1), y.shape
    assert w.shape == (d, 1), w.shape
    bs = _row_block(s, bs)
    n_blocks = s // bs
    return pl.pallas_call(
        functools.partial(
            _linreg_grad_kernel, n_blocks=n_blocks, inv_s=1.0 / s
        ),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((bs, d), lambda i: (i, 0)),   # row block of X
            pl.BlockSpec((bs, 1), lambda i: (i, 0)),   # matching y slice
            pl.BlockSpec((d, 1), lambda i: (0, 0)),    # full w, every step
        ],
        out_specs=pl.BlockSpec((d, 1), lambda i: (0, 0)),  # accumulator
        out_shape=jax.ShapeDtypeStruct((d, 1), jnp.float32),
        interpret=interpret,
    )(x, y, w)
