"""Fused loss-evaluation kernel: F(w) = ||X w - y||^2 / (2 m).

Same single-pass structure as ``linreg_grad``: the grid walks row-blocks
of ``X``; each step computes its residual slice on the MXU and reduces the
squared norm into a (1, 1) accumulator block that every grid step maps to.
One HBM pass over ``X``, scalar out.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linreg_loss_kernel(x_ref, y_ref, w_ref, o_ref, *, n_blocks: int,
                        inv_2m: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    r = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ) - y_ref[...]
    o_ref[...] += jnp.sum(r * r)

    @pl.when(i == n_blocks - 1)
    def _scale():
        o_ref[...] *= inv_2m


def _row_block(m: int, want: int) -> int:
    b = min(m, want)
    while m % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def linreg_loss(x, y, w, bs: int = 512, interpret: bool = True):
    """Scalar loss ``(1, 1)`` for ``x (m,d)``, ``y (m,1)``, ``w (d,1)``."""
    m, d = x.shape
    assert y.shape == (m, 1) and w.shape == (d, 1)
    bs = _row_block(m, bs)
    n_blocks = m // bs
    return pl.pallas_call(
        functools.partial(
            _linreg_loss_kernel, n_blocks=n_blocks, inv_2m=0.5 / m
        ),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
            pl.BlockSpec((bs, 1), lambda i: (i, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x, y, w)
