"""Tiled Pallas matmul with f32 accumulation.

The workhorse kernel re-used by the L2 transformer MLP. Classic TPU
structure: a 3-D grid over (M, N, K) blocks; each grid step keeps one
``(bm, bk)`` block of ``x`` and one ``(bk, bn)`` block of ``y`` resident in
VMEM and feeds the MXU with a ``bm x bk x bn`` contraction, accumulating
into the output block (revisited across the K dimension of the grid).

VMEM budget per grid step (f32): ``bm*bk + bk*bn + bm*bn`` words. The
default 128-tiles use 3 * 128*128 * 4 B = 192 KiB, far inside the ~16 MiB
VMEM of a TPU core, leaving room for double buffering (the Mosaic pipeline
overlaps the HBM->VMEM copy of step i+1 with the compute of step i; under
``interpret=True`` this is emulated functionally).

``matmul`` carries a ``custom_vjp`` so L2 model code can differentiate
through it; both cotangents are computed by the same tiled kernel
(dx = g @ y^T, dy = x^T @ g).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i, j] += x[i, k] @ y[k, j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulation on the MXU; preferred_element_type pins the
    # accumulator dtype even if inputs are later flipped to bf16.
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _block(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= `want` (keeps the grid exact)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def _matmul_fwd(x, y, bm, bk, bn, interpret):
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm, bk, bn = _block(m, bm), _block(k, bk), _block(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, y)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def matmul(x, y, bm: int = 128, bk: int = 128, bn: int = 128,
           interpret: bool = True):
    """``x @ y`` via the tiled Pallas kernel. Differentiable."""
    return _matmul_fwd(x, y, bm, bk, bn, interpret)


def _vjp_fwd(x, y, bm, bk, bn, interpret):
    return _matmul_fwd(x, y, bm, bk, bn, interpret), (x, y)


def _vjp_bwd(bm, bk, bn, interpret, res, g):
    x, y = res
    # Reuse the same tiled kernel for both cotangents.
    dx = _matmul_fwd(g, y.T, bm, bk, bn, interpret)
    dy = _matmul_fwd(x.T, g, bm, bk, bn, interpret)
    return dx, dy


matmul.defvjp(_vjp_fwd, _vjp_bwd)
