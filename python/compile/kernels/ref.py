"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest (python/tests/) sweeps shapes/dtypes with hypothesis and asserts
``assert_allclose(kernel(...), ref(...))``. Keep these boring: no tiling,
no tricks, just the textbook expression.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Plain ``x @ y`` with f32 accumulation."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def linreg_grad_ref(x, y, w):
    """Two-op partial gradient: g = X^T (X w - y) / s."""
    s = x.shape[0]
    r = jnp.dot(x, w, preferred_element_type=jnp.float32) - y
    return jnp.dot(x.T, r, preferred_element_type=jnp.float32) / s


def apply_update_ref(w, g, step_scale):
    """w' = w - step_scale * sum_rows(G)."""
    return w - step_scale[0, 0] * jnp.sum(g, axis=0, keepdims=True)


def linreg_loss_ref(x, y, w):
    """Mean-square error F(w) = ||X w - y||^2 / (2 m)  (scalar)."""
    r = jnp.dot(x, w, preferred_element_type=jnp.float32) - y
    return jnp.sum(r * r) / (2.0 * x.shape[0])
