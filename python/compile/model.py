"""L2 — JAX compute graphs (build-time only), calling the L1 kernels.

Two workloads:

1. **Linear regression** — the paper's evaluation workload (§V): per-shard
   partial gradient (Pallas ``linreg_grad``), full-data loss, and the
   fastest-k masked-average apply (Pallas ``apply_update``).

2. **Transformer LM** — the end-to-end driver workload: a decoder-only
   transformer whose parameters live in ONE flat f32 vector (so the Rust
   coordinator treats the model as an opaque parameter buffer and the
   fastest-k machinery is workload-agnostic). ``transformer_grad`` returns
   ``(flat_grad, loss)`` for one worker microbatch; the MLP matmuls route
   through the Pallas ``matmul`` kernel (differentiated via its custom_vjp).

Everything here is traced once by ``aot.py`` and exported as HLO text; no
function in this file runs at serving/training time.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import apply_update, linreg_grad, linreg_loss as _loss_kernel, matmul

# ---------------------------------------------------------------------------
# Workload 1: linear regression (paper §V)
# ---------------------------------------------------------------------------


def linreg_partial_grad(x_shard, y_shard, w):
    """Per-worker partial gradient (paper Eq. 2 inner term), Pallas-fused.

    Shapes: x ``(s, d)``, y ``(s, 1)``, w ``(d, 1)`` -> ``(d, 1)``.
    """
    return linreg_grad(x_shard, y_shard, w)


def linreg_grad_all(x_all, y_all, w):
    """All n per-shard partial gradients in ONE graph: ``x_all (n,s,d)``,
    ``y_all (n,s,1)``, ``w (d,1)`` -> ``(n, d)``.

    The coordinator-side win: one PJRT dispatch per iteration instead of
    k. Semantically faithful — in the real cluster *all* workers compute
    every iteration; the master merely ignores the stragglers' results.

    Lowered as two batched contractions rather than a vmapped Pallas call:
    under ``interpret=True`` the vmapped kernel becomes an interpreter
    loop (measured 4x slower than per-shard dispatch); the direct batched
    ``dot_general`` is what XLA:CPU fuses best, and on TPU the per-shard
    Pallas kernel (``linreg_grad``) remains the hand-tiled hot spot.
    """
    s = x_all.shape[1]
    r = jnp.einsum(
        "nsd,dz->nsz", x_all, w, preferred_element_type=jnp.float32
    ) - y_all                                         # (n, s, 1)
    g = jnp.einsum(
        "nsd,nsz->nd", x_all, r, preferred_element_type=jnp.float32
    )
    return g / s


def linreg_loss(x, y, w):
    """Full-data loss F(w) = ||X w - y||^2 / (2 m), Pallas-fused (single
    HBM pass); the error metric of Figs. 2-3 is ``F(w) - F*`` with F*
    evaluated on the same graph. Returns a scalar."""
    return _loss_kernel(x, y, w)[0, 0]


def fastest_k_apply(w, g_stack, step_scale):
    """Masked fastest-k average + SGD step (Pallas-fused).

    ``g_stack`` is ``(n, d)`` with rows of stragglers zeroed by the
    coordinator, ``step_scale`` is ``(1, 1) = eta / k``.
    """
    return apply_update(w, g_stack, step_scale)


# ---------------------------------------------------------------------------
# Workload 2: decoder-only transformer LM with flat-packed parameters
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TransformerConfig:
    """Static architecture config (baked into the HLO artifact)."""

    vocab: int = 256       # byte-level vocab for the synthetic corpus
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    seq_len: int = 64
    batch: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Named "~100M" config: compile-only target for the --large artifact.
LARGE = TransformerConfig(
    vocab=32000, d_model=768, n_heads=12, n_layers=12, d_ff=3072,
    seq_len=256, batch=4,
)
TINY = TransformerConfig()


def _param_layout(cfg: TransformerConfig):
    """Ordered (name, shape) list defining the flat packing."""
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    layout = [("embed", (v, d)), ("pos", (s, d))]
    for i in range(cfg.n_layers):
        layout += [
            (f"l{i}.ln1_scale", (d,)), (f"l{i}.ln1_bias", (d,)),
            (f"l{i}.wq", (d, d)), (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)), (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2_scale", (d,)), (f"l{i}.ln2_bias", (d,)),
            (f"l{i}.w1", (d, f)), (f"l{i}.w2", (f, d)),
        ]
    layout += [("lnf_scale", (d,)), ("lnf_bias", (d,))]
    return layout


def param_count(cfg: TransformerConfig) -> int:
    """Total flat parameter count P."""
    total = 0
    for _, shape in _param_layout(cfg):
        n = 1
        for dim in shape:
            n *= dim
        total += n
    return total


def _unpack(flat, cfg: TransformerConfig):
    """Flat (P,) vector -> dict of named arrays (static offsets)."""
    params, off = {}, 0
    for name, shape in _param_layout(cfg):
        n = 1
        for dim in shape:
            n *= dim
        params[name] = flat[off:off + n].reshape(shape)
        off += n
    return params


def init_params(cfg: TransformerConfig, key) -> jnp.ndarray:
    """Scaled-normal init, returned already flat-packed."""
    chunks = []
    for name, shape in _param_layout(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_scale",)):
            chunks.append(jnp.ones(shape, jnp.float32).ravel())
        elif name.endswith(("_bias",)):
            chunks.append(jnp.zeros(shape, jnp.float32).ravel())
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / jnp.sqrt(jnp.float32(fan_in))
            chunks.append((jax.random.normal(sub, shape) * std).ravel())
    return jnp.concatenate(chunks)


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _mlp(x, w1, w2, cfg: TransformerConfig):
    """Position-wise MLP; matmuls run on the Pallas tiled kernel."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    h = jax.nn.gelu(matmul(x2, w1))
    out = matmul(h, w2)
    return out.reshape(b, s, d)


def _attention(x, p, i, cfg: TransformerConfig):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p[f"l{i}.wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ p[f"l{i}.wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (x @ p[f"l{i}.wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ p[f"l{i}.wo"]


def transformer_loss(flat_params, tokens, cfg: TransformerConfig):
    """Next-token cross-entropy over a ``(B, S+1)`` int32 token batch."""
    p = _unpack(flat_params, cfg)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    b, s = inp.shape
    x = p["embed"][inp] + p["pos"][None, :s, :]
    for i in range(cfg.n_layers):
        hx = _layer_norm(x, p[f"l{i}.ln1_scale"], p[f"l{i}.ln1_bias"])
        x = x + _attention(hx, p, i, cfg)
        hx = _layer_norm(x, p[f"l{i}.ln2_scale"], p[f"l{i}.ln2_bias"])
        x = x + _mlp(hx, p[f"l{i}.w1"], p[f"l{i}.w2"], cfg)
    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    logits = x @ p["embed"].T  # tied unembedding
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
    return jnp.mean(nll)


def transformer_grad(flat_params, tokens, cfg: TransformerConfig):
    """Per-worker microbatch gradient: ``(flat_grad (P,), loss ())``.

    This is the artifact the fastest-k coordinator calls on each simulated
    worker; averaging + apply happen coordinator-side (natively or via the
    ``apply_update`` artifact).
    """
    loss, grad = jax.value_and_grad(transformer_loss)(flat_params, tokens, cfg)
    return grad, loss


def transformer_step(flat_params, tokens, eta, cfg: TransformerConfig):
    """Fused single-worker train step: ``(new_params, loss)``.

    ``flat_params`` is donated at lowering time so XLA updates in place.
    """
    grad, loss = transformer_grad(flat_params, tokens, cfg)
    return flat_params - eta * grad, loss
