"""Shared pytest config: force CPU, deterministic seeds, fast hypothesis."""

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

# Kernel sweeps trace+compile per example; keep example counts modest so the
# suite stays interactive. CI can raise this via HYPOTHESIS_PROFILE.
settings.register_profile("kernels", max_examples=25, deadline=None)
settings.register_profile("ci", max_examples=100, deadline=None)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "kernels"))
