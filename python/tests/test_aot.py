"""AOT export: artifacts exist, HLO text is loadable-shaped, manifest sane."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    """Run the exporter once at small shapes into a temp dir."""
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--m", "80", "--d", "16", "--n", "4", "--transformer", "none"],
        cwd=ROOT, env=env, check=True,
    )
    return out


def test_linreg_artifacts_written(artifacts):
    names = sorted(os.listdir(artifacts))
    assert "linreg_grad_s20_d16.hlo.txt" in names
    assert "linreg_loss_m80_d16.hlo.txt" in names
    assert "apply_update_n4_d16.hlo.txt" in names
    assert "manifest.json" in names


def test_hlo_text_shape(artifacts):
    """The interchange files are HLO *text* with a single ENTRY."""
    for name in os.listdir(artifacts):
        if not name.endswith(".hlo.txt"):
            continue
        text = (artifacts / name).read_text()
        assert text.startswith("HloModule"), name
        assert text.count("ENTRY") == 1, name
        # jax>=0.5 64-bit-id proto issue: text must not be a binary proto.
        assert "\x00" not in text, name


def test_manifest_schema(artifacts):
    manifest = json.loads((artifacts / "manifest.json").read_text())
    assert manifest["version"] == 1
    entries = {e["name"]: e for e in manifest["entries"]}
    grad = entries["linreg_grad_s20_d16"]
    assert grad["inputs"] == [
        {"shape": [20, 16], "dtype": "float32"},
        {"shape": [20, 1], "dtype": "float32"},
        {"shape": [16, 1], "dtype": "float32"},
    ]
    assert grad["outputs"][0]["shape"] == [16, 1]
    assert grad["meta"]["kind"] == "linreg_grad"
    for e in manifest["entries"]:
        assert os.path.exists(artifacts / e["file"]), e["file"]


def test_hlo_reimports_into_xla_computation(artifacts):
    """Round-trip: the emitted text parses back via the HLO text parser."""
    from jax._src.lib import xla_client as xc
    text = (artifacts / "linreg_grad_s20_d16.hlo.txt").read_text()
    # xla_client exposes the HLO text parser used by the Rust side's
    # HloModuleProto::from_text_file equivalent.
    mod = xc._xla.hlo_module_from_text(text)
    assert "linreg" in mod.name or mod.name  # parsed fine


def test_exporter_requires_divisible_shards(tmp_path):
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--m", "81", "--d", "4", "--n", "4", "--transformer", "none"],
        cwd=ROOT, env=env, capture_output=True,
    )
    assert proc.returncode != 0
