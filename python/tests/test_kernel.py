"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (including non-tile-multiple and degenerate ones)
and data; assert_allclose against ref.py is THE correctness signal for the
kernels that end up inside every HLO artifact the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import apply_update, linreg_grad, matmul
from compile.kernels import ref


def _rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    kx, ky = _keys(seed, 2)
    x, y = _rand(kx, (m, k)), _rand(ky, (k, n))
    np.testing.assert_allclose(
        matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("bm,bk,bn", [(8, 8, 8), (16, 32, 8), (128, 128, 128)])
def test_matmul_block_shapes(bm, bk, bn):
    kx, ky = _keys(7, 2)
    x, y = _rand(kx, (64, 48)), _rand(ky, (48, 80))
    np.testing.assert_allclose(
        matmul(x, y, bm=bm, bk=bk, bn=bn),
        ref.matmul_ref(x, y),
        rtol=1e-5,
        atol=1e-5,
    )


def test_matmul_grad_matches_jnp():
    kx, ky = _keys(11, 2)
    x, y = _rand(kx, (32, 24)), _rand(ky, (24, 16))

    def f_pallas(x, y):
        return jnp.sum(matmul(x, y) ** 2)

    def f_ref(x, y):
        return jnp.sum(ref.matmul_ref(x, y) ** 2)

    gx_p, gy_p = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy_p, gy_r, rtol=1e-4, atol=1e-4)


def test_matmul_identity():
    x = jnp.eye(16, dtype=jnp.float32)
    y = _rand(_keys(3, 1)[0], (16, 16))
    np.testing.assert_allclose(matmul(x, y), y, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# linreg_grad (the paper's hot spot)
# ---------------------------------------------------------------------------


@given(
    s=st.integers(1, 128),
    d=st.integers(1, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_linreg_grad_matches_ref(s, d, seed):
    kx, ky, kw = _keys(seed, 3)
    x = _rand(kx, (s, d), 1.0, 10.0)  # paper's data range
    y = _rand(ky, (s, 1), -100.0, 100.0)
    w = _rand(kw, (d, 1), -1.0, 1.0)
    np.testing.assert_allclose(
        linreg_grad(x, y, w),
        ref.linreg_grad_ref(x, y, w),
        rtol=1e-4,
        atol=1e-3,
    )


@pytest.mark.parametrize("bs", [1, 4, 8, 40, 256])
def test_linreg_grad_block_sizes(bs):
    """Row-block tiling must not change the accumulated result."""
    kx, ky, kw = _keys(5, 3)
    x = _rand(kx, (40, 100), 1.0, 10.0)  # paper Fig-2 shard shape
    y = _rand(ky, (40, 1))
    w = _rand(kw, (100, 1))
    np.testing.assert_allclose(
        linreg_grad(x, y, w, bs=bs),
        ref.linreg_grad_ref(x, y, w),
        rtol=1e-4,
        atol=1e-4,
    )


def test_linreg_grad_zero_residual():
    """If y = X w exactly, the gradient must vanish."""
    kx, kw = _keys(9, 2)
    x = _rand(kx, (32, 16))
    w = _rand(kw, (16, 1))
    y = x @ w
    g = linreg_grad(x, y, w)
    np.testing.assert_allclose(g, jnp.zeros((16, 1)), atol=1e-4)


def test_linreg_grad_is_mean_not_sum():
    """Duplicating every row must leave the partial gradient unchanged."""
    kx, ky, kw = _keys(13, 3)
    x, y, w = _rand(kx, (8, 4)), _rand(ky, (8, 1)), _rand(kw, (4, 1))
    x2, y2 = jnp.concatenate([x, x]), jnp.concatenate([y, y])
    np.testing.assert_allclose(
        linreg_grad(x, y, w), linreg_grad(x2, y2, w), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# apply_update (masked fastest-k average + SGD apply)
# ---------------------------------------------------------------------------


@given(
    n=st.integers(1, 64),
    d=st.integers(1, 300),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_apply_update_matches_ref(n, d, k, seed):
    k = min(k, n)
    kw, kg = _keys(seed, 2)
    w = _rand(kw, (1, d))
    g = _rand(kg, (n, d))
    g = g.at[k:].set(0.0)  # straggler rows zeroed, as the coordinator does
    scale = jnp.full((1, 1), 0.05 / k, jnp.float32)
    np.testing.assert_allclose(
        apply_update(w, g, scale),
        ref.apply_update_ref(w, g, scale),
        rtol=1e-5,
        atol=1e-5,
    )


def test_apply_update_zero_gradient_is_identity():
    w = _rand(_keys(1, 1)[0], (1, 64))
    g = jnp.zeros((8, 64), jnp.float32)
    scale = jnp.full((1, 1), 0.1, jnp.float32)
    np.testing.assert_allclose(apply_update(w, g, scale), w, atol=0)


def test_apply_update_equals_explicit_fastest_k():
    """Masked layout == averaging the k received gradients explicitly."""
    n, d, k, eta = 10, 32, 4, 0.01
    keys = _keys(21, n + 1)
    w = _rand(keys[0], (1, d))
    grads = [_rand(keys[i + 1], (1, d)) for i in range(n)]
    g_stack = jnp.concatenate(grads + [], axis=0)
    g_stack = g_stack.at[k:].set(0.0)
    scale = jnp.full((1, 1), eta / k, jnp.float32)
    out = apply_update(w, g_stack, scale)
    expect = w - eta * sum(grads[:k]) / k
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bd", [1, 7, 64, 4096])
def test_apply_update_block_sizes(bd):
    kw, kg = _keys(17, 2)
    w, g = _rand(kw, (1, 96)), _rand(kg, (12, 96))
    scale = jnp.full((1, 1), 0.02, jnp.float32)
    np.testing.assert_allclose(
        apply_update(w, g, scale, bd=bd),
        ref.apply_update_ref(w, g, scale),
        rtol=1e-5,
        atol=1e-5,
    )
