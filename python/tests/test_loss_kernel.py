"""Pallas linreg_loss kernel vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import linreg_loss
from compile.kernels import ref


def _rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


@given(
    m=st.integers(1, 256),
    d=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_loss_matches_ref(m, d, seed):
    kx, ky, kw = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(kx, (m, d), 1.0, 10.0)
    y = _rand(ky, (m, 1), -50.0, 50.0)
    w = _rand(kw, (d, 1), -1.0, 1.0)
    got = linreg_loss(x, y, w)[0, 0]
    want = ref.linreg_loss_ref(x, y, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bs", [1, 5, 100, 512])
def test_loss_block_sizes(bs):
    kx, ky, kw = jax.random.split(jax.random.PRNGKey(3), 3)
    x = _rand(kx, (100, 16))
    y = _rand(ky, (100, 1))
    w = _rand(kw, (16, 1))
    got = linreg_loss(x, y, w, bs=bs)[0, 0]
    want = ref.linreg_loss_ref(x, y, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_loss_zero_at_exact_fit():
    kx, kw = jax.random.split(jax.random.PRNGKey(5))
    x = _rand(kx, (64, 8))
    w = _rand(kw, (8, 1))
    y = x @ w
    got = linreg_loss(x, y, w)[0, 0]
    assert abs(float(got)) < 1e-6


def test_loss_is_half_msq():
    x = jnp.ones((4, 1), jnp.float32)
    y = jnp.zeros((4, 1), jnp.float32)
    w = jnp.full((1, 1), 2.0, jnp.float32)
    # residual = 2 everywhere -> F = 4*4/(2*4) = 2
    got = linreg_loss(x, y, w)[0, 0]
    np.testing.assert_allclose(got, 2.0, rtol=1e-6)
