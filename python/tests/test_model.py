"""L2 correctness: model-level functions (linreg workload) and shapes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import model
from compile.kernels import ref


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def _paper_data(key, m, d):
    """Synthetic data exactly per paper §V.A (integer features/weights)."""
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.randint(kx, (m, d), 1, 11).astype(jnp.float32)
    wbar = jax.random.randint(kw, (d, 1), 1, 101).astype(jnp.float32)
    y = x @ wbar + jax.random.normal(ky, (m, 1))
    return x, y, wbar


def test_partial_grad_shapes():
    x, y, _ = _paper_data(jax.random.PRNGKey(0), 40, 100)
    w = jnp.zeros((100, 1), jnp.float32)
    g = model.linreg_partial_grad(x, y, w)
    assert g.shape == (100, 1)


def test_full_gradient_is_mean_of_partial_gradients():
    """Averaging all n shard gradients == the full-data gradient (Eq. 1)."""
    m, d, n = 200, 10, 5
    x, y, _ = _paper_data(jax.random.PRNGKey(1), m, d)
    w = jax.random.normal(jax.random.PRNGKey(2), (d, 1))
    s = m // n
    partials = [
        model.linreg_partial_grad(x[i * s:(i + 1) * s], y[i * s:(i + 1) * s], w)
        for i in range(n)
    ]
    avg = sum(partials) / n
    full = ref.linreg_grad_ref(x, y, w)
    np.testing.assert_allclose(avg, full, rtol=1e-4, atol=1e-2)


def test_loss_at_ground_truth_is_noise_floor():
    """F(w_bar) ~ noise variance / 2 (labels are <x,w>+N(0,1))."""
    x, y, wbar = _paper_data(jax.random.PRNGKey(3), 2000, 100)
    loss = model.linreg_loss(x, y, wbar)
    assert 0.3 < float(loss) < 0.7, float(loss)


def test_gd_descends_with_paper_step_size():
    """Full-batch GD with the Fig-2 step size must strictly descend."""
    x, y, _ = _paper_data(jax.random.PRNGKey(4), 2000, 100)
    w = jnp.zeros((100, 1), jnp.float32)
    eta = 0.0005
    losses = []
    for _ in range(20):
        losses.append(float(model.linreg_loss(x, y, w)))
        g = ref.linreg_grad_ref(x, y, w)
        w = w - eta * g
    assert losses[-1] < losses[0] * 0.5, losses[::5]


@given(k=st.integers(1, 50), seed=st.integers(0, 2**31 - 1))
def test_fastest_k_apply_matches_manual(k, seed):
    """The masked-apply path == manual average of the k fastest gradients."""
    n, d, eta = 50, 100, 0.0005
    kg, kw = _keys(seed, 2)
    g_all = jax.random.normal(kg, (n, d))
    w = jax.random.normal(kw, (1, d))
    g_stack = g_all.at[k:].set(0.0)
    scale = jnp.full((1, 1), eta / k, jnp.float32)
    got = model.fastest_k_apply(w, g_stack, scale)
    expect = w - (eta / k) * jnp.sum(g_all[:k], axis=0, keepdims=True)
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)
