"""L2 transformer: packing round-trip, gradient parity, training descent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref as kref

# Micro config keeps trace+interpret time tiny while exercising every path.
MICRO = model.TransformerConfig(
    vocab=17, d_model=16, n_heads=2, n_layers=2, d_ff=32, seq_len=12, batch=2
)


def _tokens(cfg, seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab
    )


def _params(cfg, seed=1):
    return model.init_params(cfg, jax.random.PRNGKey(seed))


def test_param_count_matches_layout():
    p = _params(MICRO)
    assert p.shape == (model.param_count(MICRO),)


def test_param_count_large_config_is_about_100m():
    assert 80e6 < model.param_count(model.LARGE) < 130e6


def test_unpack_round_trip():
    flat = _params(MICRO)
    parts = model._unpack(flat, MICRO)
    rebuilt = jnp.concatenate(
        [parts[name].ravel() for name, _ in model._param_layout(MICRO)]
    )
    np.testing.assert_array_equal(flat, rebuilt)


def test_loss_is_finite_and_near_uniform_at_init():
    """At init the LM should predict ~uniform: loss ~ log(vocab)."""
    loss = model.transformer_loss(_params(MICRO), _tokens(MICRO), MICRO)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(MICRO.vocab)) < 1.0


def test_grad_matches_pure_jnp_matmul(monkeypatch):
    """Same loss/grad with the Pallas MLP matmul vs plain jnp dot."""
    flat, toks = _params(MICRO), _tokens(MICRO)
    g_pallas, l_pallas = model.transformer_grad(flat, toks, MICRO)
    monkeypatch.setattr(model, "matmul", lambda a, b: kref.matmul_ref(a, b))
    g_ref, l_ref = model.transformer_grad(flat, toks, MICRO)
    np.testing.assert_allclose(l_pallas, l_ref, rtol=1e-5)
    np.testing.assert_allclose(g_pallas, g_ref, rtol=2e-3, atol=2e-5)


def test_grad_direction_decreases_loss():
    flat, toks = _params(MICRO), _tokens(MICRO)
    g, l0 = model.transformer_grad(flat, toks, MICRO)
    l1 = model.transformer_loss(flat - 0.05 * g, toks, MICRO)
    assert float(l1) < float(l0)


def test_step_trains_on_fixed_batch():
    """A few fused steps on one batch must overfit it measurably."""
    cfg = MICRO
    flat, toks = _params(cfg), _tokens(cfg)
    step = jax.jit(
        lambda p, t: model.transformer_step(p, t, 0.05, cfg), donate_argnums=0
    )
    losses = []
    for _ in range(30):
        flat, loss = step(flat, toks)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_causality():
    """Changing a future token must not affect earlier next-token logits.

    We check through the loss: per-position NLL for positions < t is
    unchanged when token t+1 changes.
    """
    cfg = MICRO
    flat = _params(cfg)
    toks = _tokens(cfg)

    def per_pos_nll(tokens):
        p = model._unpack(flat, cfg)
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        b, s = inp.shape
        x = p["embed"][inp] + p["pos"][None, :s, :]
        for i in range(cfg.n_layers):
            hx = model._layer_norm(x, p[f"l{i}.ln1_scale"], p[f"l{i}.ln1_bias"])
            x = x + model._attention(hx, p, i, cfg)
            hx = model._layer_norm(x, p[f"l{i}.ln2_scale"], p[f"l{i}.ln2_bias"])
            x = x + model._mlp(hx, p[f"l{i}.w1"], p[f"l{i}.w2"], cfg)
        x = model._layer_norm(x, p["lnf_scale"], p["lnf_bias"])
        logits = x @ p["embed"].T
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]

    nll_a = per_pos_nll(toks)
    toks_b = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab)
    nll_b = per_pos_nll(toks_b)
    np.testing.assert_allclose(nll_a[:, :-1], nll_b[:, :-1], rtol=1e-5)


def test_fastest_k_data_parallel_equivalence():
    """Averaging per-worker microbatch grads == grad of the union batch.

    This is the property that makes the transformer trainable through the
    same fastest-k coordinator as the linreg workload.
    """
    cfg = MICRO
    flat = _params(cfg)
    t1, t2 = _tokens(cfg, 5), _tokens(cfg, 6)
    g1, _ = model.transformer_grad(flat, t1, cfg)
    g2, _ = model.transformer_grad(flat, t2, cfg)
    union = jnp.concatenate([t1, t2], axis=0)
    cfg_u = model.TransformerConfig(
        vocab=cfg.vocab, d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_layers=cfg.n_layers, d_ff=cfg.d_ff, seq_len=cfg.seq_len,
        batch=2 * cfg.batch,
    )
    gu, _ = model.transformer_grad(flat, union, cfg_u)
    np.testing.assert_allclose((g1 + g2) / 2, gu, rtol=2e-3, atol=2e-5)
