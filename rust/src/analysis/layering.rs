//! L001 — the architecture layering rule.
//!
//! The crate's dependency direction is enforced here as an explicit
//! allowlist over **live** `use crate::<module>` declarations (test
//! regions are exempt: tests may reach across layers to set up
//! scenarios). The table is the architecture document — changing a
//! layer boundary means editing [`ALLOWED_IMPORTS`] deliberately, in
//! the same commit as the import it legalises.
//!
//! Two global guards apply on top of the table: no library module may
//! import `crate::cli` (the CLI sits above everything) or
//! `crate::analysis` (the linter must not leak into the product).

use super::report::Finding;
use super::source::SourceFile;
use crate::analysis::lexer::TokenKind;

/// Allowed `use crate::X` targets per top-level module. Modules not
/// listed (`lib`, `main`, `config`, `coordinator`, `sweep`,
/// `bench_harness`, `analysis`) are orchestration layers and may
/// import anything except the global-guard targets.
///
/// Leaf modules (`rng`, `linalg`, `sim`, `metrics`, `cli`) import
/// nothing from the crate, which is what keeps the engine embeddable.
///
/// `engine`/`grad` → `exec` and `exec` → `engine` are both sanctioned:
/// `exec` hosts two layers at once — the leaf fork–join primitives
/// (`exec::par`, `exec::pool`, `exec::scratch`), which the hot path
/// uses for intra-round parallelism, and the top-of-stack
/// `ThreadedCluster`, which drives the engine. The module-level cycle
/// is tolerated because the *file*-level graph stays acyclic; the
/// leaves must never import back (enforced by their own table rows).
pub const ALLOWED_IMPORTS: &[(&str, &[&str])] = &[
    ("rng", &[]),
    ("linalg", &[]),
    ("sim", &[]),
    ("metrics", &[]),
    ("cli", &[]),
    ("proptest_lite", &["rng"]),
    ("stats", &["rng", "straggler"]),
    ("straggler", &["rng"]),
    ("data", &["linalg", "rng"]),
    ("model", &["data", "linalg"]),
    ("grad", &["data", "exec", "linalg", "model", "runtime"]),
    ("theory", &["stats"]),
    ("policy", &["stats", "theory"]),
    ("comm", &["rng", "straggler"]),
    ("trace", &["metrics", "rng", "straggler"]),
    (
        "coding",
        &[
            "comm", "data", "engine", "grad", "linalg", "master",
            "metrics", "model", "policy", "rng", "straggler", "trace",
        ],
    ),
    (
        "engine",
        &[
            "coding", "comm", "data", "exec", "grad", "linalg",
            "master", "metrics", "model", "policy", "rng", "sim",
            "stats", "straggler", "trace",
        ],
    ),
    (
        "master",
        &[
            "comm", "data", "engine", "grad", "metrics", "model",
            "policy", "straggler", "trace",
        ],
    ),
    (
        "async_sgd",
        &[
            "comm", "data", "engine", "grad", "metrics", "model",
            "sim", "straggler", "trace",
        ],
    ),
    (
        "exec",
        &[
            "async_sgd", "comm", "data", "engine", "grad", "linalg",
            "master", "metrics", "model", "policy", "sim",
            "straggler", "trace",
        ],
    ),
    ("transformer", &["data", "grad", "linalg", "rng", "runtime"]),
    ("runtime", &["config", "data", "grad", "linalg"]),
];

/// Crate modules no library module may import, table or not.
const GLOBAL_FORBIDDEN: &[&str] = &["cli", "analysis"];

/// Check live `use crate::X` declarations in `sf` (top-level module
/// `top`) against [`ALLOWED_IMPORTS`] and the global guards.
pub(super) fn l001(sf: &SourceFile, top: &str, out: &mut Vec<Finding>) {
    let allowed = ALLOWED_IMPORTS
        .iter()
        .find(|(m, _)| *m == top)
        .map(|(_, list)| *list);
    for (line, target) in live_crate_imports(sf) {
        if target == top {
            continue;
        }
        let globally_forbidden = top != "main"
            && top != "analysis"
            && GLOBAL_FORBIDDEN.contains(&target.as_str());
        let table_violation = match allowed {
            Some(list) => !list.contains(&target.as_str()),
            None => false,
        };
        if !(globally_forbidden || table_violation) {
            continue;
        }
        out.push(Finding {
            rule: "L001",
            file: sf.rel.clone(),
            line,
            message: format!(
                "layering: `{top}` must not import `crate::{target}`"
            ),
            hint: "the dependency table is \
                   analysis/layering.rs::ALLOWED_IMPORTS; move shared \
                   code down a layer or change the table in the same \
                   commit, deliberately"
                .to_string(),
            suppressed: false,
        });
    }
}

/// Extract `(line, first_path_segment)` for every live (non-test)
/// `use crate::X...` declaration, including grouped forms like
/// `use crate::{a::B, c::D};` (which yields `a` and `c`).
fn live_crate_imports(sf: &SourceFile) -> Vec<(u32, String)> {
    let toks = &sf.tokens;
    let mut out = Vec::new();
    let ident = |i: usize, s: &str| {
        toks.get(i)
            .map(|t| t.kind == TokenKind::Ident && t.text == s)
            .unwrap_or(false)
    };
    let punct = |i: usize, s: &str| {
        toks.get(i)
            .map(|t| t.kind == TokenKind::Punct && t.text == s)
            .unwrap_or(false)
    };
    for i in 0..toks.len() {
        if !(ident(i, "use")
            && ident(i + 1, "crate")
            && punct(i + 2, ":")
            && punct(i + 3, ":"))
        {
            continue;
        }
        let line = toks[i].line;
        if sf.is_test_line(line) {
            continue;
        }
        let first = i + 4;
        if let Some(t) = toks.get(first) {
            if t.kind == TokenKind::Ident {
                out.push((line, t.text.clone()));
                continue;
            }
        }
        if punct(first, "{") {
            // Grouped import: take the leading ident of each
            // depth-1 comma-separated path.
            let mut depth = 1usize;
            let mut j = first + 1;
            let mut at_path_start = true;
            while j < toks.len() && depth > 0 {
                let t = &toks[j];
                if t.kind == TokenKind::Punct {
                    match t.text.as_str() {
                        "{" => depth += 1,
                        "}" => depth -= 1,
                        "," if depth == 1 => at_path_start = true,
                        _ => {}
                    }
                } else if t.kind == TokenKind::Ident && at_path_start {
                    out.push((t.line, t.text.clone()));
                    at_path_start = false;
                }
                j += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn imports(src: &str) -> Vec<String> {
        let sf = SourceFile::parse("rust/src/x/mod.rs", src).unwrap();
        live_crate_imports(&sf).into_iter().map(|(_, m)| m).collect()
    }

    fn check(rel: &str, top: &str, src: &str) -> Vec<Finding> {
        let sf = SourceFile::parse(rel, src).unwrap();
        let mut out = Vec::new();
        l001(&sf, top, &mut out);
        out
    }

    #[test]
    fn extracts_plain_and_grouped_imports() {
        let src = "\
use crate::rng::Pcg64;
use crate::{data::DataSet, model::Model};
use std::collections::BTreeMap;
";
        assert_eq!(imports(src), ["rng", "data", "model"]);
    }

    #[test]
    fn test_region_imports_are_exempt() {
        let src = "\
use crate::rng::Pcg64;

#[cfg(test)]
mod tests {
    use crate::sweep::derive_seed;
}
";
        assert_eq!(imports(src), ["rng"]);
    }

    #[test]
    fn table_violation_fires() {
        let src = "use crate::sweep::derive_seed;\n";
        let fs = check("rust/src/engine/mod.rs", "engine", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "L001");
        assert!(fs[0].message.contains("crate::sweep"));
    }

    #[test]
    fn allowed_import_is_clean() {
        let src = "use crate::comm::CommStream;\n";
        assert!(check("rust/src/engine/mod.rs", "engine", src)
            .is_empty());
    }

    #[test]
    fn unlisted_module_is_unconstrained_except_globals() {
        let src = "use crate::engine::EngineCore;\n";
        assert!(check("rust/src/sweep/mod.rs", "sweep", src)
            .is_empty());
        let bad = "use crate::analysis::LintReport;\n";
        assert_eq!(check("rust/src/sweep/mod.rs", "sweep", bad).len(), 1);
        let cli = "use crate::cli::Args;\n";
        assert_eq!(
            check("rust/src/engine/mod.rs", "engine", cli).len(),
            1
        );
    }

    #[test]
    fn engine_may_import_stats_but_stats_not_engine() {
        // The fastpath gather's order-statistics sampler made
        // engine → stats a sanctioned edge; the reverse stays illegal.
        let src = "use crate::stats::OrderStatSampler;\n";
        assert!(check("rust/src/engine/fastpath.rs", "engine", src)
            .is_empty());
        let rev = "use crate::engine::FastpathGather;\n";
        assert_eq!(
            check("rust/src/stats/order_sampler.rs", "stats", rev).len(),
            1
        );
    }

    #[test]
    fn stats_may_import_straggler_but_not_the_reverse() {
        // The class-merge sampler keys classes off delay-model
        // attributes, so stats → straggler is a sanctioned edge; the
        // delay models must never reach up into the statistics layer.
        let fwd = "use crate::straggler::DelayModel;\n";
        assert!(check("rust/src/stats/class_sampler.rs", "stats", fwd)
            .is_empty());
        let rev = "use crate::stats::ClassOrderSampler;\n";
        assert_eq!(
            check("rust/src/straggler/models.rs", "straggler", rev).len(),
            1
        );
    }

    #[test]
    fn engine_may_import_comm_but_not_the_reverse() {
        // The priced fastpath composes uplink constants and the FIFO
        // ingress chain, so engine → comm is a sanctioned edge; the
        // comm substrate must stay engine-agnostic.
        let fwd = "use crate::comm::IngressModel;\n";
        assert!(check("rust/src/engine/fastpath.rs", "engine", fwd)
            .is_empty());
        let rev = "use crate::engine::EngineCore;\n";
        assert_eq!(
            check("rust/src/comm/link.rs", "comm", rev).len(),
            1
        );
    }

    #[test]
    fn hot_path_may_import_exec_but_leaves_may_not() {
        // Intra-round parallelism made engine → exec and grad → exec
        // sanctioned edges (Parallelism tokens, block helpers, the
        // scratch arena). The reverse direction from true leaves stays
        // illegal: linalg and rng must not know about the pool.
        let par = "use crate::exec::Parallelism;\n";
        assert!(check("rust/src/engine/core.rs", "engine", par)
            .is_empty());
        assert!(check("rust/src/grad/native.rs", "grad", par)
            .is_empty());
        assert_eq!(
            check("rust/src/linalg/ops.rs", "linalg", par).len(),
            1
        );
        assert_eq!(check("rust/src/rng/mod.rs", "rng", par).len(), 1);
    }

    #[test]
    fn leaf_modules_import_nothing() {
        let src = "use crate::stats::RunningStats;\n";
        assert_eq!(check("rust/src/rng/mod.rs", "rng", src).len(), 1);
    }

    #[test]
    fn table_has_no_duplicate_modules_and_is_sorted_within() {
        let mut seen = std::collections::BTreeSet::new();
        for (m, list) in ALLOWED_IMPORTS {
            assert!(seen.insert(*m), "duplicate table entry {m}");
            let mut sorted = list.to_vec();
            sorted.sort_unstable();
            assert_eq!(&sorted, list, "unsorted allowlist for {m}");
        }
    }
}
