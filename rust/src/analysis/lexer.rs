//! A lightweight Rust lexer for the in-repo lint pass.
//!
//! Not a full parser: `detlint` rules are token-sequence patterns, so
//! all the lexer must get *exactly* right is what is and is not a
//! token — comments (line, block, nested block), string literals
//! (plain, raw `r#"..."#` with any hash count, byte), char literals vs
//! lifetimes, and numeric literals — each carrying the 1-based source
//! line so findings are clickable `file:line` spans. `//` comments are
//! kept (not tokenized) because suppression pragmas live in them.

/// Kind of a lexical token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (the rules match on the text).
    Ident,
    /// Integer literal, including hex/octal/binary and suffixed forms.
    IntLit,
    /// Float literal (`1.0`, `5e-4`, `2.5f64`).
    FloatLit,
    /// String literal; `text` holds the *cooked* value (escapes
    /// processed, `\`-newline continuations joined) so schema checks
    /// compare real values, not source spelling.
    StrLit,
    /// Character or byte literal.
    CharLit,
    /// Lifetime (`'a`, `'static`), without the leading quote.
    Lifetime,
    /// Any other single character (`(`, `:`, `!`, ...).
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token {
    /// What kind of token this is.
    pub kind: TokenKind,
    /// Token text (cooked value for [`TokenKind::StrLit`]).
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
}

/// One `//` comment (suppression pragmas are only recognized here).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LineComment {
    /// 1-based line the comment starts on.
    pub line: u32,
    /// Text after the `//` (includes any further `/` of `///`).
    pub text: String,
    /// True when code tokens precede the comment on its line (a
    /// trailing comment).
    pub trailing: bool,
}

/// Lexer failure: an unterminated string, char, or block comment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LexError {
    /// 1-based line where the offending construct started.
    pub line: u32,
    /// What went wrong.
    pub message: String,
}

impl std::fmt::Display for LexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for LexError {}

/// Lexer output: the token stream plus every `//` comment.
#[derive(Debug, Clone, Default)]
pub struct Lexed {
    /// Tokens in source order.
    pub tokens: Vec<Token>,
    /// `//` comments in source order.
    pub comments: Vec<LineComment>,
}

/// Lex one source file.
pub fn lex(src: &str) -> Result<Lexed, LexError> {
    let mut lx = Lexer {
        chars: src.chars().collect(),
        pos: 0,
        line: 1,
        line_had_token: false,
        out: Lexed::default(),
    };
    lx.run()?;
    Ok(lx.out)
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    line_had_token: bool,
    out: Lexed,
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

impl Lexer {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn peek_at(&self, off: usize) -> Option<char> {
        self.chars.get(self.pos + off).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek()?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
            self.line_had_token = false;
        }
        Some(c)
    }

    fn push(&mut self, kind: TokenKind, text: String, line: u32) {
        self.line_had_token = true;
        self.out.tokens.push(Token { kind, text, line });
    }

    fn err(&self, line: u32, message: &str) -> LexError {
        LexError { line, message: message.to_string() }
    }

    fn run(&mut self) -> Result<(), LexError> {
        while let Some(c) = self.peek() {
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek_at(1) == Some('/') {
                self.line_comment();
            } else if c == '/' && self.peek_at(1) == Some('*') {
                self.block_comment()?;
            } else if c == '"' {
                self.cooked_string()?;
            } else if (c == 'r' || c == 'b') && self.string_prefix()? {
                // raw string / byte string / raw identifier consumed
            } else if c == '\'' {
                self.char_or_lifetime()?;
            } else if c.is_ascii_digit() {
                self.number();
            } else if is_ident_start(c) {
                self.ident();
            } else {
                let line = self.line;
                self.bump();
                self.push(TokenKind::Punct, c.to_string(), line);
            }
        }
        Ok(())
    }

    fn line_comment(&mut self) {
        let line = self.line;
        let trailing = self.line_had_token;
        self.bump();
        self.bump(); // the two slashes
        let mut text = String::new();
        while let Some(c) = self.peek() {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.out.comments.push(LineComment { line, text, trailing });
    }

    fn block_comment(&mut self) -> Result<(), LexError> {
        let start = self.line;
        self.bump();
        self.bump(); // "/*"
        let mut depth = 1usize;
        while depth > 0 {
            match (self.peek(), self.peek_at(1)) {
                (Some('/'), Some('*')) => {
                    depth += 1;
                    self.bump();
                    self.bump();
                }
                (Some('*'), Some('/')) => {
                    depth -= 1;
                    self.bump();
                    self.bump();
                }
                (Some(_), _) => {
                    self.bump();
                }
                (None, _) => {
                    return Err(self
                        .err(start, "unterminated block comment"));
                }
            }
        }
        Ok(())
    }

    /// A plain `"..."` string with escape cooking (handles `\"`, the
    /// standard named escapes, `\xNN`, `\u{...}`, and `\`-newline
    /// continuation, which joins lines and strips leading whitespace).
    fn cooked_string(&mut self) -> Result<(), LexError> {
        let start = self.line;
        self.bump(); // opening quote
        let mut text = String::new();
        loop {
            match self.bump() {
                None => {
                    return Err(
                        self.err(start, "unterminated string literal")
                    )
                }
                Some('"') => break,
                Some('\\') => match self.bump() {
                    None => {
                        return Err(self
                            .err(start, "unterminated string escape"))
                    }
                    Some('n') => text.push('\n'),
                    Some('r') => text.push('\r'),
                    Some('t') => text.push('\t'),
                    Some('0') => text.push('\0'),
                    Some('x') => {
                        let mut v = 0u32;
                        for _ in 0..2 {
                            if let Some(d) =
                                self.peek().and_then(|c| c.to_digit(16))
                            {
                                v = v * 16 + d;
                                self.bump();
                            }
                        }
                        if let Some(c) = char::from_u32(v) {
                            text.push(c);
                        }
                    }
                    Some('u') => {
                        // \u{XXXX}
                        if self.peek() == Some('{') {
                            self.bump();
                            let mut v = 0u32;
                            while let Some(d) =
                                self.peek().and_then(|c| c.to_digit(16))
                            {
                                v = v * 16 + d;
                                self.bump();
                            }
                            if self.peek() == Some('}') {
                                self.bump();
                            }
                            if let Some(c) = char::from_u32(v) {
                                text.push(c);
                            }
                        }
                    }
                    Some('\n') => {
                        // Line continuation: skip the indentation of
                        // the next line (Rust's behaviour).
                        while matches!(
                            self.peek(),
                            Some(' ') | Some('\t') | Some('\r')
                                | Some('\n')
                        ) {
                            self.bump();
                        }
                    }
                    Some(other) => text.push(other),
                },
                Some(c) => text.push(c),
            }
        }
        self.push(TokenKind::StrLit, text, start);
        Ok(())
    }

    /// Handle `r"..."` / `r#"..."#` raw strings, `b"..."` byte
    /// strings, `b'x'` byte chars, `br#"..."#`, and `r#ident` raw
    /// identifiers. Returns false when the `r`/`b` is just the start
    /// of a plain identifier.
    fn string_prefix(&mut self) -> Result<bool, LexError> {
        let c = self.peek().unwrap();
        if c == 'r' {
            match self.peek_at(1) {
                Some('"') => {
                    self.bump(); // r
                    self.raw_string()?;
                    return Ok(true);
                }
                Some('#') => {
                    // Count hashes; a quote after them means a raw
                    // string, an identifier char means `r#ident`.
                    let mut n = 1;
                    while self.peek_at(1 + n) == Some('#') {
                        n += 1;
                    }
                    if self.peek_at(1 + n) == Some('"') {
                        self.bump(); // r
                        self.raw_string()?;
                        return Ok(true);
                    }
                    if n == 1
                        && self
                            .peek_at(2)
                            .map(is_ident_start)
                            .unwrap_or(false)
                    {
                        self.bump();
                        self.bump(); // r#
                        self.ident();
                        return Ok(true);
                    }
                    return Ok(false);
                }
                _ => return Ok(false),
            }
        }
        // c == 'b'
        match self.peek_at(1) {
            Some('"') => {
                self.bump(); // b
                self.cooked_string()?;
                Ok(true)
            }
            Some('\'') => {
                self.bump(); // b
                self.char_or_lifetime()?;
                Ok(true)
            }
            Some('r')
                if matches!(
                    self.peek_at(2),
                    Some('"') | Some('#')
                ) =>
            {
                self.bump();
                self.bump(); // br
                self.raw_string()?;
                Ok(true)
            }
            _ => Ok(false),
        }
    }

    /// At the `#`s or `"` of a raw string (the `r`/`br` prefix is
    /// already consumed).
    fn raw_string(&mut self) -> Result<(), LexError> {
        let start = self.line;
        let mut hashes = 0usize;
        while self.peek() == Some('#') {
            hashes += 1;
            self.bump();
        }
        if self.peek() != Some('"') {
            return Err(self.err(start, "malformed raw string start"));
        }
        self.bump();
        let mut text = String::new();
        'scan: loop {
            match self.bump() {
                None => {
                    return Err(self
                        .err(start, "unterminated raw string literal"))
                }
                Some('"') => {
                    let mut ok = true;
                    for i in 0..hashes {
                        if self.peek_at(i) != Some('#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        for _ in 0..hashes {
                            self.bump();
                        }
                        break 'scan;
                    }
                    text.push('"');
                }
                Some(c) => text.push(c),
            }
        }
        self.push(TokenKind::StrLit, text, start);
        Ok(())
    }

    /// Disambiguate `'a'` (char literal) from `'a` (lifetime).
    fn char_or_lifetime(&mut self) -> Result<(), LexError> {
        let start = self.line;
        self.bump(); // opening quote
        match self.peek() {
            None => Err(self.err(start, "unterminated char literal")),
            Some('\\') => {
                // Escaped char literal: consume the escape then the
                // closing quote.
                self.bump();
                let esc = self
                    .bump()
                    .ok_or_else(|| {
                        self.err(start, "unterminated char escape")
                    })?;
                let mut text = String::from(esc);
                if esc == 'x' || esc == 'u' {
                    while let Some(c) = self.peek() {
                        if c == '\'' {
                            break;
                        }
                        text.push(c);
                        self.bump();
                    }
                }
                if self.peek() == Some('\'') {
                    self.bump();
                    self.push(TokenKind::CharLit, text, start);
                    Ok(())
                } else {
                    Err(self.err(start, "unterminated char literal"))
                }
            }
            Some(c) if is_ident_start(c) => {
                // Could be 'x' (char) or 'lifetime. Scan the ident
                // run; a closing quote right after it means char.
                let mut n = 0;
                while self
                    .peek_at(n)
                    .map(is_ident_continue)
                    .unwrap_or(false)
                {
                    n += 1;
                }
                if self.peek_at(n) == Some('\'') {
                    let mut text = String::new();
                    for _ in 0..n {
                        text.push(self.bump().unwrap());
                    }
                    self.bump(); // closing quote
                    self.push(TokenKind::CharLit, text, start);
                } else {
                    let mut text = String::new();
                    for _ in 0..n {
                        text.push(self.bump().unwrap());
                    }
                    self.push(TokenKind::Lifetime, text, start);
                }
                Ok(())
            }
            Some(c) => {
                // Punctuation char literal like '(' or ' '.
                self.bump();
                if self.peek() == Some('\'') {
                    self.bump();
                    self.push(
                        TokenKind::CharLit,
                        c.to_string(),
                        start,
                    );
                    Ok(())
                } else {
                    Err(self.err(start, "unterminated char literal"))
                }
            }
        }
    }

    fn number(&mut self) {
        let start = self.line;
        let mut text = String::new();
        while let Some(c) = self.peek() {
            if is_ident_continue(c) {
                text.push(c);
                self.bump();
            } else if (c == '+' || c == '-')
                && matches!(
                    text.chars().last(),
                    Some('e') | Some('E')
                )
                && !text.starts_with("0x")
                && !text.starts_with("0X")
                && self
                    .peek_at(1)
                    .map(|d| d.is_ascii_digit())
                    .unwrap_or(false)
            {
                text.push(c);
                self.bump();
            } else if c == '.'
                && !text.contains('.')
                && self
                    .peek_at(1)
                    .map(|d| d.is_ascii_digit())
                    .unwrap_or(false)
            {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        let radix_prefixed = text.starts_with("0x")
            || text.starts_with("0X")
            || text.starts_with("0b")
            || text.starts_with("0o");
        let is_float = !radix_prefixed
            && (text.contains('.')
                || text.contains('e')
                || text.contains('E')
                || text.ends_with("f32")
                || text.ends_with("f64"));
        let kind =
            if is_float { TokenKind::FloatLit } else { TokenKind::IntLit };
        self.push(kind, text, start);
    }

    fn ident(&mut self) {
        let start = self.line;
        let mut text = String::new();
        while let Some(c) = self.peek() {
            if is_ident_continue(c) {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push(TokenKind::Ident, text, start);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokenKind, String)> {
        lex(src)
            .unwrap()
            .tokens
            .into_iter()
            .map(|t| (t.kind, t.text))
            .collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let toks = kinds("let x = a.partial_cmp(b);");
        assert_eq!(toks[0], (TokenKind::Ident, "let".into()));
        assert_eq!(toks[1], (TokenKind::Ident, "x".into()));
        assert_eq!(toks[2], (TokenKind::Punct, "=".into()));
        assert!(toks
            .iter()
            .any(|t| t == &(TokenKind::Ident, "partial_cmp".into())));
    }

    #[test]
    fn numeric_literal_kinds() {
        // 0xC0DE contains an `E` but is an integer; f-suffixes float.
        let toks = kinds("0xC0DE 42 1_000u64 1.5 5e-4 2f64 0b1010");
        let want = [
            TokenKind::IntLit,
            TokenKind::IntLit,
            TokenKind::IntLit,
            TokenKind::FloatLit,
            TokenKind::FloatLit,
            TokenKind::FloatLit,
            TokenKind::IntLit,
        ];
        let got: Vec<TokenKind> =
            toks.iter().map(|(k, _)| *k).collect();
        assert_eq!(got, want, "{toks:?}");
    }

    #[test]
    fn range_dots_are_not_float_parts() {
        let toks = kinds("for i in 0..n {}");
        assert!(toks.contains(&(TokenKind::IntLit, "0".into())));
        assert!(toks.contains(&(TokenKind::Punct, ".".into())));
        assert!(toks.contains(&(TokenKind::Ident, "n".into())));
    }

    #[test]
    fn slashes_inside_string_literals_are_not_comments() {
        let out = lex("let url = \"http://example.com // not a comment\"; x")
            .unwrap();
        assert!(out.comments.is_empty());
        assert!(out
            .tokens
            .iter()
            .any(|t| t.kind == TokenKind::StrLit
                && t.text.contains("// not a comment")));
        assert!(out
            .tokens
            .iter()
            .any(|t| t.kind == TokenKind::Ident && t.text == "x"));
    }

    #[test]
    fn nested_block_comments() {
        let out =
            lex("a /* outer /* inner */ still comment */ b").unwrap();
        let idents: Vec<&str> = out
            .tokens
            .iter()
            .filter(|t| t.kind == TokenKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["a", "b"]);
        assert!(lex("/* unterminated /* nested */").is_err());
    }

    #[test]
    fn raw_strings_with_hashes() {
        let out = lex(r###"let s = r#"quote " and // slash"# ; y"###)
            .unwrap();
        assert!(out.comments.is_empty());
        let s = out
            .tokens
            .iter()
            .find(|t| t.kind == TokenKind::StrLit)
            .unwrap();
        assert_eq!(s.text, "quote \" and // slash");
        assert!(out
            .tokens
            .iter()
            .any(|t| t.kind == TokenKind::Ident && t.text == "y"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let toks = kinds("'a' 'x 'static '\\n' ' ' b'z' '_'");
        assert_eq!(
            toks,
            vec![
                (TokenKind::CharLit, "a".into()),
                (TokenKind::Lifetime, "x".into()),
                (TokenKind::Lifetime, "static".into()),
                (TokenKind::CharLit, "n".into()),
                (TokenKind::CharLit, " ".into()),
                (TokenKind::CharLit, "z".into()),
                (TokenKind::CharLit, "_".into()),
            ]
        );
    }

    #[test]
    fn string_escape_cooking_and_continuation() {
        let src = "let s = \"ab\\\n      cd,\\\"q\\\"\";";
        let out = lex(src).unwrap();
        let s = out
            .tokens
            .iter()
            .find(|t| t.kind == TokenKind::StrLit)
            .unwrap();
        // The backslash-newline joins the halves and strips the
        // second line's indentation, exactly like rustc.
        assert_eq!(s.text, "abcd,\"q\"");
    }

    #[test]
    fn comments_record_line_and_trailing() {
        let src = "let a = 1; // trailing note\n// own line\nlet b = 2;";
        let out = lex(src).unwrap();
        assert_eq!(out.comments.len(), 2);
        assert_eq!(out.comments[0].line, 1);
        assert!(out.comments[0].trailing);
        assert_eq!(out.comments[1].line, 2);
        assert!(!out.comments[1].trailing);
        assert_eq!(out.comments[1].text.trim(), "own line");
    }

    #[test]
    fn lines_are_tracked_across_multiline_tokens() {
        let src = "a\n/* two\nlines */\nb \"x\ny\" c";
        let out = lex(src).unwrap();
        let b = out
            .tokens
            .iter()
            .find(|t| t.text == "b")
            .unwrap();
        assert_eq!(b.line, 4);
        let c = out
            .tokens
            .iter()
            .find(|t| t.text == "c")
            .unwrap();
        assert_eq!(c.line, 5);
    }

    #[test]
    fn raw_identifiers_lex_as_idents() {
        let toks = kinds("r#fn r#type normal");
        assert_eq!(
            toks,
            vec![
                (TokenKind::Ident, "fn".into()),
                (TokenKind::Ident, "type".into()),
                (TokenKind::Ident, "normal".into()),
            ]
        );
    }

    #[test]
    fn unterminated_string_is_an_error() {
        let err = lex("let s = \"no end").unwrap_err();
        assert!(err.message.contains("unterminated"), "{err}");
    }
}
