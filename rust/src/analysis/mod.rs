//! `detlint` — the in-repo determinism & layering static-analysis
//! pass (`adasgd lint`).
//!
//! The repo's core promise is bitwise determinism: `--jobs 1` ≡
//! `--jobs N`, simulator ≡ threaded executor, record ≡ replay. Those
//! guarantees are protected by equivalence tests, but the failure
//! modes that break them (a hash-ordered traversal, a wall-clock
//! read, a hard-coded seed) are easy to introduce far from any test.
//! This module scans the source itself, so the hazard is caught at
//! the line that introduces it, in CI, with a fix hint.
//!
//! # Rules
//!
//! | id | forbids |
//! |------|---------|
//! | D001 | `partial_cmp(..).unwrap()`/`.expect()` float ordering |
//! | D002 | `HashMap`/`HashSet` in deterministic modules |
//! | D003 | wall-clock reads outside `bench_harness` |
//! | D004 | literal-seeded RNG construction |
//! | D005 | `println!`/`eprintln!` in library modules |
//! | D006 | `thread::spawn` outside `exec` |
//! | L001 | `use crate::X` edges outside the layering table |
//! | S001 | CSV / trace schema drift between writer and reader |
//!
//! `E001` is reserved for files the [`lexer`] cannot process.
//!
//! # Suppression
//!
//! A finding is silenced only by an explicit inline pragma on the
//! same line or the line above:
//!
//! ```text
//! // wall clock feeds the reported stat only. detlint: allow(D003)
//! let start = Instant::now();
//! ```
//!
//! Suppressed findings are still reported and counted — the pragma
//! makes the exception visible; it cannot hide the site.
//!
//! # Scan scope
//!
//! [`lint_root`] walks `rust/src`, `rust/tests`, `benches`, and
//! `examples` under the repo root, in sorted order, skipping
//! `lint_fixtures` (intentionally-bad test inputs), `vendor`,
//! `target`, and `.git`. The analyzer is std-only and never imported
//! by library modules (L001 enforces that direction).

pub mod lexer;
mod layering;
mod report;
mod rules;
mod schema;
mod source;

pub use layering::ALLOWED_IMPORTS;
pub use report::{Finding, LintReport, RuleInfo, RULES};
pub use rules::{check_file, top_module, DET_MODULES};
pub use schema::CSV_SCHEMA_VERSIONS;
pub use source::SourceFile;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Directories the walker never descends into.
const SKIP_DIRS: &[&str] = &["lint_fixtures", "vendor", "target", ".git"];

/// Directories scanned, relative to the repo root.
const SCAN_ROOTS: &[&str] =
    &["rust/src", "rust/tests", "benches", "examples"];

/// Lint every `.rs` file under `root`'s scan roots.
pub fn lint_root(root: &Path) -> std::io::Result<LintReport> {
    let mut files = Vec::new();
    for scan in SCAN_ROOTS {
        let dir = root.join(scan);
        if dir.is_dir() {
            collect_rs_files(&dir, &mut files)?;
        }
    }
    files.sort();
    let mut sources = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = std::fs::read_to_string(path)?;
        sources.push((rel, text));
    }
    Ok(lint_sources(&sources))
}

/// Lint an in-memory workspace of `(repo-relative path, text)` pairs.
/// This is the whole pipeline behind [`lint_root`]; tests feed it
/// fixture files directly.
pub fn lint_sources(sources: &[(String, String)]) -> LintReport {
    let mut findings = Vec::new();
    let mut workspace: BTreeMap<String, SourceFile> = BTreeMap::new();
    for (rel, text) in sources {
        match SourceFile::parse(rel, text) {
            Ok(sf) => {
                findings.extend(rules::check_file(&sf));
                workspace.insert(sf.rel.clone(), sf);
            }
            Err(e) => findings.push(Finding {
                rule: "E001",
                file: rel.replace('\\', "/"),
                line: e.line,
                message: format!("lexer error: {e}"),
                hint: "fix the source (or the lexer, if the syntax \
                       is legal Rust it mishandles)"
                    .to_string(),
                suppressed: false,
            }),
        }
    }
    let mut cross = Vec::new();
    schema::s001(&workspace, &mut cross);
    for f in &mut cross {
        if let Some(sf) = workspace.get(&f.file) {
            if sf.allowed(f.rule, f.line) {
                f.suppressed = true;
            }
        }
    }
    findings.extend(cross);
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    LintReport { findings, files_scanned: sources.len() }
}

fn collect_rs_files(
    dir: &Path,
    out: &mut Vec<PathBuf>,
) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if !SKIP_DIRS.contains(&name.as_ref()) {
                collect_rs_files(&path, out)?;
            }
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(rel: &str, text: &str) -> (String, String) {
        (rel.to_string(), text.to_string())
    }

    #[test]
    fn lint_sources_runs_per_file_and_cross_file_rules() {
        let report = lint_sources(&[
            src(
                "rust/src/engine/x.rs",
                "use std::collections::HashMap;\n",
            ),
            src(
                "rust/src/metrics/csv.rs",
                "pub const CSV_COLUMNS: &str = \"label\";\n\
                 fn w() { let _ = \"# adasgd run series v4\"; }\n",
            ),
        ]);
        assert_eq!(report.files_scanned, 2);
        let rules: Vec<&str> =
            report.findings.iter().map(|f| f.rule).collect();
        assert!(rules.contains(&"D002"));
        assert!(rules.contains(&"S001"));
    }

    #[test]
    fn unlexable_file_reports_e001() {
        let report = lint_sources(&[src(
            "rust/src/stats/x.rs",
            "fn f() { let s = \"unterminated; }\n",
        )]);
        assert_eq!(report.findings.len(), 1);
        assert_eq!(report.findings[0].rule, "E001");
        assert_eq!(report.active_count(), 1);
    }

    #[test]
    fn findings_are_sorted_by_file_then_line() {
        let report = lint_sources(&[
            src(
                "rust/src/trace/z.rs",
                "use std::collections::HashSet;\n",
            ),
            src(
                "rust/src/engine/a.rs",
                "fn f() { println!(\"x\"); }\n\
                 use std::collections::HashMap;\n",
            ),
        ]);
        let keys: Vec<(&str, u32)> = report
            .findings
            .iter()
            .map(|f| (f.file.as_str(), f.line))
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }
}
