//! Findings, rule metadata, and report rendering for `adasgd lint`.

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule id (`D001` ... `D006`, `L001`, `S001`, or `E001` for a
    /// file the lexer could not process).
    pub rule: &'static str,
    /// Repo-relative path with forward slashes.
    pub file: String,
    /// 1-based line of the finding.
    pub line: u32,
    /// What is wrong.
    pub message: String,
    /// How to fix it.
    pub hint: String,
    /// True when an inline `// detlint: allow(<rule>)` pragma covers
    /// the finding. Suppressed findings are still reported and
    /// counted — the pragma makes the exception visible, it does not
    /// hide the site.
    pub suppressed: bool,
}

/// Static description of one rule, for `--help`-style docs.
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    /// Rule id.
    pub id: &'static str,
    /// One-line statement of what the rule forbids.
    pub summary: &'static str,
    /// The repo guarantee the rule protects.
    pub protects: &'static str,
}

/// The registered rule set, in id order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "D001",
        summary: "no partial_cmp(..).unwrap() float ordering; \
                  use total_cmp",
        protects: "NaN inputs must reorder deterministically instead \
                   of panicking mid-run",
    },
    RuleInfo {
        id: "D002",
        summary: "no HashMap/HashSet in deterministic modules \
                  (engine, sweep, trace, sim, comm, coding)",
        protects: "iteration order must not leak into trajectories, \
                   CSVs, or traces",
    },
    RuleInfo {
        id: "D003",
        summary: "no wall-clock reads (Instant::now, SystemTime) in \
                  library code",
        protects: "the virtual clock alone drives results; wall time \
                   is bench/cluster-stat territory",
    },
    RuleInfo {
        id: "D004",
        summary: "no literal-seeded RNG construction in library code",
        protects: "all streams derive from the run seed via \
                   RngStreams/derive_seed, so --jobs 1 == --jobs N",
    },
    RuleInfo {
        id: "D005",
        summary: "no println!/eprintln! in library modules",
        protects: "library output goes through metrics/recorders; \
                   stdout belongs to the CLI and benches",
    },
    RuleInfo {
        id: "D006",
        summary: "no thread::spawn outside exec",
        protects: "one shared pool: sweep- and intra-round \
                   parallelism compose without oversubscription, and \
                   every reduction stays fixed-order",
    },
    RuleInfo {
        id: "L001",
        summary: "layering: core modules must not import \
                  cli/coordinator/sweep/bench_harness; rng and linalg \
                  stay leaf",
        protects: "the engine stays embeddable and the dependency \
                   graph acyclic",
    },
    RuleInfo {
        id: "S001",
        summary: "schema drift: CSV_COLUMNS vs registered schema \
                  version; trace kind tags vs the reader skip table",
        protects: "recorded CSVs and traces stay readable by the \
                   committed readers",
    },
];

/// Result of a lint run.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// Every finding, suppressed ones included (flagged).
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    /// Findings not covered by a pragma; these fail the CI gate.
    pub fn active(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| !f.suppressed)
    }

    /// Number of active (gate-failing) findings.
    pub fn active_count(&self) -> usize {
        self.active().count()
    }

    /// Number of pragma-suppressed findings.
    pub fn suppressed_count(&self) -> usize {
        self.findings.len() - self.active_count()
    }

    /// Human-readable report.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            if f.suppressed {
                continue;
            }
            out.push_str(&format!(
                "{}:{}: {} {}\n    hint: {}\n",
                f.file, f.line, f.rule, f.message, f.hint
            ));
        }
        for f in &self.findings {
            if f.suppressed {
                out.push_str(&format!(
                    "{}:{}: {} suppressed by pragma: {}\n",
                    f.file, f.line, f.rule, f.message
                ));
            }
        }
        let active = self.active_count();
        let verdict = if active == 0 { "clean" } else { "FAIL" };
        out.push_str(&format!(
            "detlint: {} — {} finding(s), {} suppressed by pragma, \
             {} file(s) scanned\n",
            verdict,
            active,
            self.suppressed_count(),
            self.files_scanned
        ));
        out
    }

    /// Machine-readable report (the CI artifact).
    pub fn render_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n  \"findings\": [\n");
        for (i, f) in self.findings.iter().enumerate() {
            let sep = if i + 1 < self.findings.len() { "," } else { "" };
            out.push_str(&format!(
                "    {{\"rule\": \"{}\", \"file\": \"{}\", \
                 \"line\": {}, \"message\": \"{}\", \
                 \"hint\": \"{}\", \"suppressed\": {}}}{}\n",
                f.rule,
                json_escape(&f.file),
                f.line,
                json_escape(&f.message),
                json_escape(&f.hint),
                f.suppressed,
                sep
            ));
        }
        out.push_str("  ],\n");
        out.push_str(&format!(
            "  \"active\": {},\n  \"suppressed\": {},\n  \
             \"files_scanned\": {}\n}}\n",
            self.active_count(),
            self.suppressed_count(),
            self.files_scanned
        ));
        out
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> LintReport {
        LintReport {
            findings: vec![
                Finding {
                    rule: "D001",
                    file: "rust/src/x.rs".to_string(),
                    line: 7,
                    message: "NaN-unsafe float sort".to_string(),
                    hint: "use total_cmp".to_string(),
                    suppressed: false,
                },
                Finding {
                    rule: "D003",
                    file: "rust/src/y.rs".to_string(),
                    line: 12,
                    message: "wall-clock read".to_string(),
                    hint: "use the virtual clock".to_string(),
                    suppressed: true,
                },
            ],
            files_scanned: 2,
        }
    }

    #[test]
    fn counts_split_active_and_suppressed() {
        let r = sample();
        assert_eq!(r.active_count(), 1);
        assert_eq!(r.suppressed_count(), 1);
    }

    #[test]
    fn text_report_mentions_both_classes() {
        let text = sample().render_text();
        assert!(text.contains("rust/src/x.rs:7: D001"));
        assert!(text.contains("hint: use total_cmp"));
        assert!(text.contains("suppressed by pragma"));
        assert!(text.contains("FAIL"));
        let clean = LintReport { findings: vec![], files_scanned: 3 }
            .render_text();
        assert!(clean.contains("clean"));
    }

    #[test]
    fn json_report_parses_with_repo_json_reader() {
        let json = sample().render_json();
        let v = crate::config::json::Json::parse(&json).unwrap();
        let findings = v.get("findings").unwrap().as_arr().unwrap();
        assert_eq!(findings.len(), 2);
        assert_eq!(
            findings[0].get("rule").unwrap().as_str().unwrap(),
            "D001"
        );
        assert_eq!(
            v.get("active").unwrap().as_usize().unwrap(),
            1
        );
        assert_eq!(
            v.get("suppressed").unwrap().as_usize().unwrap(),
            1
        );
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn rules_table_is_complete_and_ordered() {
        let ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
        assert_eq!(
            ids,
            [
                "D001", "D002", "D003", "D004", "D005", "D006",
                "L001", "S001"
            ]
        );
    }
}
