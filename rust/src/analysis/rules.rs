//! The determinism rules (D001–D006) and per-file rule dispatch.
//!
//! Each rule is a token-sequence matcher over a [`SourceFile`]; rule
//! scoping (which directories, whether test regions count) lives here
//! so the matchers themselves stay simple. Layering (L001) is in
//! [`super::layering`]; schema drift (S001) is cross-file and lives
//! in [`super::schema`].

use super::layering;
use super::report::Finding;
use super::source::SourceFile;
use crate::analysis::lexer::{Token, TokenKind};

/// Modules whose iteration order can leak into trajectories, CSVs,
/// or traces — D002 forbids hash collections anywhere inside them.
pub const DET_MODULES: &[&str] =
    &["engine", "sweep", "trace", "sim", "comm", "coding"];

/// Run every per-file rule on `sf` and mark pragma suppressions.
pub fn check_file(sf: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    let top = top_module(&sf.rel);

    d001(sf, &mut out);
    if let Some(top) = top {
        if DET_MODULES.contains(&top) {
            d002(sf, &mut out);
        }
        if !matches!(top, "bench_harness") {
            d003(sf, &mut out);
        }
        d004(sf, &mut out);
        if !matches!(top, "cli" | "bench_harness" | "main") {
            d005(sf, &mut out);
        }
        if !matches!(top, "exec") {
            d006(sf, &mut out);
        }
        layering::l001(sf, top, &mut out);
    }

    for f in &mut out {
        if sf.allowed(f.rule, f.line) {
            f.suppressed = true;
        }
    }
    out
}

/// The top-level module a `rust/src/` path belongs to:
/// `rust/src/stats/running.rs` -> `stats`, `rust/src/lib.rs` -> `lib`.
/// Paths outside `rust/src/` (tests, benches, examples) return `None`
/// — only D001 applies there.
pub fn top_module(rel: &str) -> Option<&str> {
    let rest = rel.strip_prefix("rust/src/")?;
    let first = rest.split('/').next().unwrap_or(rest);
    Some(first.strip_suffix(".rs").unwrap_or(first))
}

fn ident_at(toks: &[Token], i: usize, text: &str) -> bool {
    toks.get(i)
        .map(|t| t.kind == TokenKind::Ident && t.text == text)
        .unwrap_or(false)
}

fn punct_at(toks: &[Token], i: usize, text: &str) -> bool {
    toks.get(i)
        .map(|t| t.kind == TokenKind::Punct && t.text == text)
        .unwrap_or(false)
}

/// Index of the `)` matching the `(` at `open`, if balanced.
fn matching_paren(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (i, t) in toks.iter().enumerate().skip(open) {
        if t.kind == TokenKind::Punct {
            if t.text == "(" {
                depth += 1;
            } else if t.text == ")" {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
        }
    }
    None
}

/// D001: `partial_cmp(..).unwrap()` / `.expect(..)` — panics on NaN
/// and makes float sorts input-order dependent. Applies everywhere,
/// test code included: an equivalence test that panics on NaN hides
/// the very regression it pins.
fn d001(sf: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &sf.tokens;
    for i in 0..toks.len() {
        if !ident_at(toks, i, "partial_cmp") {
            continue;
        }
        // `fn partial_cmp(...)` is a trait impl, not a call site.
        if i > 0 && ident_at(toks, i - 1, "fn") {
            continue;
        }
        if !punct_at(toks, i + 1, "(") {
            continue;
        }
        let Some(close) = matching_paren(toks, i + 1) else {
            continue;
        };
        if punct_at(toks, close + 1, ".")
            && (ident_at(toks, close + 2, "unwrap")
                || ident_at(toks, close + 2, "expect"))
        {
            out.push(Finding {
                rule: "D001",
                file: sf.rel.clone(),
                line: toks[i].line,
                message: "NaN-unsafe float ordering: \
                          partial_cmp(..).unwrap() panics on NaN"
                    .to_string(),
                hint: "use total_cmp (see master::sync::\
                       fastest_k_select for the pattern)"
                    .to_string(),
                suppressed: false,
            });
        }
    }
}

/// D002: hash collections in deterministic modules. Iteration order
/// of `HashMap`/`HashSet` is seeded per-process, so any traversal
/// that feeds results breaks `--jobs 1` ≡ `--jobs N` and replay.
/// Test regions are *not* exempt: in-module tests often assert on
/// trajectories, and a hash-ordered helper makes them flaky.
fn d002(sf: &SourceFile, out: &mut Vec<Finding>) {
    for t in &sf.tokens {
        if t.kind == TokenKind::Ident
            && (t.text == "HashMap" || t.text == "HashSet")
        {
            out.push(Finding {
                rule: "D002",
                file: sf.rel.clone(),
                line: t.line,
                message: format!(
                    "{} in a deterministic module: iteration order \
                     is process-seeded",
                    t.text
                ),
                hint: "use BTreeMap/BTreeSet or a sorted Vec"
                    .to_string(),
                suppressed: false,
            });
        }
    }
}

/// D003: wall-clock reads outside `bench_harness`. The engine's
/// virtual clock is the only time source allowed to influence
/// results; `Instant::now()` in library code is how real time leaks
/// into trajectories.
fn d003(sf: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &sf.tokens;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokenKind::Ident || sf.is_test_line(t.line) {
            continue;
        }
        let hit = (t.text == "Instant"
            && punct_at(toks, i + 1, ":")
            && punct_at(toks, i + 2, ":")
            && ident_at(toks, i + 3, "now"))
            || t.text == "SystemTime";
        if hit {
            out.push(Finding {
                rule: "D003",
                file: sf.rel.clone(),
                line: t.line,
                message: "wall-clock read in library code"
                    .to_string(),
                hint: "drive logic from the engine's virtual clock; \
                       if this only feeds a reported stat, annotate \
                       with // detlint: allow(D003) and a \
                       justification"
                    .to_string(),
                suppressed: false,
            });
        }
    }
}

/// D004: literal-seeded RNG construction. Every stream must derive
/// from the run seed (RngStreams / Pcg64::derive / seed_stream with a
/// derived first argument); a hard-coded integer seed silently
/// decouples a code path from `--seed`.
fn d004(sf: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &sf.tokens;
    for i in 0..toks.len() {
        if !ident_at(toks, i, "Pcg64") || sf.is_test_line(toks[i].line)
        {
            continue;
        }
        if !(punct_at(toks, i + 1, ":") && punct_at(toks, i + 2, ":"))
        {
            continue;
        }
        let is_ctor = ident_at(toks, i + 3, "seed")
            || ident_at(toks, i + 3, "seed_stream");
        if !is_ctor || !punct_at(toks, i + 4, "(") {
            continue;
        }
        if toks
            .get(i + 5)
            .map(|t| t.kind == TokenKind::IntLit)
            .unwrap_or(false)
        {
            out.push(Finding {
                rule: "D004",
                file: sf.rel.clone(),
                line: toks[i].line,
                message: "literal-seeded RNG: this stream ignores \
                          the run seed"
                    .to_string(),
                hint: "derive the seed from the run seed via \
                       RngStreams or sweep::derive_seed"
                    .to_string(),
                suppressed: false,
            });
        }
    }
}

/// D005: `println!`/`eprintln!` in library modules. Library output
/// must flow through recorders/metrics so sweeps stay quiet and
/// machine-readable; stdout belongs to `cli`, `main`, and benches.
fn d005(sf: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &sf.tokens;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokenKind::Ident || sf.is_test_line(t.line) {
            continue;
        }
        let is_print = matches!(
            t.text.as_str(),
            "println" | "eprintln" | "print" | "eprint"
        );
        if is_print && punct_at(toks, i + 1, "!") {
            out.push(Finding {
                rule: "D005",
                file: sf.rel.clone(),
                line: t.line,
                message: format!(
                    "{}! in a library module",
                    t.text
                ),
                hint: "return data or record through metrics; only \
                       cli/bench_harness own stdout"
                    .to_string(),
                suppressed: false,
            });
        }
    }
}

/// D006: `thread::spawn` outside `exec`. Ad-hoc OS threads bypass the
/// single shared pool, so they oversubscribe the machine under
/// sweep-level fan-out and their nondeterministic interleaving has no
/// fixed-order reduction to hide behind. All live parallelism routes
/// through `exec` (`ThreadPool::scope` / `parallel_for` /
/// `Parallelism`); test regions may spawn freely to build scenarios.
fn d006(sf: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &sf.tokens;
    for i in 0..toks.len() {
        if !ident_at(toks, i, "thread") || sf.is_test_line(toks[i].line)
        {
            continue;
        }
        if punct_at(toks, i + 1, ":")
            && punct_at(toks, i + 2, ":")
            && ident_at(toks, i + 3, "spawn")
        {
            out.push(Finding {
                rule: "D006",
                file: sf.rel.clone(),
                line: toks[i].line,
                message: "thread::spawn outside exec: ad-hoc threads \
                          bypass the shared pool"
                    .to_string(),
                hint: "route parallelism through exec (ThreadPool::\
                       scope / parallel_for, or a Parallelism token); \
                       one pool keeps sweeps from oversubscribing and \
                       keeps reductions in fixed order"
                    .to_string(),
                suppressed: false,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        let sf = SourceFile::parse(rel, src).unwrap();
        check_file(&sf)
    }

    #[test]
    fn top_module_resolution() {
        assert_eq!(
            top_module("rust/src/stats/running.rs"),
            Some("stats")
        );
        assert_eq!(top_module("rust/src/lib.rs"), Some("lib"));
        assert_eq!(top_module("rust/src/main.rs"), Some("main"));
        assert_eq!(top_module("rust/tests/proptests.rs"), None);
        assert_eq!(top_module("benches/fig1_bound.rs"), None);
    }

    #[test]
    fn d001_fires_on_unwrap_and_expect() {
        let src = "\
fn f(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v.sort_by(|a, b| a.partial_cmp(b).expect(\"cmp\"));
}
";
        let fs = findings("rust/src/stats/x.rs", src);
        let d001: Vec<u32> = fs
            .iter()
            .filter(|f| f.rule == "D001")
            .map(|f| f.line)
            .collect();
        assert_eq!(d001, [2, 3]);
    }

    #[test]
    fn d001_ignores_trait_impl_and_propagated_option() {
        let src = "\
impl PartialOrd for T {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
fn g(a: f64, b: f64) -> Option<Ordering> {
    a.partial_cmp(&b)
}
";
        let fs = findings("rust/src/sim/x.rs", src);
        assert!(fs.iter().all(|f| f.rule != "D001"), "{fs:?}");
    }

    #[test]
    fn d002_scoped_to_det_modules() {
        let src = "use std::collections::HashMap;\n";
        assert!(findings("rust/src/engine/x.rs", src)
            .iter()
            .any(|f| f.rule == "D002"));
        assert!(findings("rust/src/metrics/x.rs", src)
            .iter()
            .all(|f| f.rule != "D002"));
    }

    #[test]
    fn d003_exempts_tests_and_bench_harness() {
        let live = "fn f() { let t = Instant::now(); }\n";
        assert!(findings("rust/src/exec/x.rs", live)
            .iter()
            .any(|f| f.rule == "D003" && !f.suppressed));
        assert!(findings("rust/src/bench_harness/x.rs", live)
            .iter()
            .all(|f| f.rule != "D003"));
        let test_only = "\
#[cfg(test)]
mod tests {
    #[test]
    fn t() { let t = Instant::now(); }
}
";
        assert!(findings("rust/src/exec/x.rs", test_only)
            .iter()
            .all(|f| f.rule != "D003"));
    }

    #[test]
    fn d003_pragma_suppresses_but_is_counted() {
        let src = "\
fn f() {
    // detlint: allow(D003)
    let t = Instant::now();
}
";
        let fs = findings("rust/src/exec/x.rs", src);
        let hit =
            fs.iter().find(|f| f.rule == "D003").expect("finding");
        assert!(hit.suppressed);
    }

    #[test]
    fn d004_literal_seed_fires_derived_seed_clean() {
        let bad = "fn f() { let r = Pcg64::seed_stream(42, 7); }\n";
        assert!(findings("rust/src/straggler/x.rs", bad)
            .iter()
            .any(|f| f.rule == "D004"));
        let good = "\
fn f(seed: u64) {
    let r = Pcg64::seed_stream(seed, 0xC0DE);
}
";
        assert!(findings("rust/src/straggler/x.rs", good)
            .iter()
            .all(|f| f.rule != "D004"));
    }

    #[test]
    fn d005_scoped_by_module() {
        let src = "fn f() { println!(\"x\"); }\n";
        assert!(findings("rust/src/stats/x.rs", src)
            .iter()
            .any(|f| f.rule == "D005"));
        assert!(findings("rust/src/cli/x.rs", src)
            .iter()
            .all(|f| f.rule != "D005"));
        assert!(findings("rust/src/main.rs", src)
            .iter()
            .all(|f| f.rule != "D005"));
    }

    #[test]
    fn d006_scoped_to_non_exec_live_code() {
        let live = "fn f() { std::thread::spawn(|| {}); }\n";
        // Live spawn in a library module fires; both the
        // `std::thread::spawn` and bare `thread::spawn` spellings hit
        // the same `thread :: spawn` token core.
        assert!(findings("rust/src/engine/x.rs", live)
            .iter()
            .any(|f| f.rule == "D006" && !f.suppressed));
        let bare = "fn f() { thread::spawn(|| {}); }\n";
        assert!(findings("rust/src/metrics/x.rs", bare)
            .iter()
            .any(|f| f.rule == "D006"));
        // exec owns the pool: exempt.
        assert!(findings("rust/src/exec/pool.rs", live)
            .iter()
            .all(|f| f.rule != "D006"));
        // Test regions may spawn scenario threads.
        let test_only = "\
#[cfg(test)]
mod tests {
    #[test]
    fn t() { std::thread::spawn(|| {}).join().unwrap(); }
}
";
        assert!(findings("rust/src/engine/x.rs", test_only)
            .iter()
            .all(|f| f.rule != "D006"));
        // Integration tests / benches (no top module) are exempt.
        assert!(findings("rust/tests/t.rs", live)
            .iter()
            .all(|f| f.rule != "D006"));
    }

    #[test]
    fn comments_and_strings_never_fire() {
        let src = "\
// a comment mentioning partial_cmp(x).unwrap() and HashMap
fn f() {
    let s = \"Instant::now() println! HashMap\";
    let _ = s;
}
";
        let fs = findings("rust/src/engine/x.rs", src);
        assert!(fs.is_empty(), "{fs:?}");
    }
}
