//! S001 — schema-drift checks (cross-file).
//!
//! Two on-disk formats are written by one module and read by another,
//! so drift cannot be caught by any single-file rule:
//!
//! * the run-series CSV: `metrics::csv::CSV_COLUMNS` and the
//!   `# adasgd run series vN` header comment vs the version registry
//!   here ([`CSV_SCHEMA_VERSIONS`]);
//! * the binary trace: the `KIND_*` tag constants in
//!   `trace::event` vs the reader's length-prefixed skip protocol —
//!   every tag must be unique, nonzero (0 is reserved for
//!   "unknown/skip" testing), and referenced at least three times
//!   (declaration, `kind()` dispatch, `decode()` dispatch), so a new
//!   event kind cannot be added without wiring both directions.
//!
//! Bumping the CSV schema is legal — add the new column list here as
//! `vN+1` in the same commit, which is exactly the reviewable moment
//! the rule exists to create.

use std::collections::BTreeMap;

use super::report::Finding;
use super::source::SourceFile;
use crate::analysis::lexer::TokenKind;

/// Every CSV schema version ever written, oldest first. Each version
/// must extend the previous by appending columns (readers rely on
/// prefix compatibility to consume old files).
pub const CSV_SCHEMA_VERSIONS: &[(u32, &str)] = &[
    (2, "label,iteration,time,k,error,bytes,comm_time"),
    (
        3,
        "label,iteration,time,k,error,bytes,comm_time,\
         bytes_down,down_time",
    ),
    (
        4,
        "label,iteration,time,k,error,bytes,comm_time,\
         bytes_down,down_time,late_responses,mean_staleness",
    ),
];

const CSV_FILE: &str = "rust/src/metrics/csv.rs";
const EVENT_FILE: &str = "rust/src/trace/event.rs";

/// Run the schema checks over the whole workspace (rel path ->
/// parsed file). Files absent from the workspace are skipped, so the
/// pass composes with synthetic fixture workspaces in tests.
pub(super) fn s001(
    files: &BTreeMap<String, SourceFile>,
    out: &mut Vec<Finding>,
) {
    if let Some(sf) = files.get(CSV_FILE) {
        check_csv(sf, out);
    }
    if let Some(sf) = files.get(EVENT_FILE) {
        check_trace(sf, out);
    }
}

fn finding(sf: &SourceFile, line: u32, message: String, hint: &str) -> Finding {
    Finding {
        rule: "S001",
        file: sf.rel.clone(),
        line,
        message,
        hint: hint.to_string(),
        suppressed: false,
    }
}

const CSV_HINT: &str = "bump the schema: append the new columns, \
                        bump the vN header, and register the new \
                        version in analysis/schema.rs::\
                        CSV_SCHEMA_VERSIONS in the same commit";

/// CSV side: the `CSV_COLUMNS` const must equal the latest registered
/// column list, and every `adasgd run series vN` string in the file
/// (writer header and tests alike) must claim the latest version.
fn check_csv(sf: &SourceFile, out: &mut Vec<Finding>) {
    let (latest_version, latest_columns) = match CSV_SCHEMA_VERSIONS.last()
    {
        Some(&(v, c)) => (v, c),
        None => return,
    };
    // Registry self-check: append-only prefix compatibility.
    for w in CSV_SCHEMA_VERSIONS.windows(2) {
        let (pv, pc) = w[0];
        let (nv, nc) = w[1];
        if nv <= pv || !nc.starts_with(pc) {
            out.push(finding(
                sf,
                1,
                format!(
                    "CSV schema registry broken: v{nv} does not \
                     extend v{pv} by appended columns"
                ),
                CSV_HINT,
            ));
        }
    }

    let toks = &sf.tokens;
    let mut found_const = false;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokenKind::Ident || t.text != "CSV_COLUMNS" {
            continue;
        }
        let after_const = i > 0
            && toks[i - 1].kind == TokenKind::Ident
            && toks[i - 1].text == "const";
        if !after_const {
            continue;
        }
        found_const = true;
        // `const CSV_COLUMNS: &str = "...";` — the first string
        // literal after the ident is the value.
        let value = toks[i..]
            .iter()
            .take(8)
            .find(|t| t.kind == TokenKind::StrLit);
        match value {
            Some(v) if v.text == latest_columns => {}
            Some(v) => out.push(finding(
                sf,
                v.line,
                format!(
                    "CSV_COLUMNS does not match registered schema \
                     v{latest_version} ({} vs {} columns)",
                    v.text.split(',').count(),
                    latest_columns.split(',').count()
                ),
                CSV_HINT,
            )),
            None => out.push(finding(
                sf,
                t.line,
                "CSV_COLUMNS const has no string value".to_string(),
                CSV_HINT,
            )),
        }
        break;
    }
    if !found_const {
        out.push(finding(
            sf,
            1,
            "metrics/csv.rs no longer declares CSV_COLUMNS".to_string(),
            CSV_HINT,
        ));
    }

    let marker = "adasgd run series v";
    let mut saw_version = false;
    for t in toks {
        if t.kind != TokenKind::StrLit {
            continue;
        }
        let Some(idx) = t.text.find(marker) else {
            continue;
        };
        saw_version = true;
        let digits: String = t.text[idx + marker.len()..]
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect();
        if digits.parse::<u32>() != Ok(latest_version) {
            out.push(finding(
                sf,
                t.line,
                format!(
                    "CSV header claims series v{digits} but the \
                     registered latest is v{latest_version}"
                ),
                CSV_HINT,
            ));
        }
    }
    if !saw_version {
        out.push(finding(
            sf,
            1,
            "no `adasgd run series vN` header string found in \
             metrics/csv.rs"
                .to_string(),
            CSV_HINT,
        ));
    }
}

const TRACE_HINT: &str = "wire the new kind through all of: the \
                          KIND_* const, Event::kind(), and \
                          Event::decode() (the reader skips unknown \
                          kinds by length prefix, so a half-wired \
                          kind silently drops events)";

/// Trace side: collect `const KIND_*: u8 = N;` declarations and check
/// tag uniqueness, nonzero-ness, and that each ident is referenced at
/// least three times in the file.
fn check_trace(sf: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &sf.tokens;
    let mut decls: Vec<(String, u32, Option<u64>)> = Vec::new();
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokenKind::Ident
            || !t.text.starts_with("KIND_")
            || i == 0
            || toks[i - 1].kind != TokenKind::Ident
            || toks[i - 1].text != "const"
        {
            continue;
        }
        // `const KIND_X: u8 = 3;` — first int literal after the ident.
        let tag = toks[i..]
            .iter()
            .take(8)
            .find(|t| t.kind == TokenKind::IntLit)
            .and_then(|t| t.text.parse::<u64>().ok());
        decls.push((t.text.clone(), t.line, tag));
    }
    if decls.is_empty() {
        out.push(Finding {
            rule: "S001",
            file: sf.rel.clone(),
            line: 1,
            message: "trace/event.rs declares no KIND_* tag constants"
                .to_string(),
            hint: TRACE_HINT.to_string(),
            suppressed: false,
        });
        return;
    }
    let mut seen_tags: BTreeMap<u64, String> = BTreeMap::new();
    for (name, line, tag) in &decls {
        match tag {
            None => out.push(finding(
                sf,
                *line,
                format!("{name} has no integer tag value"),
                TRACE_HINT,
            )),
            Some(0) => out.push(finding(
                sf,
                *line,
                format!("{name} uses tag 0, reserved for unknown-kind \
                         skip tests"),
                TRACE_HINT,
            )),
            Some(v) => {
                if let Some(prev) = seen_tags.insert(*v, name.clone()) {
                    out.push(finding(
                        sf,
                        *line,
                        format!("{name} reuses tag {v} already taken \
                                 by {prev}"),
                        TRACE_HINT,
                    ));
                }
            }
        }
        let refs = toks
            .iter()
            .filter(|t| t.kind == TokenKind::Ident && t.text == *name)
            .count();
        if refs < 3 {
            out.push(finding(
                sf,
                *line,
                format!(
                    "{name} referenced {refs}x; expected >= 3 \
                     (declaration, kind(), decode())"
                ),
                TRACE_HINT,
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workspace(files: &[(&str, &str)]) -> BTreeMap<String, SourceFile> {
        files
            .iter()
            .map(|(rel, src)| {
                (
                    rel.to_string(),
                    SourceFile::parse(rel, src).unwrap(),
                )
            })
            .collect()
    }

    fn run(files: &[(&str, &str)]) -> Vec<Finding> {
        let ws = workspace(files);
        let mut out = Vec::new();
        s001(&ws, &mut out);
        out
    }

    fn latest_columns() -> &'static str {
        CSV_SCHEMA_VERSIONS.last().unwrap().1
    }

    #[test]
    fn registry_versions_are_append_only() {
        for w in CSV_SCHEMA_VERSIONS.windows(2) {
            assert!(w[1].0 > w[0].0);
            assert!(w[1].1.starts_with(w[0].1));
            assert_eq!(&w[1].1[w[0].1.len()..w[0].1.len() + 1], ",");
        }
    }

    #[test]
    fn matching_csv_file_is_clean() {
        let src = format!(
            "pub const CSV_COLUMNS: &str = \"{}\";\n\
             fn write() {{ let _ = \"# adasgd run series v{}; \
             columns\"; }}\n",
            latest_columns(),
            CSV_SCHEMA_VERSIONS.last().unwrap().0
        );
        assert!(run(&[(super::CSV_FILE, src.as_str())]).is_empty());
    }

    #[test]
    fn column_drift_fires() {
        let src = "pub const CSV_COLUMNS: &str = \
                   \"label,iteration,time\";\n\
                   fn write() { let _ = \"# adasgd run series v4\"; }\n";
        let fs = run(&[(super::CSV_FILE, src)]);
        assert!(fs
            .iter()
            .any(|f| f.message.contains("does not match")), "{fs:?}");
    }

    #[test]
    fn stale_version_header_fires() {
        let src = format!(
            "pub const CSV_COLUMNS: &str = \"{}\";\n\
             fn write() {{ let _ = \"# adasgd run series v3\"; }}\n",
            latest_columns()
        );
        let fs = run(&[(super::CSV_FILE, src.as_str())]);
        assert!(fs.iter().any(|f| f.message.contains("claims series")));
    }

    #[test]
    fn missing_const_or_header_fires() {
        let fs = run(&[(super::CSV_FILE, "fn nothing() {}\n")]);
        assert!(fs.iter().any(|f| f.message.contains("CSV_COLUMNS")));
        assert!(fs
            .iter()
            .any(|f| f.message.contains("run series vN")));
    }

    const GOOD_EVENTS: &str = "\
const KIND_A: u8 = 1;
const KIND_B: u8 = 2;
fn kind(e: u8) -> u8 {
    match e { 0 => KIND_A, _ => KIND_B }
}
fn decode(k: u8) -> bool {
    k == KIND_A || k == KIND_B
}
";

    #[test]
    fn wired_trace_kinds_are_clean() {
        assert!(run(&[(super::EVENT_FILE, GOOD_EVENTS)]).is_empty());
    }

    #[test]
    fn duplicate_zero_and_unwired_tags_fire() {
        let src = "\
const KIND_A: u8 = 1;
const KIND_B: u8 = 1;
const KIND_C: u8 = 0;
const KIND_D: u8 = 4;
fn kind() -> u8 { KIND_A + KIND_B + KIND_C + KIND_D }
fn decode() -> u8 { KIND_A + KIND_B + KIND_C }
";
        let fs = run(&[(super::EVENT_FILE, src)]);
        assert!(fs.iter().any(|f| f.message.contains("reuses tag 1")));
        assert!(fs.iter().any(|f| f.message.contains("tag 0")));
        assert!(fs
            .iter()
            .any(|f| f.message.contains("KIND_D referenced 2x")));
    }

    #[test]
    fn absent_files_are_skipped() {
        assert!(run(&[("rust/src/other.rs", "fn f() {}\n")]).is_empty());
    }
}
