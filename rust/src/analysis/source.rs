//! Per-file source model for the lint pass.
//!
//! Wraps the lexed token stream with the two pieces of per-file
//! context every rule needs: which lines carry a
//! `// detlint: allow(<rule>)` suppression pragma, and which line
//! ranges belong to `#[cfg(test)]` / `#[test]` regions (most rules
//! exempt test code — tests may use wall clocks, ad-hoc seeds, and
//! stdout freely).

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, LexError, Token, TokenKind};

/// A lexed source file plus pragma and test-region metadata.
#[derive(Debug, Clone)]
pub struct SourceFile {
    /// Repo-relative path with forward slashes
    /// (e.g. `rust/src/stats/running.rs`).
    pub rel: String,
    /// Tokens in source order.
    pub tokens: Vec<Token>,
    /// line -> rules allowed by a pragma on that line.
    allows: BTreeMap<u32, BTreeSet<String>>,
    /// Inclusive line spans of `#[cfg(test)]` / `#[test]` items.
    test_spans: Vec<(u32, u32)>,
}

impl SourceFile {
    /// Lex `text` and extract pragmas and test regions.
    pub fn parse(rel: &str, text: &str) -> Result<SourceFile, LexError> {
        let lexed = lex(text)?;
        let mut allows: BTreeMap<u32, BTreeSet<String>> = BTreeMap::new();
        for comment in &lexed.comments {
            if let Some(rules) = parse_pragma(&comment.text) {
                allows.entry(comment.line).or_default().extend(rules);
            }
        }
        let test_spans = test_spans(&lexed.tokens);
        Ok(SourceFile {
            rel: rel.replace('\\', "/"),
            tokens: lexed.tokens,
            allows,
            test_spans,
        })
    }

    /// True when `line` falls inside a `#[cfg(test)]`/`#[test]` item.
    pub fn is_test_line(&self, line: u32) -> bool {
        self.test_spans
            .iter()
            .any(|&(lo, hi)| lo <= line && line <= hi)
    }

    /// True when a pragma allows `rule` on `line` — either trailing on
    /// the line itself or on the line immediately above it.
    pub fn allowed(&self, rule: &str, line: u32) -> bool {
        let hit = |l: u32| {
            self.allows
                .get(&l)
                .map(|rules| rules.contains(rule))
                .unwrap_or(false)
        };
        hit(line) || (line > 1 && hit(line - 1))
    }

    /// Number of suppression pragma lines in the file.
    pub fn pragma_lines(&self) -> usize {
        self.allows.len()
    }
}

/// Parse `detlint: allow(D001)` / `detlint: allow(D001, D003)` out of
/// a comment body. Returns `None` when the comment is not a pragma.
fn parse_pragma(comment: &str) -> Option<Vec<String>> {
    let idx = comment.find("detlint:")?;
    let rest = comment[idx + "detlint:".len()..].trim_start();
    let body = rest.strip_prefix("allow(")?;
    let close = body.find(')')?;
    let rules: Vec<String> = body[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if rules.is_empty() {
        None
    } else {
        Some(rules)
    }
}

fn punct_at(tokens: &[Token], i: usize, p: &str) -> bool {
    tokens
        .get(i)
        .map(|t| t.kind == TokenKind::Punct && t.text == p)
        .unwrap_or(false)
}

/// Find line spans of items marked `#[cfg(test)]` or `#[test]`. The
/// scan is token-based: on an attribute containing the ident `test`
/// (and not `not`, so `#[cfg(not(test))]` stays live code), the next
/// `{ ... }` block's balanced-brace extent becomes a test span.
fn test_spans(tokens: &[Token]) -> Vec<(u32, u32)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < tokens.len() {
        if !(punct_at(tokens, i, "#") && punct_at(tokens, i + 1, "[")) {
            i += 1;
            continue;
        }
        // Scan the attribute to its matching `]`.
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut saw_test = false;
        let mut saw_not = false;
        while j < tokens.len() && depth > 0 {
            let t = &tokens[j];
            if t.kind == TokenKind::Punct && t.text == "[" {
                depth += 1;
            } else if t.kind == TokenKind::Punct && t.text == "]" {
                depth -= 1;
            } else if t.kind == TokenKind::Ident {
                if t.text == "test" {
                    saw_test = true;
                } else if t.text == "not" {
                    saw_not = true;
                }
            }
            j += 1;
        }
        if !(saw_test && !saw_not) {
            i = j;
            continue;
        }
        // Attribute marks a test item: find its body block (stop at
        // `;` for block-less items like `use`).
        let mut k = j;
        while k < tokens.len()
            && !punct_at(tokens, k, "{")
            && !punct_at(tokens, k, ";")
        {
            k += 1;
        }
        if k >= tokens.len() || punct_at(tokens, k, ";") {
            i = k.saturating_add(1);
            continue;
        }
        let start = tokens[i].line;
        let mut m = k + 1;
        let mut braces = 1usize;
        while m < tokens.len() && braces > 0 {
            if punct_at(tokens, m, "{") {
                braces += 1;
            } else if punct_at(tokens, m, "}") {
                braces -= 1;
            }
            m += 1;
        }
        let end = tokens[m.saturating_sub(1)].line;
        spans.push((start, end));
        i = m;
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pragma_parsing_forms() {
        assert_eq!(
            parse_pragma(" detlint: allow(D003)"),
            Some(vec!["D003".to_string()])
        );
        assert_eq!(
            parse_pragma(" detlint: allow(D001, L001)"),
            Some(vec!["D001".to_string(), "L001".to_string()])
        );
        assert_eq!(parse_pragma(" ordinary comment"), None);
        assert_eq!(parse_pragma(" detlint: allow()"), None);
        assert_eq!(parse_pragma(" detlint: deny(D001)"), None);
    }

    #[test]
    fn pragma_covers_own_and_next_line() {
        let src = "\
let a = 1; // detlint: allow(D003)
let b = 2;
let c = 3;
";
        let sf = SourceFile::parse("x.rs", src).unwrap();
        assert!(sf.allowed("D003", 1));
        assert!(sf.allowed("D003", 2));
        assert!(!sf.allowed("D003", 3));
        assert!(!sf.allowed("D001", 1));
    }

    #[test]
    fn cfg_test_region_is_detected() {
        let src = "\
fn live() {}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let x = 1;
    }
}

fn also_live() {}
";
        let sf = SourceFile::parse("x.rs", src).unwrap();
        assert!(!sf.is_test_line(1));
        assert!(sf.is_test_line(3));
        assert!(sf.is_test_line(7));
        assert!(sf.is_test_line(9));
        assert!(!sf.is_test_line(11));
    }

    #[test]
    fn cfg_not_test_is_live_code() {
        let src = "\
#[cfg(not(test))]
fn live() {
    let x = 1;
}
";
        let sf = SourceFile::parse("x.rs", src).unwrap();
        assert!(!sf.is_test_line(3));
    }

    #[test]
    fn test_attr_in_string_does_not_mark_region() {
        let src = "\
fn live() {
    let s = \"#[cfg(test)]\";
    let _ = s;
}
";
        let sf = SourceFile::parse("x.rs", src).unwrap();
        assert!(!sf.is_test_line(2));
        assert!(!sf.is_test_line(3));
    }
}
