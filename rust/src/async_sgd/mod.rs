//! Fully-asynchronous distributed SGD — the Fig. 3 comparator, per
//! Dutta et al. [2].
//!
//! Every worker computes the partial gradient of *its own shard* against
//! the model version it last received. Whenever any worker finishes, the
//! master immediately applies that (possibly stale) gradient:
//!
//! ```text
//! w ← w − η ∇F(S_i, w_stale_i)
//! ```
//!
//! hands the worker the fresh model, and the worker starts over. There is
//! no synchronization barrier, so the clock advances on an event queue of
//! per-worker completion times rather than an order statistic.
//!
//! Both entry points are compatibility shims over the round engine: they
//! build an [`engine::EngineCore`](crate::engine::EngineCore) with the
//! historical async rng streams and run the
//! [`engine::StalenessGather`](crate::engine::StalenessGather)
//! discipline, preserving the pre-engine trajectories bit for bit
//! (asserted by `rust/tests/test_engine_equivalence.rs`).

use crate::comm::CommChannel;
use crate::engine::{
    EngineConfig, EngineCore, RngStreams, RoundEngine, StalenessGather,
};
use crate::grad::GradBackend;
use crate::metrics::Recorder;
use crate::straggler::DelayModel;

/// Async-run configuration.
#[derive(Debug, Clone)]
pub struct AsyncConfig {
    /// Step size η.
    pub eta: f32,
    /// Total model updates (each worker completion is one update).
    pub max_updates: u64,
    /// Stop once the virtual clock passes this (0 = no budget).
    pub max_time: f64,
    /// Seed for the delay draws.
    pub seed: u64,
    /// Evaluate + record every this many updates.
    pub record_stride: u64,
    /// Staleness-aware step damping: apply `η/(1 + staleness)` per update.
    ///
    /// Raw delayed SGD is unstable whenever `η·λ_max·τ ≳ 1`; with the
    /// paper's Fig-3 parameters (η = 2·10⁻⁴, λ_max ≈ 3·10³, τ ≈ n−1 = 49)
    /// that product is ≈ 30, so the undamped run diverges (kept available
    /// as an ablation — see EXPERIMENTS.md). The paper does not state its
    /// async stabilisation; this damping is the standard staleness-aware
    /// rule (cf. Zhang et al. 2016) and is the documented substitution.
    pub staleness_damping: bool,
    /// Intra-round worker budget (1 = serial, 0 = the machine). Pure
    /// wall-clock — trajectories are bitwise identical for every value.
    pub intra_jobs: usize,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        Self {
            eta: 2e-4,
            max_updates: 100_000,
            max_time: 0.0,
            seed: 0,
            record_stride: 50,
            staleness_damping: true,
            intra_jobs: 1,
        }
    }
}

/// Result of an async run.
pub struct AsyncRun {
    /// Error-vs-time record.
    pub recorder: Recorder,
    /// Final model.
    pub w: Vec<f32>,
    /// Updates applied.
    pub updates: u64,
    /// Final virtual clock.
    pub total_time: f64,
    /// Mean staleness (model versions elapsed between a worker's read and
    /// its gradient's application) — diagnostic for the Fig. 3 discussion.
    pub mean_staleness: f64,
    /// True if the run blew up (non-finite model) and stopped early.
    pub diverged: bool,
    /// Encoded bytes of all applied gradient messages.
    pub bytes_sent: u64,
    /// Total upload time of applied messages.
    pub comm_time: f64,
    /// Encoded bytes of all model downloads (one per applied update —
    /// the async downlink is unicast).
    pub bytes_down: u64,
    /// Total download time charged.
    pub down_time: f64,
    /// Late (discarded) responses — always 0 here (no async update is
    /// ever discarded); present for uniform CSV plumbing.
    pub late_responses: u64,
    /// The binary event trace when tracing was enabled (see
    /// [`crate::trace`]), `None` otherwise.
    pub trace: Option<crate::trace::Trace>,
}

/// Run asynchronous SGD from `w0` with the zero-cost dense channel.
pub fn run_async(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    w0: &[f32],
    cfg: &AsyncConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> AsyncRun {
    let n = backend.n_shards();
    let mut channel = CommChannel::dense(n);
    run_async_comm(backend, delays, &mut channel, w0, cfg, eval_error)
}

/// Run asynchronous SGD from `w0`, shipping every update through
/// `channel`: a worker's completion event fires after compute delay plus
/// the upload delay of its encoded message, and the applied gradient is
/// the channel's reconstruction (error feedback applies every round here,
/// since no async update is ever discarded).
///
/// Bidirectional pricing: with a finite master-ingress capacity an
/// arriving upload contends for the NIC before it is applied — FIFO
/// store-and-forward by default (a running free-chain), or exact
/// processor sharing when the channel's
/// [`IngressDiscipline`](crate::comm::IngressDiscipline) says so (the
/// engine simulates the shared drain with completion events, so each
/// update's apply time reflects true PS) — and each restart downloads
/// the fresh model through the
/// channel's downlink, adding a download delay to the worker's next
/// cycle. Workers are assumed to know `w0`, so the initial dispatch
/// carries no download. A `Delta` downlink models a master streaming one
/// shared delta log that every worker replays up to its latest restart:
/// a restarting worker downloads every delta appended since it last
/// pulled (one per intervening update, i.e. staleness + 1 messages),
/// each priced at the scheme's encoded size.
pub fn run_async_comm(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &AsyncConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> AsyncRun {
    run_async_comm_traced(backend, delays, channel, w0, cfg, eval_error, false)
}

/// [`run_async_comm`] with opt-in binary event tracing (see
/// [`crate::trace`]); the trajectory is bit-identical either way.
#[allow(clippy::too_many_arguments)]
pub fn run_async_comm_traced(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &AsyncConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
    trace: bool,
) -> AsyncRun {
    let n = backend.n_shards();
    let d = backend.dim();
    assert_eq!(w0.len(), d, "w0 dimension mismatch");
    assert_eq!(
        channel.n(),
        n,
        "comm channel sized for {} workers, backend has {n}",
        channel.n()
    );

    let engine_cfg = EngineConfig {
        eta: cfg.eta,
        momentum: 0.0,
        max_steps: cfg.max_updates,
        max_time: cfg.max_time,
        seed: cfg.seed,
        record_stride: cfg.record_stride,
        intra_jobs: cfg.intra_jobs,
    };
    let mut core = EngineCore::new(
        "async",
        channel,
        delays,
        eval_error,
        w0,
        engine_cfg,
        RngStreams::asynchronous(cfg.seed),
    );
    if trace {
        core.enable_trace(crate::trace::Discipline::Async);
    }
    let mut gather = StalenessGather::new(backend, cfg.staleness_damping);
    let run = RoundEngine::new(core).run(&mut gather);
    AsyncRun {
        recorder: run.recorder,
        w: run.w,
        updates: run.steps,
        total_time: run.total_time,
        mean_staleness: run.mean_staleness,
        diverged: run.diverged,
        bytes_sent: run.bytes_sent,
        comm_time: run.comm_time,
        bytes_down: run.bytes_down,
        down_time: run.down_time,
        late_responses: run.late_responses,
        trace: run.trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
    use crate::grad::NativeBackend;
    use crate::model::LinRegProblem;
    use crate::straggler::ExponentialDelays;

    fn setup(n: usize) -> (NativeBackend, LinRegProblem) {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 200, d: 10, ..Default::default() },
            4,
        );
        let p = LinRegProblem::new(&ds);
        (NativeBackend::new(Shards::partition(&ds, n)), p)
    }

    #[test]
    fn async_training_descends() {
        let (mut backend, problem) = setup(10);
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0005,
            max_updates: 3000,
            seed: 1,
            record_stride: 100,
            ..Default::default()
        };
        let run = run_async(
            &mut backend,
            &delays,
            &vec![0.0; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 0.01, "{first} -> {last}");
        assert_eq!(run.updates, 3000);
    }

    #[test]
    fn staleness_grows_with_workers() {
        let delays = ExponentialDelays::new(1.0);
        let stale_for = |n: usize| {
            let (mut backend, problem) = setup(n);
            let cfg = AsyncConfig {
                eta: 0.0001,
                max_updates: 2000,
                seed: 3,
                record_stride: 500,
                ..Default::default()
            };
            run_async(&mut backend, &delays, &vec![0.0; 10], &cfg, &mut |w| {
                problem.error(w)
            })
            .mean_staleness
        };
        let s2 = stale_for(2);
        let s20 = stale_for(20);
        // With n concurrent workers mean staleness ≈ n − 1.
        assert!((s2 - 1.0).abs() < 0.3, "s2={s2}");
        assert!(s20 > 10.0, "s20={s20}");
    }

    #[test]
    fn updates_arrive_faster_than_sync_iterations() {
        // n workers each ~exp(1): async applies ~n updates per unit time.
        let (mut backend, problem) = setup(10);
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0001,
            max_updates: 5000,
            seed: 5,
            record_stride: 1000,
            ..Default::default()
        };
        let run = run_async(
            &mut backend,
            &delays,
            &vec![0.0; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        let rate = run.updates as f64 / run.total_time;
        assert!((rate - 10.0).abs() < 1.5, "rate={rate}");
    }

    #[test]
    fn dense_comm_channel_reproduces_the_plain_async_run() {
        use crate::comm::CommChannel;
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0005,
            max_updates: 500,
            seed: 8,
            record_stride: 100,
            ..Default::default()
        };
        let plain = {
            let (mut backend, problem) = setup(10);
            run_async(&mut backend, &delays, &vec![0.0; 10], &cfg, &mut |w| {
                problem.error(w)
            })
        };
        let comm = {
            let (mut backend, problem) = setup(10);
            let mut channel = CommChannel::dense(10);
            run_async_comm(
                &mut backend,
                &delays,
                &mut channel,
                &vec![0.0; 10],
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        assert_eq!(plain.w, comm.w);
        assert_eq!(plain.total_time, comm.total_time);
        assert!(plain.bytes_sent > 0);
        assert_eq!(plain.bytes_sent, comm.bytes_sent);
    }

    #[test]
    fn finite_uplink_slows_async_updates() {
        use crate::comm::{CommChannel, Dense, LinkModel};
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0001,
            max_updates: 2000,
            seed: 9,
            record_stride: 500,
            ..Default::default()
        };
        let (mut backend, problem) = setup(10);
        // d=10 -> 56-byte messages; bw 56 B/unit => +1.0 per completion.
        let mut channel = CommChannel::new(
            Box::new(Dense::new()),
            LinkModel::uniform(10, 56.0, 0.0),
            false,
        );
        let run = run_async_comm(
            &mut backend,
            &delays,
            &mut channel,
            &vec![0.0; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        // Per-worker cycle time is now ~2.0, so 10 workers apply ~5
        // updates per unit time instead of ~10.
        let rate = run.updates as f64 / run.total_time;
        assert!((rate - 5.0).abs() < 1.0, "rate={rate}");
        assert!(run.comm_time > 0.0);
    }

    #[test]
    fn delta_downlink_replay_charges_the_whole_log() {
        use crate::comm::{
            Broadcast, CommChannel, DownlinkMode, LinkModel, TopK,
        };
        let (mut backend, problem) = setup(10);
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0001,
            max_updates: 1000,
            seed: 12,
            record_stride: 200,
            ..Default::default()
        };
        let mut channel = CommChannel::dense(10).with_broadcast(
            Broadcast::new(
                Box::new(TopK::new(0.3)),
                LinkModel::zero_cost(10),
                DownlinkMode::Delta,
            ),
        );
        let run = run_async_comm(
            &mut backend,
            &delays,
            &mut channel,
            &vec![0.0; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        // With 10 workers, mean staleness ≈ 9, so each restart replays
        // ≈ 10 deltas of the shared log: downlink traffic must be far
        // more than one 40-byte delta per update, but bounded by a full
        // staleness-scaled replay.
        let per_msg = 40u64; // top-3-of-10 delta message
        assert!(
            run.bytes_down > cfg.max_updates * per_msg * 5,
            "replay accounting lost: bytes_down={}",
            run.bytes_down
        );
        assert!(run.bytes_down < cfg.max_updates * per_msg * 20);
        assert!(!run.diverged);
    }

    #[test]
    fn time_budget_respected() {
        let (mut backend, problem) = setup(5);
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0001,
            max_updates: u64::MAX / 2,
            max_time: 30.0,
            seed: 6,
            record_stride: 100,
            ..Default::default()
        };
        let run = run_async(
            &mut backend,
            &delays,
            &vec![0.0; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        assert!(run.total_time <= 31.0);
    }
}
