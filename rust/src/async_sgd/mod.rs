//! Fully-asynchronous distributed SGD — the Fig. 3 comparator, per
//! Dutta et al. [2].
//!
//! Every worker computes the partial gradient of *its own shard* against
//! the model version it last received. Whenever any worker finishes, the
//! master immediately applies that (possibly stale) gradient:
//!
//! ```text
//! w ← w − η ∇F(S_i, w_stale_i)
//! ```
//!
//! hands the worker the fresh model, and the worker starts over. There is
//! no synchronization barrier, so the clock advances on an event queue of
//! per-worker completion times rather than an order statistic.

use crate::comm::{CommChannel, DownlinkMode};
use crate::grad::GradBackend;
use crate::metrics::{Recorder, Sample};
use crate::rng::Pcg64;
use crate::sim::EventQueue;
use crate::straggler::DelayModel;

/// Async-run configuration.
#[derive(Debug, Clone)]
pub struct AsyncConfig {
    /// Step size η.
    pub eta: f32,
    /// Total model updates (each worker completion is one update).
    pub max_updates: u64,
    /// Stop once the virtual clock passes this (0 = no budget).
    pub max_time: f64,
    /// Seed for the delay draws.
    pub seed: u64,
    /// Evaluate + record every this many updates.
    pub record_stride: u64,
    /// Staleness-aware step damping: apply `η/(1 + staleness)` per update.
    ///
    /// Raw delayed SGD is unstable whenever `η·λ_max·τ ≳ 1`; with the
    /// paper's Fig-3 parameters (η = 2·10⁻⁴, λ_max ≈ 3·10³, τ ≈ n−1 = 49)
    /// that product is ≈ 30, so the undamped run diverges (kept available
    /// as an ablation — see EXPERIMENTS.md). The paper does not state its
    /// async stabilisation; this damping is the standard staleness-aware
    /// rule (cf. Zhang et al. 2016) and is the documented substitution.
    pub staleness_damping: bool,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        Self {
            eta: 2e-4,
            max_updates: 100_000,
            max_time: 0.0,
            seed: 0,
            record_stride: 50,
            staleness_damping: true,
        }
    }
}

/// Result of an async run.
pub struct AsyncRun {
    /// Error-vs-time record.
    pub recorder: Recorder,
    /// Final model.
    pub w: Vec<f32>,
    /// Updates applied.
    pub updates: u64,
    /// Final virtual clock.
    pub total_time: f64,
    /// Mean staleness (model versions elapsed between a worker's read and
    /// its gradient's application) — diagnostic for the Fig. 3 discussion.
    pub mean_staleness: f64,
    /// True if the run blew up (non-finite model) and stopped early.
    pub diverged: bool,
    /// Encoded bytes of all applied gradient messages.
    pub bytes_sent: u64,
    /// Total upload time of applied messages.
    pub comm_time: f64,
    /// Encoded bytes of all model downloads (one per applied update —
    /// the async downlink is unicast).
    pub bytes_down: u64,
    /// Total download time charged.
    pub down_time: f64,
}

/// Run asynchronous SGD from `w0` with the zero-cost dense channel.
pub fn run_async(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    w0: &[f32],
    cfg: &AsyncConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> AsyncRun {
    let n = backend.n_shards();
    let mut channel = CommChannel::dense(n);
    run_async_comm(backend, delays, &mut channel, w0, cfg, eval_error)
}

/// Run asynchronous SGD from `w0`, shipping every update through
/// `channel`: a worker's completion event fires after compute delay plus
/// the upload delay of its encoded message, and the applied gradient is
/// the channel's reconstruction (error feedback applies every round here,
/// since no async update is ever discarded).
///
/// Bidirectional pricing: with a finite master-ingress capacity an
/// arriving upload waits for the NIC to free (FIFO — arrivals pop in
/// time order, so the queue discipline is consistent) before it is
/// applied, and each restart downloads the fresh model through the
/// channel's downlink, adding a download delay to the worker's next
/// cycle. Workers are assumed to know `w0`, so the initial dispatch
/// carries no download. A `Delta` downlink models a master streaming one
/// shared delta log that every worker replays up to its latest restart:
/// a restarting worker downloads every delta appended since it last
/// pulled (one per intervening update, i.e. staleness + 1 messages),
/// each priced at the scheme's encoded size.
pub fn run_async_comm(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &AsyncConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> AsyncRun {
    let n = backend.n_shards();
    let d = backend.dim();
    assert_eq!(w0.len(), d, "w0 dimension mismatch");
    assert_eq!(
        channel.n(),
        n,
        "comm channel sized for {} workers, backend has {n}",
        channel.n()
    );

    let mut rng = Pcg64::seed_stream(cfg.seed, 0xA57C);
    let mut comm_rng = Pcg64::seed_stream(cfg.seed, 0xC045);
    // Downlink encoder stream (dense draws nothing — delay stream intact).
    let mut bcast_rng = Pcg64::seed_stream(cfg.seed, 0xB04E);
    let bytes0 = channel.stats.bytes_sent;
    let comm_t0 = channel.stats.comm_time;
    let down0 = channel.stats.bytes_down;
    let down_t0 = channel.stats.down_time;
    let mut w = w0.to_vec();
    let mut g_raw = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    // Shared master-ingress state: when the NIC next frees. With the
    // unlimited default, serve_at is bitwise the arrival time.
    let ingress = *channel.ingress();
    let mut ingress_free = f64::NEG_INFINITY;
    // The effective clock: completion time of the last applied update
    // (equals the event-queue clock when the ingress is unlimited).
    let mut clock = 0.0f64;

    // Zero-cost links price every message at exactly 0.0, so the upload
    // term can be added unconditionally without perturbing dense runs.
    let msg_bytes = channel.message_bytes(d);

    // Each worker computes against its stale snapshot; in the simulated
    // timeline only the *version* matters for staleness accounting, and the
    // gradient is computed lazily at completion using the stale snapshot.
    let mut snapshots: Vec<Vec<f32>> = vec![w.clone(); n];
    let mut read_version = vec![0u64; n];
    let mut version = 0u64;
    let mut staleness_sum = 0.0f64;

    let mut queue: EventQueue<usize> = EventQueue::new();
    for i in 0..n {
        let dt = delays.sample(0, i, &mut rng)
            + channel.link_upload_delay(i, msg_bytes);
        queue.schedule_in(dt, i);
    }

    let mut recorder = Recorder::with_stride("async", cfg.record_stride);
    recorder.push_forced(Sample {
        iteration: 0,
        time: 0.0,
        k: 1,
        error: eval_error(&w),
        ..Default::default()
    });

    let mut updates = 0u64;
    let mut diverged = false;
    while updates < cfg.max_updates {
        let ev = match queue.pop() {
            Some(e) => e,
            None => break,
        };
        // Congested ingress: the upload that *arrived* at ev.time is
        // applied once the master's NIC has served it.
        let t_apply = ingress.serve_at(ev.time, ingress_free, msg_bytes);
        ingress_free = t_apply;
        clock = t_apply;
        if cfg.max_time > 0.0 && t_apply > cfg.max_time {
            break;
        }
        let i = ev.payload;

        // Gradient at the worker's stale snapshot, shipped through the
        // channel (compression + error feedback + byte accounting).
        backend.partial_grad(i, &snapshots[i], &mut g_raw);
        channel.transmit(i, &g_raw, &mut g, &mut comm_rng);
        let staleness = version - read_version[i];
        let step = if cfg.staleness_damping {
            cfg.eta / (1.0 + staleness as f32)
        } else {
            cfg.eta
        };
        for (wv, gv) in w.iter_mut().zip(&g) {
            *wv -= step * *gv;
        }
        version += 1;
        staleness_sum += staleness as f64;
        updates += 1;
        if !w[0].is_finite() {
            diverged = true;
            recorder.push_forced(Sample {
                iteration: updates,
                time: clock,
                k: 1,
                error: f64::INFINITY,
                bytes: channel.stats.bytes_sent - bytes0,
                comm_time: channel.stats.comm_time - comm_t0,
                bytes_down: channel.stats.bytes_down - down0,
                down_time: channel.stats.down_time - down_t0,
            });
            break;
        }

        // Worker restarts immediately: it downloads the fresh model
        // through the priced downlink (its snapshot becomes the decoded
        // view — bitwise `w` on the default dense downlink), then its
        // next cycle covers download + compute + upload. Delta mode
        // streams one delta per update, so the worker replays every
        // delta appended since its last restart: the staleness + 1
        // updates applied since it last pulled, one message each.
        let replay = match channel.downlink_mode() {
            DownlinkMode::Full => 1,
            DownlinkMode::Delta => staleness + 1,
        };
        let (_, down_delay) = channel.push_model(
            i,
            &w,
            &mut snapshots[i],
            replay,
            &mut bcast_rng,
        );
        read_version[i] = version;
        let dt = delays.sample(updates, i, &mut rng)
            + channel.link_upload_delay(i, msg_bytes)
            + down_delay;
        queue.schedule_at(t_apply + dt, i);

        if updates % cfg.record_stride == 0 {
            recorder.push_forced(Sample {
                iteration: updates,
                time: clock,
                k: 1,
                error: eval_error(&w),
                bytes: channel.stats.bytes_sent - bytes0,
                comm_time: channel.stats.comm_time - comm_t0,
                bytes_down: channel.stats.bytes_down - down0,
                down_time: channel.stats.down_time - down_t0,
            });
        }
    }

    let total_time = clock;
    if !diverged && updates % cfg.record_stride != 0 {
        recorder.push_forced(Sample {
            iteration: updates,
            time: total_time,
            k: 1,
            error: eval_error(&w),
            bytes: channel.stats.bytes_sent - bytes0,
            comm_time: channel.stats.comm_time - comm_t0,
            bytes_down: channel.stats.bytes_down - down0,
            down_time: channel.stats.down_time - down_t0,
        });
    }

    AsyncRun {
        recorder,
        w,
        updates,
        total_time,
        mean_staleness: if updates > 0 {
            staleness_sum / updates as f64
        } else {
            0.0
        },
        diverged,
        bytes_sent: channel.stats.bytes_sent - bytes0,
        comm_time: channel.stats.comm_time - comm_t0,
        bytes_down: channel.stats.bytes_down - down0,
        down_time: channel.stats.down_time - down_t0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
    use crate::grad::NativeBackend;
    use crate::model::LinRegProblem;
    use crate::straggler::ExponentialDelays;

    fn setup(n: usize) -> (NativeBackend, LinRegProblem) {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 200, d: 10, ..Default::default() },
            4,
        );
        let p = LinRegProblem::new(&ds);
        (NativeBackend::new(Shards::partition(&ds, n)), p)
    }

    #[test]
    fn async_training_descends() {
        let (mut backend, problem) = setup(10);
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0005,
            max_updates: 3000,
            seed: 1,
            record_stride: 100,
            ..Default::default()
        };
        let run = run_async(
            &mut backend,
            &delays,
            &vec![0.0; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 0.01, "{first} -> {last}");
        assert_eq!(run.updates, 3000);
    }

    #[test]
    fn staleness_grows_with_workers() {
        let delays = ExponentialDelays::new(1.0);
        let stale_for = |n: usize| {
            let (mut backend, problem) = setup(n);
            let cfg = AsyncConfig {
                eta: 0.0001,
                max_updates: 2000,
                seed: 3,
                record_stride: 500,
                ..Default::default()
            };
            run_async(&mut backend, &delays, &vec![0.0; 10], &cfg, &mut |w| {
                problem.error(w)
            })
            .mean_staleness
        };
        let s2 = stale_for(2);
        let s20 = stale_for(20);
        // With n concurrent workers mean staleness ≈ n − 1.
        assert!((s2 - 1.0).abs() < 0.3, "s2={s2}");
        assert!(s20 > 10.0, "s20={s20}");
    }

    #[test]
    fn updates_arrive_faster_than_sync_iterations() {
        // n workers each ~exp(1): async applies ~n updates per unit time.
        let (mut backend, problem) = setup(10);
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0001,
            max_updates: 5000,
            seed: 5,
            record_stride: 1000,
            ..Default::default()
        };
        let run = run_async(
            &mut backend,
            &delays,
            &vec![0.0; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        let rate = run.updates as f64 / run.total_time;
        assert!((rate - 10.0).abs() < 1.5, "rate={rate}");
    }

    #[test]
    fn dense_comm_channel_reproduces_the_plain_async_run() {
        use crate::comm::CommChannel;
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0005,
            max_updates: 500,
            seed: 8,
            record_stride: 100,
            ..Default::default()
        };
        let plain = {
            let (mut backend, problem) = setup(10);
            run_async(&mut backend, &delays, &vec![0.0; 10], &cfg, &mut |w| {
                problem.error(w)
            })
        };
        let comm = {
            let (mut backend, problem) = setup(10);
            let mut channel = CommChannel::dense(10);
            run_async_comm(
                &mut backend,
                &delays,
                &mut channel,
                &vec![0.0; 10],
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        assert_eq!(plain.w, comm.w);
        assert_eq!(plain.total_time, comm.total_time);
        assert!(plain.bytes_sent > 0);
        assert_eq!(plain.bytes_sent, comm.bytes_sent);
    }

    #[test]
    fn finite_uplink_slows_async_updates() {
        use crate::comm::{CommChannel, Dense, LinkModel};
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0001,
            max_updates: 2000,
            seed: 9,
            record_stride: 500,
            ..Default::default()
        };
        let (mut backend, problem) = setup(10);
        // d=10 -> 56-byte messages; bw 56 B/unit => +1.0 per completion.
        let mut channel = CommChannel::new(
            Box::new(Dense::new()),
            LinkModel::uniform(10, 56.0, 0.0),
            false,
        );
        let run = run_async_comm(
            &mut backend,
            &delays,
            &mut channel,
            &vec![0.0; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        // Per-worker cycle time is now ~2.0, so 10 workers apply ~5
        // updates per unit time instead of ~10.
        let rate = run.updates as f64 / run.total_time;
        assert!((rate - 5.0).abs() < 1.0, "rate={rate}");
        assert!(run.comm_time > 0.0);
    }

    #[test]
    fn delta_downlink_replay_charges_the_whole_log() {
        use crate::comm::{
            Broadcast, CommChannel, DownlinkMode, LinkModel, TopK,
        };
        let (mut backend, problem) = setup(10);
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0001,
            max_updates: 1000,
            seed: 12,
            record_stride: 200,
            ..Default::default()
        };
        let mut channel = CommChannel::dense(10).with_broadcast(
            Broadcast::new(
                Box::new(TopK::new(0.3)),
                LinkModel::zero_cost(10),
                DownlinkMode::Delta,
            ),
        );
        let run = run_async_comm(
            &mut backend,
            &delays,
            &mut channel,
            &vec![0.0; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        // With 10 workers, mean staleness ≈ 9, so each restart replays
        // ≈ 10 deltas of the shared log: downlink traffic must be far
        // more than one 40-byte delta per update, but bounded by a full
        // staleness-scaled replay.
        let per_msg = 40u64; // top-3-of-10 delta message
        assert!(
            run.bytes_down > cfg.max_updates * per_msg * 5,
            "replay accounting lost: bytes_down={}",
            run.bytes_down
        );
        assert!(run.bytes_down < cfg.max_updates * per_msg * 20);
        assert!(!run.diverged);
    }

    #[test]
    fn time_budget_respected() {
        let (mut backend, problem) = setup(5);
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.0001,
            max_updates: u64::MAX / 2,
            max_time: 30.0,
            seed: 6,
            record_stride: 100,
            ..Default::default()
        };
        let run = run_async(
            &mut backend,
            &delays,
            &vec![0.0; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        assert!(run.total_time <= 31.0);
    }
}
