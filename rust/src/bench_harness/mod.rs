//! Criterion-lite: a tiny benchmark harness (criterion is not available
//! offline). Warmup, timed iterations, robust summary stats, and a
//! throughput-style report. `benches/*.rs` use `harness = false` and drive
//! this directly.

use crate::stats::quantile;
use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Samples, seconds per iteration.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Mean seconds/iteration.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Median seconds/iteration.
    pub fn median(&self) -> f64 {
        quantile(&self.samples, 0.5)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    /// Pretty one-line summary with adaptive units.
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12}  median {:>12}  ±{:>10}  ({} samples)",
            self.name,
            fmt_duration(self.mean()),
            fmt_duration(self.median()),
            fmt_duration(self.stddev()),
            self.samples.len()
        )
    }
}

/// Format seconds with adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark runner with fixed sample counts.
pub struct Bencher {
    /// Warmup iterations before sampling.
    pub warmup_iters: usize,
    /// Number of timed samples.
    pub samples: usize,
    /// Inner iterations per sample (amortizes timer overhead).
    pub iters_per_sample: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 15, iters_per_sample: 1 }
    }
}

impl Bencher {
    /// Quick-run settings for micro-benchmarks.
    pub fn micro() -> Self {
        Self { warmup_iters: 100, samples: 30, iters_per_sample: 100 }
    }

    /// Time `f`, returning a [`BenchResult`]. `f` is called once per inner
    /// iteration; use `std::hint::black_box` inside to defeat DCE.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let start = Instant::now();
            for _ in 0..self.iters_per_sample {
                f();
            }
            samples
                .push(start.elapsed().as_secs_f64() / self.iters_per_sample as f64);
        }
        BenchResult { name: name.to_string(), samples }
    }
}

/// Print a section header for a bench report.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 10 };
        let r = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(x);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() > 0.0);
        assert!(r.median() > 0.0);
        assert!(!r.summary().is_empty());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(2.5e-3), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(2.5e-9), "2.5 ns");
    }
}
