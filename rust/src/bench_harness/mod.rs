//! Criterion-lite: a tiny benchmark harness (criterion is not available
//! offline). Warmup, timed iterations, robust summary stats, a
//! throughput-style report, machine-readable JSON emission
//! ([`write_json_report`] → `BENCH_*.json`, the perf-trajectory record),
//! and the flags shared by every bench binary ([`BenchArgs`]: `--smoke`
//! tiny-grid CI mode, `--jobs` sweep parallelism). `benches/*.rs` use
//! `harness = false` and drive this directly.

use crate::stats::quantile;
use std::path::Path;
use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Samples, seconds per iteration.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Mean seconds/iteration.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Median seconds/iteration.
    pub fn median(&self) -> f64 {
        quantile(&self.samples, 0.5)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    /// q-quantile (0 ≤ q ≤ 1) of the seconds-per-iteration samples.
    pub fn quantile(&self, q: f64) -> f64 {
        quantile(&self.samples, q)
    }

    /// One JSON object for the machine-readable bench report: name,
    /// median, p10/p90 spread, mean/stddev, and the sample count.
    /// Numbers use Rust's `{:e}` float form, which is valid JSON.
    pub fn json_entry(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"median_s\":{:e},\"p10_s\":{:e},\
             \"p90_s\":{:e},\"mean_s\":{:e},\"stddev_s\":{:e},\
             \"samples\":{}}}",
            json_escape(&self.name),
            self.median(),
            self.quantile(0.10),
            self.quantile(0.90),
            self.mean(),
            self.stddev(),
            self.samples.len()
        )
    }

    /// Pretty one-line summary with adaptive units.
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12}  median {:>12}  ±{:>10}  ({} samples)",
            self.name,
            fmt_duration(self.mean()),
            fmt_duration(self.median()),
            fmt_duration(self.stddev()),
            self.samples.len()
        )
    }
}

/// Format seconds with adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark runner with fixed sample counts.
pub struct Bencher {
    /// Warmup iterations before sampling.
    pub warmup_iters: usize,
    /// Number of timed samples.
    pub samples: usize,
    /// Inner iterations per sample (amortizes timer overhead).
    pub iters_per_sample: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 15, iters_per_sample: 1 }
    }
}

impl Bencher {
    /// Quick-run settings for micro-benchmarks.
    pub fn micro() -> Self {
        Self { warmup_iters: 100, samples: 30, iters_per_sample: 100 }
    }

    /// Time `f`, returning a [`BenchResult`]. `f` is called once per inner
    /// iteration; use `std::hint::black_box` inside to defeat DCE.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let start = Instant::now();
            for _ in 0..self.iters_per_sample {
                f();
            }
            samples
                .push(start.elapsed().as_secs_f64() / self.iters_per_sample as f64);
        }
        BenchResult { name: name.to_string(), samples }
    }
}

/// Print a section header for a bench report.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Escape a string for a JSON string literal: `"` and `\` get a
/// backslash, control characters become `\u00XX`, and everything else
/// (including non-ASCII like `§`/`×`, legal raw in JSON) passes through.
/// Rust's `{:?}` is NOT a substitute — it emits `\u{a7}`-style escapes
/// JSON parsers reject.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

/// Write a machine-readable bench report: a JSON array of
/// [`BenchResult::json_entry`] objects. `perf_hotpath` emits
/// `results/BENCH_hotpath.json` through this so perf runs leave a
/// diffable trajectory next to the human-readable text report.
pub fn write_json_report(
    path: &Path,
    results: &[BenchResult],
) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "[")?;
    for (i, r) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        writeln!(f, "  {}{sep}", r.json_entry())?;
    }
    writeln!(f, "]")?;
    f.flush()
}

/// Flags shared by every bench binary, parsed from the argv cargo
/// forwards after `--` (`cargo bench --bench X -- --smoke --jobs 2`).
///
/// * `--smoke` — shrink the grid to a seconds-long end-to-end pass (the
///   CI smoke step runs one figure bench this way, so the sweep-executor
///   path cannot silently rot);
/// * `--jobs N` — sweep worker threads (`0` = all cores, the default;
///   results are byte-identical for every value).
///
/// Unknown tokens (e.g. cargo's own `--bench`) are ignored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BenchArgs {
    /// Tiny-grid CI mode.
    pub smoke: bool,
    /// Sweep worker threads (0 = all cores).
    pub jobs: usize,
}

impl BenchArgs {
    /// Parse from the process argv.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from any token stream (testable). Accepts both `--jobs N`
    /// and `--jobs=N`.
    pub fn parse(args: impl Iterator<Item = String>) -> Self {
        let warn = |v: &str| {
            eprintln!(
                "warning: --jobs expects an integer, got '{v}'; using 0 \
                 (all cores)"
            )
        };
        let mut out = Self { smoke: false, jobs: 0 };
        let mut expect_jobs = false;
        for tok in args {
            if expect_jobs {
                expect_jobs = false;
                // A flag is never the value: `--jobs --smoke` must not
                // eat the next flag, only warn and keep parsing it.
                if !tok.starts_with("--") {
                    match tok.parse::<usize>() {
                        Ok(j) => out.jobs = j,
                        Err(_) => warn(&tok),
                    }
                    continue;
                }
                warn("<missing>");
            }
            match tok.as_str() {
                "--smoke" => out.smoke = true,
                "--jobs" => expect_jobs = true,
                _ => {
                    if let Some(v) = tok.strip_prefix("--jobs=") {
                        match v.parse::<usize>() {
                            Ok(j) => out.jobs = j,
                            Err(_) => warn(v),
                        }
                    }
                    // else: cargo's --bench, filters, etc.
                }
            }
        }
        if expect_jobs {
            warn("<missing>");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 10 };
        let r = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(x);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() > 0.0);
        assert!(r.median() > 0.0);
        assert!(!r.summary().is_empty());
    }

    #[test]
    fn json_report_round_trips_structurally() {
        let r = BenchResult {
            name: "spin".into(),
            samples: vec![1.0e-3, 2.0e-3, 3.0e-3, 4.0e-3, 5.0e-3],
        };
        let entry = r.json_entry();
        assert!(entry.starts_with("{\"name\":\"spin\""), "{entry}");
        // Non-ASCII names pass through raw (legal JSON); quotes,
        // backslashes, and control chars are escaped JSON-style.
        let fancy = BenchResult {
            name: "gemm 256³ — \"setup\"\tpath".into(),
            samples: vec![1.0],
        };
        let e = fancy.json_entry();
        assert!(
            e.contains("\"gemm 256³ — \\\"setup\\\"\\tpath\""),
            "{e}"
        );
        assert!(entry.contains("\"median_s\":3e-3"), "{entry}");
        assert!(entry.contains("\"p10_s\":"), "{entry}");
        assert!(entry.contains("\"samples\":5"), "{entry}");
        assert_eq!(r.quantile(0.5), r.median());

        let dir = std::env::temp_dir().join("adasgd_bench_json_test");
        let path = dir.join("BENCH_test.json");
        write_json_report(&path, &[r.clone(), r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "[");
        assert!(lines[1].ends_with(','), "{}", lines[1]);
        assert!(!lines[2].ends_with(','), "{}", lines[2]);
        assert_eq!(*lines.last().unwrap(), "]");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_args_parse_and_ignore_unknown_tokens() {
        let argv = |s: &str| s.split_whitespace().map(str::to_string);
        assert_eq!(
            BenchArgs::parse(argv("--bench --smoke --jobs 2")),
            BenchArgs { smoke: true, jobs: 2 }
        );
        assert_eq!(
            BenchArgs::parse(argv("--bench somefilter")),
            BenchArgs { smoke: false, jobs: 0 }
        );
        // Malformed --jobs degrades to 0 with a warning, not a panic;
        // so does a trailing --jobs with no value.
        assert_eq!(
            BenchArgs::parse(argv("--jobs lots")),
            BenchArgs { smoke: false, jobs: 0 }
        );
        assert_eq!(
            BenchArgs::parse(argv("--smoke --jobs")),
            BenchArgs { smoke: true, jobs: 0 }
        );
        // The = form works too.
        assert_eq!(
            BenchArgs::parse(argv("--jobs=3")),
            BenchArgs { smoke: false, jobs: 3 }
        );
        // A transposed `--jobs --smoke` must not eat the smoke flag.
        assert_eq!(
            BenchArgs::parse(argv("--jobs --smoke")),
            BenchArgs { smoke: true, jobs: 0 }
        );
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(2.5e-3), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(2.5e-9), "2.5 ns");
    }
}
