//! Criterion-lite: a tiny benchmark harness (criterion is not available
//! offline). Warmup, timed iterations, robust summary stats, a
//! throughput-style report, machine-readable JSON emission
//! ([`write_json_report`] → `BENCH_*.json`, the perf-trajectory record),
//! baseline diffing ([`print_baseline_deltas`] against a prior report),
//! and the flags shared by every bench binary ([`BenchArgs`]: `--smoke`
//! tiny-grid CI mode, `--jobs` sweep parallelism, `--baseline` prior
//! report, `--update-snapshot` committed-snapshot refresh).
//! `benches/*.rs` use `harness = false` and drive this directly.

use crate::stats::quantile;
use std::path::Path;
use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Samples, seconds per iteration.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Mean seconds/iteration.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Median seconds/iteration.
    pub fn median(&self) -> f64 {
        quantile(&self.samples, 0.5)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    /// q-quantile (0 ≤ q ≤ 1) of the seconds-per-iteration samples.
    pub fn quantile(&self, q: f64) -> f64 {
        quantile(&self.samples, q)
    }

    /// One JSON object for the machine-readable bench report: name,
    /// median, p10/p90 spread, mean/stddev, and the sample count.
    /// Numbers use Rust's `{:e}` float form, which is valid JSON.
    pub fn json_entry(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"median_s\":{:e},\"p10_s\":{:e},\
             \"p90_s\":{:e},\"mean_s\":{:e},\"stddev_s\":{:e},\
             \"samples\":{}}}",
            json_escape(&self.name),
            self.median(),
            self.quantile(0.10),
            self.quantile(0.90),
            self.mean(),
            self.stddev(),
            self.samples.len()
        )
    }

    /// Pretty one-line summary with adaptive units.
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12}  median {:>12}  ±{:>10}  ({} samples)",
            self.name,
            fmt_duration(self.mean()),
            fmt_duration(self.median()),
            fmt_duration(self.stddev()),
            self.samples.len()
        )
    }
}

/// Format seconds with adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark runner with fixed sample counts.
pub struct Bencher {
    /// Warmup iterations before sampling.
    pub warmup_iters: usize,
    /// Number of timed samples.
    pub samples: usize,
    /// Inner iterations per sample (amortizes timer overhead).
    pub iters_per_sample: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 15, iters_per_sample: 1 }
    }
}

impl Bencher {
    /// Quick-run settings for micro-benchmarks.
    pub fn micro() -> Self {
        Self { warmup_iters: 100, samples: 30, iters_per_sample: 100 }
    }

    /// Time `f`, returning a [`BenchResult`]. `f` is called once per inner
    /// iteration; use `std::hint::black_box` inside to defeat DCE.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let start = Instant::now();
            for _ in 0..self.iters_per_sample {
                f();
            }
            samples
                .push(start.elapsed().as_secs_f64() / self.iters_per_sample as f64);
        }
        BenchResult { name: name.to_string(), samples }
    }
}

/// Print a section header for a bench report.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Escape a string for a JSON string literal: `"` and `\` get a
/// backslash, control characters become `\u00XX`, and everything else
/// (including non-ASCII like `§`/`×`, legal raw in JSON) passes through.
/// Rust's `{:?}` is NOT a substitute — it emits `\u{a7}`-style escapes
/// JSON parsers reject.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

/// Write a machine-readable bench report: a JSON array of
/// [`BenchResult::json_entry`] objects. `perf_hotpath` emits
/// `results/BENCH_hotpath.json` through this so perf runs leave a
/// diffable trajectory next to the human-readable text report.
pub fn write_json_report(
    path: &Path,
    results: &[BenchResult],
) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "[")?;
    for (i, r) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        writeln!(f, "  {}{sep}", r.json_entry())?;
    }
    writeln!(f, "]")?;
    f.flush()
}

/// Parse a prior `BENCH_*.json` report (the [`write_json_report`]
/// format) into `(name, median seconds)` pairs, in file order.
pub fn parse_baseline(text: &str) -> Result<Vec<(String, f64)>, String> {
    let v = crate::config::json::Json::parse(text)
        .map_err(|e| format!("baseline is not valid JSON: {e}"))?;
    let arr = v
        .as_arr()
        .ok_or_else(|| "baseline report must be a JSON array".to_string())?;
    let mut out = Vec::with_capacity(arr.len());
    for e in arr {
        let name = e
            .get("name")
            .and_then(|j| j.as_str())
            .ok_or_else(|| "baseline entry missing string 'name'".to_string())?;
        let median = e
            .get("median_s")
            .and_then(|j| j.as_f64())
            .ok_or_else(|| {
                format!("baseline entry '{name}' missing numeric 'median_s'")
            })?;
        out.push((name.to_string(), median));
    }
    Ok(out)
}

/// Print per-entry median deltas of `results` against a prior
/// `BENCH_*.json` report at `path` (matched by entry name). Entries
/// present on only one side are listed explicitly so renamed or dropped
/// benchmarks are visible rather than silently unmatched. An unreadable
/// or malformed baseline degrades to a warning, never a panic — perf
/// runs must still emit their own report.
pub fn print_baseline_deltas(path: &Path, results: &[BenchResult]) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            println!("\n(baseline {} not readable: {e})", path.display());
            return;
        }
    };
    let base = match parse_baseline(&text) {
        Ok(b) => b,
        Err(e) => {
            println!("\n(baseline {}: {e})", path.display());
            return;
        }
    };
    if base.is_empty() {
        println!(
            "\n(baseline {}: no baseline entries — nothing to diff)",
            path.display()
        );
        return;
    }
    println!("\n=== median deltas vs baseline {} ===", path.display());
    for r in results {
        let new = r.median();
        match base.iter().find(|(n, _)| n == &r.name) {
            Some((_, old)) if *old > 0.0 => {
                let pct = (new - old) / old * 100.0;
                println!(
                    "{:<44} {:>12} -> {:>12}  ({pct:+.1}%)",
                    r.name,
                    fmt_duration(*old),
                    fmt_duration(new),
                );
            }
            Some(_) => println!(
                "{:<44} {:>12} (baseline median not positive)",
                r.name,
                fmt_duration(new),
            ),
            None => println!(
                "{:<44} {:>12} (new entry — not in baseline)",
                r.name,
                fmt_duration(new),
            ),
        }
    }
    for (name, _) in &base {
        if !results.iter().any(|r| &r.name == name) {
            println!("{name:<44} (baseline-only entry — dropped?)");
        }
    }
}

/// Flags shared by every bench binary, parsed from the argv cargo
/// forwards after `--` (`cargo bench --bench X -- --smoke --jobs 2`).
///
/// * `--smoke` — shrink the grid to a seconds-long end-to-end pass (the
///   CI smoke step runs one figure bench this way, so the sweep-executor
///   path cannot silently rot);
/// * `--jobs N` — sweep worker threads (`0` = all cores, the default;
///   results are byte-identical for every value);
/// * `--baseline PATH` — a prior `BENCH_*.json` report to diff medians
///   against (see [`print_baseline_deltas`]; used by `perf_hotpath`);
/// * `--update-snapshot` — rewrite the repo-root `BENCH_*.json`
///   snapshot in place with this run's results (used by `perf_hotpath`
///   to refresh the committed perf trajectory).
///
/// Unknown tokens (e.g. cargo's own `--bench`) are ignored.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BenchArgs {
    /// Tiny-grid CI mode.
    pub smoke: bool,
    /// Sweep worker threads (0 = all cores).
    pub jobs: usize,
    /// Prior `BENCH_*.json` report to diff medians against.
    pub baseline: Option<String>,
    /// Rewrite the committed repo-root snapshot with this run.
    pub update_snapshot: bool,
}

impl BenchArgs {
    /// Parse from the process argv.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from any token stream (testable). Accepts both the
    /// space-separated (`--jobs N`) and `=` (`--jobs=N`) forms.
    pub fn parse(args: impl Iterator<Item = String>) -> Self {
        let warn = |v: &str| {
            eprintln!(
                "warning: --jobs expects an integer, got '{v}'; using 0 \
                 (all cores)"
            )
        };
        let warn_baseline =
            || eprintln!("warning: --baseline expects a path; ignored");
        let mut out = Self {
            smoke: false,
            jobs: 0,
            baseline: None,
            update_snapshot: false,
        };
        let mut expect_jobs = false;
        let mut expect_baseline = false;
        for tok in args {
            if expect_jobs {
                expect_jobs = false;
                // A flag is never the value: `--jobs --smoke` must not
                // eat the next flag, only warn and keep parsing it.
                if !tok.starts_with("--") {
                    match tok.parse::<usize>() {
                        Ok(j) => out.jobs = j,
                        Err(_) => warn(&tok),
                    }
                    continue;
                }
                warn("<missing>");
            }
            if expect_baseline {
                expect_baseline = false;
                if !tok.starts_with("--") {
                    out.baseline = Some(tok);
                    continue;
                }
                warn_baseline();
            }
            match tok.as_str() {
                "--smoke" => out.smoke = true,
                "--update-snapshot" => out.update_snapshot = true,
                "--jobs" => expect_jobs = true,
                "--baseline" => expect_baseline = true,
                _ => {
                    if let Some(v) = tok.strip_prefix("--jobs=") {
                        match v.parse::<usize>() {
                            Ok(j) => out.jobs = j,
                            Err(_) => warn(v),
                        }
                    } else if let Some(v) = tok.strip_prefix("--baseline=")
                    {
                        out.baseline = Some(v.to_string());
                    }
                    // else: cargo's --bench, filters, etc.
                }
            }
        }
        if expect_jobs {
            warn("<missing>");
        }
        if expect_baseline {
            warn_baseline();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 10 };
        let r = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(x);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() > 0.0);
        assert!(r.median() > 0.0);
        assert!(!r.summary().is_empty());
    }

    #[test]
    fn json_report_round_trips_structurally() {
        let r = BenchResult {
            name: "spin".into(),
            samples: vec![1.0e-3, 2.0e-3, 3.0e-3, 4.0e-3, 5.0e-3],
        };
        let entry = r.json_entry();
        assert!(entry.starts_with("{\"name\":\"spin\""), "{entry}");
        // Non-ASCII names pass through raw (legal JSON); quotes,
        // backslashes, and control chars are escaped JSON-style.
        let fancy = BenchResult {
            name: "gemm 256³ — \"setup\"\tpath".into(),
            samples: vec![1.0],
        };
        let e = fancy.json_entry();
        assert!(
            e.contains("\"gemm 256³ — \\\"setup\\\"\\tpath\""),
            "{e}"
        );
        assert!(entry.contains("\"median_s\":3e-3"), "{entry}");
        assert!(entry.contains("\"p10_s\":"), "{entry}");
        assert!(entry.contains("\"samples\":5"), "{entry}");
        assert_eq!(r.quantile(0.5), r.median());

        let dir = std::env::temp_dir().join("adasgd_bench_json_test");
        let path = dir.join("BENCH_test.json");
        write_json_report(&path, &[r.clone(), r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "[");
        assert!(lines[1].ends_with(','), "{}", lines[1]);
        assert!(!lines[2].ends_with(','), "{}", lines[2]);
        assert_eq!(*lines.last().unwrap(), "]");
        std::fs::remove_dir_all(&dir).ok();
    }

    fn plain(smoke: bool, jobs: usize) -> BenchArgs {
        BenchArgs { smoke, jobs, baseline: None, update_snapshot: false }
    }

    #[test]
    fn bench_args_parse_and_ignore_unknown_tokens() {
        let argv = |s: &str| s.split_whitespace().map(str::to_string);
        assert_eq!(
            BenchArgs::parse(argv("--bench --smoke --jobs 2")),
            plain(true, 2)
        );
        assert_eq!(
            BenchArgs::parse(argv("--bench somefilter")),
            plain(false, 0)
        );
        // Malformed --jobs degrades to 0 with a warning, not a panic;
        // so does a trailing --jobs with no value.
        assert_eq!(BenchArgs::parse(argv("--jobs lots")), plain(false, 0));
        assert_eq!(BenchArgs::parse(argv("--smoke --jobs")), plain(true, 0));
        // The = form works too.
        assert_eq!(BenchArgs::parse(argv("--jobs=3")), plain(false, 3));
        // A transposed `--jobs --smoke` must not eat the smoke flag.
        assert_eq!(BenchArgs::parse(argv("--jobs --smoke")), plain(true, 0));
    }

    #[test]
    fn bench_args_parse_baseline_paths() {
        let argv = |s: &str| s.split_whitespace().map(str::to_string);
        let a = BenchArgs::parse(argv(
            "--smoke --baseline results/BENCH_hotpath.json",
        ));
        assert!(a.smoke);
        assert_eq!(
            a.baseline.as_deref(),
            Some("results/BENCH_hotpath.json")
        );
        // The = form, and a transposed flag that must not be eaten.
        let b = BenchArgs::parse(argv("--baseline=prior.json --jobs 2"));
        assert_eq!(b.baseline.as_deref(), Some("prior.json"));
        assert_eq!(b.jobs, 2);
        let c = BenchArgs::parse(argv("--baseline --smoke"));
        assert_eq!(c.baseline, None);
        assert!(c.smoke);
        // Trailing --baseline with no value warns, not panics.
        assert_eq!(BenchArgs::parse(argv("--baseline")).baseline, None);
    }

    #[test]
    fn bench_args_parse_update_snapshot() {
        let argv = |s: &str| s.split_whitespace().map(str::to_string);
        let a = BenchArgs::parse(argv("--smoke --update-snapshot"));
        assert!(a.smoke);
        assert!(a.update_snapshot);
        assert!(!BenchArgs::parse(argv("--smoke")).update_snapshot);
        // It is a bare switch, not a valued flag: it must not eat the
        // next token.
        let b = BenchArgs::parse(argv("--update-snapshot --jobs 2"));
        assert!(b.update_snapshot);
        assert_eq!(b.jobs, 2);
    }

    #[test]
    fn empty_baseline_prints_a_note_instead_of_an_empty_table() {
        let dir = std::env::temp_dir().join("adasgd_bench_empty_base_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_empty.json");
        write_json_report(&path, &[]).unwrap();
        assert_eq!(parse_baseline("[]").unwrap(), vec![]);
        // Must not panic and must take the empty-note early return
        // (observable here as: no per-entry diff rows are computed for
        // the fresh results — exercised for coverage).
        let fresh =
            BenchResult { name: "entry".into(), samples: vec![1.0] };
        print_baseline_deltas(&path, &[fresh]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn baseline_report_parses_names_and_medians() {
        let r = BenchResult {
            name: "entry a".into(),
            samples: vec![1.0e-3, 2.0e-3, 3.0e-3],
        };
        let dir = std::env::temp_dir().join("adasgd_bench_baseline_test");
        let path = dir.join("BENCH_base.json");
        write_json_report(&path, &[r.clone()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let base = parse_baseline(&text).unwrap();
        assert_eq!(base.len(), 1);
        assert_eq!(base[0].0, "entry a");
        assert!((base[0].1 - 2.0e-3).abs() < 1e-12);
        // The printer tolerates both matched and unmatched entries.
        let fresh = BenchResult { name: "entry b".into(), samples: vec![1.0] };
        print_baseline_deltas(&path, &[r, fresh]);
        // Malformed inputs are errors, not panics.
        assert!(parse_baseline("{}").is_err());
        assert!(parse_baseline("[{\"name\":\"x\"}]").is_err());
        assert!(parse_baseline("not json").is_err());
        print_baseline_deltas(&dir.join("missing.json"), &[]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(2.5e-3), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(2.5e-9), "2.5 ns");
    }
}
