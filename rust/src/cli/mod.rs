//! Hand-rolled CLI parsing (no clap offline): subcommands + `--key value`
//! flags + `--bool-flag` switches, with typed getters and generated help.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first non-flag token), if any.
    pub command: Option<String>,
    /// `--key value` pairs.
    flags: BTreeMap<String, String>,
    /// Bare `--switch` tokens.
    switches: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

/// CLI parse errors.
#[derive(Debug, PartialEq)]
pub enum CliError {
    /// A --flag that expects a value hit the end of argv.
    MissingValue(String),
    /// A flag value failed to parse.
    BadValue {
        /// Flag name.
        flag: String,
        /// Offending value.
        value: String,
        /// Target type.
        ty: &'static str,
    },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(flag) => {
                write!(f, "flag --{flag} expects a value")
            }
            CliError::BadValue { flag, value, ty } => {
                write!(f, "flag --{flag}: cannot parse '{value}' as {ty}")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Flags that take a value (everything else starting with `--` is a
/// switch). Keep in sync with `print_help`.
const VALUED_FLAGS: &[&str] = &[
    "config", "seed", "n", "k", "k0", "step", "thresh", "burnin", "k-max",
    "eta", "max-time", "max-iterations", "out", "artifacts", "steps",
    "workers", "tag", "points", "time-scale", "m", "d", "lambda",
    "record-stride", "comm", "comm-levels", "comm-frac", "bandwidth",
    "link-latency", "slow-workers", "slow-factor", "downlink",
    "down-levels", "down-frac",
    "down-bandwidth", "down-bandwidths", "down-latency", "ingress-bw",
    "ingress", "coding", "replication", "jobs", "intra-jobs", "trace",
    "limit", "format", "root",
];

impl Args {
    /// Parse argv (excluding the binary name).
    pub fn parse(argv: &[String]) -> Result<Self, CliError> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if VALUED_FLAGS.contains(&name) {
                    let val = argv
                        .get(i + 1)
                        .ok_or_else(|| CliError::MissingValue(name.into()))?;
                    out.flags.insert(name.to_string(), val.clone());
                    i += 2;
                } else {
                    out.switches.push(name.to_string());
                    i += 1;
                }
            } else {
                if out.command.is_none() {
                    out.command = Some(tok.clone());
                } else {
                    out.positional.push(tok.clone());
                }
                i += 1;
            }
        }
        Ok(out)
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| CliError::BadValue {
                flag: key.to_string(),
                value: v.clone(),
                ty: std::any::type_name::<T>(),
            }),
        }
    }

    /// Boolean switch.
    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

/// Top-level usage text.
pub fn print_help() {
    println!(
        r#"adasgd — adaptive distributed fastest-k SGD (ICASSP'20 reproduction)

USAGE: adasgd <command> [flags]

COMMANDS:
  fig1        Lemma-1 bound curves + Theorem-1 envelope   [--points N]
  fig2        adaptive vs fixed-k simulation              [--seed S --max-time T]
  fig3        adaptive vs asynchronous SGD                [--seed S --max-time T]
  train       run one experiment                          [--config exp.toml | flags]
  train-transformer
              fastest-k transformer training (artifacts)  [--steps N --workers W --tag tiny]
  threaded    real-thread cluster demo                    [--workers W --k K --time-scale X]
  list-artifacts
              show the compiled artifact registry         [--artifacts DIR]
  repeat      multi-seed aggregate of a config            [--config exp.toml --steps R]
  trace       inspect / replay a recorded event trace:
                trace analyze FILE.trace
                trace dump FILE.trace [--limit N]
                trace replay FILE.trace --config exp.toml
              (record with `train --trace DIR` or `[trace] dir`; replay
              re-drives the engine from the recorded delays and verifies
              the recorder series is bitwise-identical)
  switching-times
              print the Theorem-1 schedule for Example 1
  lint        determinism & layering static analysis (detlint):
                lint [--root DIR] [--format text|json] [--rules]
              scans rust/src, rust/tests, benches, examples; exits
              non-zero on any finding not covered by an explicit
              `// detlint: allow(<rule>)` pragma (CI gate)
  help        this message

COMMON FLAGS:
  --seed S            rng seed (default 0)
  --out FILE.csv      write run series as CSV
  --artifacts DIR     artifact directory (default ./artifacts or $ADASGD_ARTIFACTS)
  --jobs N            sweep worker threads for fig1/fig2/fig3/repeat
                      (0 = all cores, the default; also `[run] jobs` in
                      TOML — results are byte-identical for every N)
  --intra-jobs I      fork–join threads *inside* one round: partial
                      gradients and the merge/apply loops split across
                      I threads with a fixed-order reduction (1 = serial,
                      the default; 0 = all cores; also `[run] intra_jobs`
                      in TOML — results are byte-identical for every I,
                      and compose with --jobs on one shared pool)
  --quiet             suppress ASCII plots

TRAIN FLAGS (no --config):
  --n N --k K | --k0 K0 --step S --thresh T --burnin B --k-max M
  --eta F --max-time T --max-iterations J --m M --d D --lambda L
  --trace DIR         record a binary event trace to
                      DIR/<label>.trace (also `[trace] dir` in TOML;
                      off by default — tracing never changes results)
  --fastpath          O(k · classes) order-statistics rounds for huge n
                      (also `[run] fastpath` in TOML; off by default —
                      same distribution as the exhaustive gather, not
                      the same bits; supports class-heterogeneous
                      closed-form delays, priced uplinks, a uniform
                      downlink, and finite FIFO ingress)
  --async             run the asynchronous baseline instead of fastest-k
  --coding SCHEME     gradient coding: frc | cyclic | bernoulli
                      (redundant shards, exact-gradient rounds; the k
                      policy adapts the wait target and each round waits
                      for the first decodable responder set)
  --replication R     shards per worker for --coding (default 2;
                      frc needs R | N, cyclic/bernoulli take any R <= N)

COMM FLAGS (train; also in [comm] of a TOML config):
  --comm SCHEME       uplink: dense | qsgd | topk | randk  (default dense)
  --comm-levels S     qsgd quantization levels        (default 4)
  --comm-frac F       topk/randk kept fraction        (default 0.1)
  --bandwidth B       uplink bytes per time unit, 0 = infinite
  --link-latency L    fixed per-message upload latency
  --slow-workers W    last W worker ids get a slowed uplink (default 0;
                      needs a finite positive --bandwidth)
  --slow-factor F     uplink slowdown of the slow tail (default 1)
  --no-error-feedback disable the compression residual accumulator
  --downlink SCHEME   model broadcast: dense = full model (default);
                      qsgd | topk | randk = compressed model deltas
                      with a master-side error-feedback residual
  --down-levels S     downlink qsgd levels            (default 4)
  --down-frac F       downlink topk/randk fraction    (default 0.1)
  --down-bandwidth B  downlink bytes per time unit, 0 = infinite
  --down-bandwidths L comma-separated per-worker downlink bandwidths
                      (n entries; 0 = infinite for that worker)
  --down-latency L    fixed per-message download latency
  --ingress-bw C      shared master-ingress bytes per time unit,
                      0 = infinite (independent uploads)
  --ingress D         ingress discipline: fifo (store-and-forward,
                      default) | ps (processor sharing)
"#
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = Args::parse(&argv(
            "fig2 --seed 7 --max-time 2500 --quiet extra",
        ))
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("fig2"));
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get_parse::<f64>("max-time", 0.0).unwrap(), 2500.0);
        assert!(a.has("quiet"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv("fig1")).unwrap();
        assert_eq!(a.get_parse::<u64>("seed", 42).unwrap(), 42);
        assert!(!a.has("quiet"));
    }

    #[test]
    fn errors() {
        assert_eq!(
            Args::parse(&argv("train --seed")).unwrap_err(),
            CliError::MissingValue("seed".into())
        );
        let a = Args::parse(&argv("train --seed abc")).unwrap();
        assert!(matches!(
            a.get_parse::<u64>("seed", 0),
            Err(CliError::BadValue { .. })
        ));
    }
}
