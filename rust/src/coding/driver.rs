//! Engine-backed coded-gather driver.

use super::scheme::CodingScheme;
use crate::comm::CommChannel;
use crate::engine::{
    CodedGather, EngineConfig, EngineCore, RngStreams, RoundEngine,
};
use crate::grad::GradBackend;
use crate::master::{FastestKRun, MasterConfig};
use crate::policy::KPolicy;
use crate::straggler::DelayModel;

/// Run coded gradient descent through the round engine, shipping every
/// contributing message through `channel`.
///
/// This is the full-stack coded path: model broadcast is priced on the
/// downlink, each worker's response time is `r ×` compute plus upload
/// plus download, accepted uploads contend on the shared master ingress,
/// contributing messages pass through uplink compression + error
/// feedback, and `policy` adapts the *wait target* — the engine extends
/// past it along the arrival order to the first decodable responder set
/// (see [`CodedGather`]). Delay draws come from the historical coded rng
/// stream ([`RngStreams::coded`]), so coded trajectories are paired
/// across schemes, replication factors, and channels at a fixed seed.
#[allow(clippy::too_many_arguments)]
pub fn run_coded_comm(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    scheme: &dyn CodingScheme,
    policy: &mut dyn KPolicy,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &MasterConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> FastestKRun {
    run_coded_comm_traced(
        backend, delays, scheme, policy, channel, w0, cfg, eval_error, false,
    )
}

/// [`run_coded_comm`] with opt-in binary event tracing (see
/// [`crate::trace`]); the trajectory is bit-identical either way.
#[allow(clippy::too_many_arguments)]
pub fn run_coded_comm_traced(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    scheme: &dyn CodingScheme,
    policy: &mut dyn KPolicy,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &MasterConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
    trace: bool,
) -> FastestKRun {
    let n = backend.n_shards();
    assert_eq!(
        scheme.n(),
        n,
        "coding scheme built for {} workers, backend has {n}",
        scheme.n()
    );
    assert_eq!(
        channel.n(),
        n,
        "comm channel sized for {} workers, backend has {n}",
        channel.n()
    );
    let engine_cfg = EngineConfig {
        eta: cfg.eta,
        momentum: cfg.momentum,
        max_steps: cfg.max_iterations,
        max_time: cfg.max_time,
        seed: cfg.seed,
        record_stride: cfg.record_stride,
        intra_jobs: cfg.intra_jobs,
    };
    let mut core = EngineCore::new(
        format!("coded-{}", scheme.name()),
        channel,
        delays,
        eval_error,
        w0,
        engine_cfg,
        RngStreams::coded(cfg.seed),
    );
    if trace {
        core.enable_trace(crate::trace::Discipline::Coded);
    }
    let mut gather = CodedGather::new(backend, scheme, policy);
    let run = RoundEngine::new(core).run(&mut gather);
    FastestKRun {
        recorder: run.recorder,
        w: run.w,
        iterations: run.steps,
        total_time: run.total_time,
        k_changes: run.k_changes,
        bytes_sent: run.bytes_sent,
        comm_time: run.comm_time,
        bytes_down: run.bytes_down,
        down_time: run.down_time,
        late_responses: run.late_responses,
        mean_staleness: run.mean_staleness,
        trace: run.trace,
    }
}
