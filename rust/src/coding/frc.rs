//! Fractional repetition gradient coding (grouped placement), plus the
//! legacy coded-GD entry point — now a compatibility shim over the round
//! engine.

use super::driver::run_coded_comm;
use super::scheme::CodingScheme;
use crate::comm::CommChannel;
use crate::data::Shards;
use crate::grad::GradBackend;
use crate::linalg::Matrix;
use crate::master::MasterConfig;
use crate::metrics::Recorder;
use crate::policy::FixedK;
use crate::straggler::DelayModel;

/// A fractional-repetition assignment: `n` workers, replication `r`,
/// `n/r` groups of `r` workers sharing the same `r` shards.
#[derive(Debug, Clone)]
pub struct FrcScheme {
    n: usize,
    r: usize,
    /// `assign[w]` = the r shard ids worker w holds.
    assign: Vec<Vec<usize>>,
}

impl FrcScheme {
    /// Build the grouped assignment; shards are the n data shards (one
    /// per worker in the uncoded scheme).
    ///
    /// Requires `r | n`, surfaced as an `Err` so user-supplied configs
    /// fail at validation time with an actionable message instead of
    /// panicking mid-run.
    pub fn new(n: usize, r: usize) -> Result<Self, String> {
        if n == 0 {
            return Err("frc coding needs n >= 1".into());
        }
        if !(1..=n).contains(&r) || n % r != 0 {
            return Err(format!(
                "frc replication r={r} must divide n={n} (groups of r \
                 workers share r shards); pick r from the divisors of n, \
                 or scheme = \"cyclic\" which allows any r <= n"
            ));
        }
        let groups = n / r;
        let mut assign = vec![Vec::new(); n];
        for g in 0..groups {
            // Group g owns shards g*r .. (g+1)*r; all its workers hold all.
            let shard_ids: Vec<usize> = (g * r..(g + 1) * r).collect();
            for member in 0..r {
                assign[g * r + member] = shard_ids.clone();
            }
        }
        Ok(Self { n, r, assign })
    }
}

impl CodingScheme for FrcScheme {
    fn n(&self) -> usize {
        self.n
    }

    fn r(&self) -> usize {
        self.r
    }

    fn assignment(&self, worker: usize) -> &[usize] {
        &self.assign[worker]
    }

    /// How many responses guarantee exact recovery: `n − r + 1` (the
    /// `r − 1` missing workers cannot empty any group of `r`).
    fn recovery_threshold(&self) -> usize {
        self.n - self.r + 1
    }

    fn name(&self) -> String {
        format!("frc(r={})", self.r)
    }
}

/// Coded-GD run configuration (legacy shim interface).
#[derive(Debug, Clone)]
pub struct CodedConfig {
    /// Step size η.
    pub eta: f32,
    /// Iteration cap.
    pub max_iterations: u64,
    /// Virtual-time budget (0 = none).
    pub max_time: f64,
    /// Delay seed.
    pub seed: u64,
    /// Record stride.
    pub record_stride: u64,
    /// Replication factor r (informational — the scheme argument is
    /// authoritative).
    pub r: usize,
}

/// Result of a coded run (legacy shim interface).
pub struct CodedRun {
    /// Error-vs-time record.
    pub recorder: Recorder,
    /// Final model.
    pub w: Vec<f32>,
    /// Iterations.
    pub iterations: u64,
    /// Final virtual time.
    pub total_time: f64,
}

/// Run exact-recovery coded gradient descent on the zero-cost dense
/// channel: each iteration waits for the fastest
/// [`recovery_threshold`](CodingScheme::recovery_threshold) workers,
/// decodes a shard cover, and applies the *exact* full gradient (no
/// stochastic noise). A worker's compute delay is scaled by `r` — it
/// computes r partial gradients, so redundancy costs compute.
///
/// Compatibility shim over the round engine: builds a
/// [`FixedK`](crate::policy::FixedK) wait target at the recovery
/// threshold and delegates to [`run_coded_comm`] (the engine path with
/// full communication pricing). `rust/tests/test_coded_equivalence.rs`
/// keeps the straight-line coded loop as an executable specification of
/// this composition.
pub fn run_coded_gd(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    scheme: &dyn CodingScheme,
    w0: &[f32],
    cfg: &CodedConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> CodedRun {
    let mut channel = CommChannel::dense(backend.n_shards());
    let mut policy = FixedK::new(scheme.recovery_threshold());
    let mcfg = MasterConfig {
        eta: cfg.eta,
        momentum: 0.0,
        max_iterations: cfg.max_iterations,
        max_time: cfg.max_time,
        seed: cfg.seed,
        record_stride: cfg.record_stride,
        intra_jobs: 1,
    };
    let run = run_coded_comm(
        backend,
        delays,
        scheme,
        &mut policy,
        &mut channel,
        w0,
        &mcfg,
        eval_error,
    );
    CodedRun {
        recorder: run.recorder,
        w: run.w,
        iterations: run.iterations,
        total_time: run.total_time,
    }
}

/// Convenience: shards + scheme consistency check.
pub fn check_scheme(
    shards: &Shards,
    scheme: &dyn CodingScheme,
) -> Result<(), String> {
    if shards.n() != scheme.n() {
        return Err(format!(
            "scheme built for n={} but shards have n={}",
            scheme.n(),
            shards.n()
        ));
    }
    let d = shards.x[0].cols();
    let consistent = shards.x.iter().all(|m: &Matrix| m.cols() == d);
    if !consistent {
        return Err("ragged shard dimensions".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticConfig, SyntheticDataset};
    use crate::grad::NativeBackend;
    use crate::model::{full_gradient, LinRegProblem};
    use crate::straggler::ExponentialDelays;

    #[test]
    fn assignment_covers_all_shards_r_times() {
        let s = FrcScheme::new(12, 3).unwrap();
        let mut count = vec![0usize; 12];
        for w in 0..12 {
            assert_eq!(s.assignment(w).len(), 3);
            for &shard in s.assignment(w) {
                count[shard] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 3), "{count:?}");
        assert_eq!(s.recovery_threshold(), 10);
    }

    #[test]
    fn decode_from_threshold_always_succeeds() {
        let s = FrcScheme::new(12, 3).unwrap();
        // Worst case: the r−1 = 2 missing workers are in the same group.
        let responders: Vec<usize> =
            (0..12).filter(|&w| w != 0 && w != 1).collect();
        let parts = s.decode(&responders).expect("decode");
        assert_eq!(parts.len(), 4);
        // Group 0 must be represented by worker 2, contributing all
        // three of the group's shards.
        assert_eq!(parts[0].worker, 2);
        assert_eq!(parts[0].shards, vec![0, 1, 2]);
        let mut covered: Vec<usize> =
            parts.iter().flat_map(|p| p.shards.clone()).collect();
        covered.sort_unstable();
        assert_eq!(covered, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn decode_fails_below_threshold_when_group_lost() {
        let s = FrcScheme::new(6, 2).unwrap();
        // Both members of group 0 missing.
        assert!(s.decode(&[2, 3, 4, 5]).is_none());
    }

    #[test]
    fn coded_gd_uses_exact_gradient() {
        // One coded iteration must move w exactly along the full gradient.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 6, ..Default::default() },
            7,
        );
        let shards = Shards::partition(&ds, 6);
        let scheme = FrcScheme::new(6, 2).unwrap();
        check_scheme(&shards, &scheme).unwrap();
        let mut backend = NativeBackend::new(shards);
        let problem = LinRegProblem::new(&ds);
        let delays = ExponentialDelays::new(1.0);
        let cfg = CodedConfig {
            eta: 1e-3,
            max_iterations: 1,
            max_time: 0.0,
            seed: 1,
            record_stride: 1,
            r: 2,
        };
        let w0 = vec![0.0f32; 6];
        let run = run_coded_gd(
            &mut backend,
            &delays,
            &scheme,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        let mut gfull = vec![0.0f32; 6];
        full_gradient(&ds.x, &ds.y, &w0, &mut gfull);
        for j in 0..6 {
            let want = -1e-3 * gfull[j];
            let rel = (run.w[j] - want).abs() / want.abs().max(1e-6);
            assert!(rel < 1e-3, "j={j}: {} vs {}", run.w[j], want);
        }
    }

    #[test]
    fn coded_gd_converges() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 200, d: 10, ..Default::default() },
            8,
        );
        let shards = Shards::partition(&ds, 10);
        let scheme = FrcScheme::new(10, 2).unwrap();
        let mut backend = NativeBackend::new(shards);
        let problem = LinRegProblem::new(&ds);
        let delays = ExponentialDelays::new(1.0);
        let cfg = CodedConfig {
            eta: 2e-3,
            max_iterations: 500,
            max_time: 0.0,
            seed: 2,
            record_stride: 100,
            r: 2,
        };
        let run = run_coded_gd(
            &mut backend,
            &delays,
            &scheme,
            &vec![0.0f32; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 1e-3, "{first} -> {last}");
    }

    #[test]
    fn replication_shortens_tail_but_costs_compute() {
        // Per-iteration time: coded waits for X_(n-r+1) scaled by r;
        // r=1 degenerates to waiting for everyone unscaled.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 4, ..Default::default() },
            9,
        );
        let problem = LinRegProblem::new(&ds);
        let delays = ExponentialDelays::new(1.0);
        let time_of = |r: usize| {
            let shards = Shards::partition(&ds, 12);
            let scheme = FrcScheme::new(12, r).unwrap();
            let mut backend = NativeBackend::new(shards);
            let cfg = CodedConfig {
                eta: 1e-3,
                max_iterations: 300,
                max_time: 0.0,
                seed: 3,
                record_stride: 300,
                r,
            };
            run_coded_gd(
                &mut backend,
                &delays,
                &scheme,
                &vec![0.0f32; 4],
                &cfg,
                &mut |w| problem.error(w),
            )
            .total_time
        };
        let t1 = time_of(1); // exact GD, waits for max of 12
        let t3 = time_of(3); // waits for 10th of 12, but 3x compute
        // The r=3 run pays the 3x scaling: per iteration 3*X_(10) vs X_(12);
        // E[X_(12)]≈3.10, E[X_(10)]≈2.02 → 3*2.02 > 3.10.
        assert!(t3 > t1, "replication is not free: t3={t3} t1={t1}");
    }

    #[test]
    fn rejects_bad_replication_as_err_not_panic() {
        let err = FrcScheme::new(10, 3).unwrap_err();
        assert!(err.contains("divide"), "{err}");
        assert!(err.contains("cyclic"), "should point at the fix: {err}");
        assert!(FrcScheme::new(10, 0).is_err());
        assert!(FrcScheme::new(10, 11).is_err());
    }
}
