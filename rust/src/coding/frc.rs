//! Fractional repetition gradient coding.

use crate::data::Shards;
use crate::grad::GradBackend;
use crate::linalg::Matrix;
use crate::master::fastest_k_select;
use crate::metrics::{Recorder, Sample};
use crate::rng::Pcg64;
use crate::straggler::DelayModel;

/// A fractional-repetition assignment: `n` workers, replication `r`.
#[derive(Debug, Clone)]
pub struct FrcScheme {
    n: usize,
    r: usize,
    /// `assign[w]` = the r shard ids worker w holds.
    assign: Vec<Vec<usize>>,
}

impl FrcScheme {
    /// Build the grouped assignment. Requires `r | n`; shards are the
    /// n data shards (one per worker in the uncoded scheme).
    pub fn new(n: usize, r: usize) -> Self {
        assert!(r >= 1 && r <= n && n % r == 0, "need r | n (n={n}, r={r})");
        let groups = n / r;
        let mut assign = vec![Vec::new(); n];
        for g in 0..groups {
            // Group g owns shards g*r .. (g+1)*r; all its workers hold all.
            let shard_ids: Vec<usize> = (g * r..(g + 1) * r).collect();
            for member in 0..r {
                assign[g * r + member] = shard_ids.clone();
            }
        }
        Self { n, r, assign }
    }

    /// Workers n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Replication factor r.
    pub fn r(&self) -> usize {
        self.r
    }

    /// Shards worker `w` computes.
    pub fn assignment(&self, w: usize) -> &[usize] {
        &self.assign[w]
    }

    /// How many responses guarantee exact recovery: `n − r + 1`.
    pub fn recovery_threshold(&self) -> usize {
        self.n - self.r + 1
    }

    /// Greedy decode: given the set of responding workers, pick one
    /// representative per group. Returns `None` if some group has no
    /// responder (cannot happen with ≥ threshold responses).
    pub fn decode(&self, responders: &[usize]) -> Option<Vec<usize>> {
        let groups = self.n / self.r;
        let mut pick: Vec<Option<usize>> = vec![None; groups];
        for &w in responders {
            let g = w / self.r;
            if pick[g].is_none() {
                pick[g] = Some(w);
            }
        }
        pick.into_iter().collect()
    }
}

/// Coded-GD run configuration.
#[derive(Debug, Clone)]
pub struct CodedConfig {
    /// Step size η.
    pub eta: f32,
    /// Iteration cap.
    pub max_iterations: u64,
    /// Virtual-time budget (0 = none).
    pub max_time: f64,
    /// Delay seed.
    pub seed: u64,
    /// Record stride.
    pub record_stride: u64,
    /// Replication factor r.
    pub r: usize,
}

/// Result of a coded run.
pub struct CodedRun {
    /// Error-vs-time record.
    pub recorder: Recorder,
    /// Final model.
    pub w: Vec<f32>,
    /// Iterations.
    pub iterations: u64,
    /// Final virtual time.
    pub total_time: f64,
}

/// Run exact-recovery coded gradient descent: each iteration waits for the
/// fastest `n − r + 1` workers, decodes one representative per group, and
/// applies the *exact* full gradient (no stochastic noise).
///
/// A worker's response time is its delay draw scaled by `r` (it computes
/// r partial gradients — redundancy costs compute).
pub fn run_coded_gd(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    scheme: &FrcScheme,
    w0: &[f32],
    cfg: &CodedConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> CodedRun {
    let n = scheme.n();
    assert_eq!(backend.n_shards(), n, "scheme/backend shard mismatch");
    let d = backend.dim();
    let threshold = scheme.recovery_threshold();

    let mut rng = Pcg64::seed_stream(cfg.seed, 0xC0DE);
    let mut w = w0.to_vec();
    let mut g = vec![0.0f32; d];
    let mut partial = vec![0.0f32; d];
    let mut delay_buf = vec![0.0f64; n];
    let mut idx_buf: Vec<usize> = Vec::with_capacity(n);

    let mut recorder = Recorder::with_stride(
        format!("coded-frc(r={})", scheme.r()),
        cfg.record_stride,
    );
    recorder.push_forced(Sample {
        iteration: 0,
        time: 0.0,
        k: threshold,
        error: eval_error(&w),
        ..Default::default()
    });

    let mut t = 0.0f64;
    let mut j = 0u64;
    while j < cfg.max_iterations && (cfg.max_time <= 0.0 || t < cfg.max_time) {
        backend.on_iteration(j);
        for (i, slot) in delay_buf.iter_mut().enumerate() {
            // r shards per worker → r× compute per response.
            *slot = delays.sample(j, i, &mut rng) * scheme.r() as f64;
        }
        let (x_thr, _) = fastest_k_select(&delay_buf, threshold, &mut idx_buf);
        t += x_thr;

        let reps = scheme
            .decode(&idx_buf[..threshold])
            .expect("threshold responses always decode");
        // Exact full gradient: average each group's r shard gradients.
        g.iter_mut().for_each(|v| *v = 0.0);
        for rep in reps {
            for &shard in scheme.assignment(rep) {
                backend.partial_grad(shard, &w, &mut partial);
                for (gv, pv) in g.iter_mut().zip(&partial) {
                    *gv += *pv;
                }
            }
        }
        let inv_n = 1.0 / n as f32;
        for (wv, gv) in w.iter_mut().zip(g.iter()) {
            *wv -= cfg.eta * *gv * inv_n;
        }

        j += 1;
        if j % cfg.record_stride == 0 {
            recorder.push_forced(Sample {
                iteration: j,
                time: t,
                k: threshold,
                error: eval_error(&w),
                ..Default::default()
            });
        }
    }
    if j % cfg.record_stride != 0 {
        recorder.push_forced(Sample {
            iteration: j,
            time: t,
            k: threshold,
            error: eval_error(&w),
            ..Default::default()
        });
    }
    CodedRun { recorder, w, iterations: j, total_time: t }
}

/// Convenience: shards + scheme consistency check.
pub fn check_scheme(shards: &Shards, scheme: &FrcScheme) -> Result<(), String> {
    if shards.n() != scheme.n() {
        return Err(format!(
            "scheme built for n={} but shards have n={}",
            scheme.n(),
            shards.n()
        ));
    }
    let d = shards.x[0].cols();
    let consistent = shards.x.iter().all(|m: &Matrix| m.cols() == d);
    if !consistent {
        return Err("ragged shard dimensions".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticConfig, SyntheticDataset};
    use crate::grad::NativeBackend;
    use crate::model::{full_gradient, LinRegProblem};
    use crate::straggler::ExponentialDelays;

    #[test]
    fn assignment_covers_all_shards_r_times() {
        let s = FrcScheme::new(12, 3);
        let mut count = vec![0usize; 12];
        for w in 0..12 {
            assert_eq!(s.assignment(w).len(), 3);
            for &shard in s.assignment(w) {
                count[shard] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 3), "{count:?}");
        assert_eq!(s.recovery_threshold(), 10);
    }

    #[test]
    fn decode_from_threshold_always_succeeds() {
        let s = FrcScheme::new(12, 3);
        // Worst case: the r−1 = 2 missing workers are in the same group.
        let responders: Vec<usize> = (0..12).filter(|&w| w != 0 && w != 1).collect();
        let reps = s.decode(&responders).expect("decode");
        assert_eq!(reps.len(), 4);
        // Group 0 must be represented by worker 2.
        assert_eq!(reps[0], 2);
    }

    #[test]
    fn decode_fails_below_threshold_when_group_lost() {
        let s = FrcScheme::new(6, 2);
        // Both members of group 0 missing.
        assert!(s.decode(&[2, 3, 4, 5]).is_none());
    }

    #[test]
    fn coded_gd_uses_exact_gradient() {
        // One coded iteration must move w exactly along the full gradient.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 6, ..Default::default() },
            7,
        );
        let shards = Shards::partition(&ds, 6);
        let scheme = FrcScheme::new(6, 2);
        check_scheme(&shards, &scheme).unwrap();
        let mut backend = NativeBackend::new(shards);
        let problem = LinRegProblem::new(&ds);
        let delays = ExponentialDelays::new(1.0);
        let cfg = CodedConfig {
            eta: 1e-3,
            max_iterations: 1,
            max_time: 0.0,
            seed: 1,
            record_stride: 1,
            r: 2,
        };
        let w0 = vec![0.0f32; 6];
        let run = run_coded_gd(
            &mut backend,
            &delays,
            &scheme,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        let mut gfull = vec![0.0f32; 6];
        full_gradient(&ds.x, &ds.y, &w0, &mut gfull);
        for j in 0..6 {
            let want = -1e-3 * gfull[j];
            let rel = (run.w[j] - want).abs() / want.abs().max(1e-6);
            assert!(rel < 1e-3, "j={j}: {} vs {}", run.w[j], want);
        }
    }

    #[test]
    fn coded_gd_converges() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 200, d: 10, ..Default::default() },
            8,
        );
        let shards = Shards::partition(&ds, 10);
        let scheme = FrcScheme::new(10, 2);
        let mut backend = NativeBackend::new(shards);
        let problem = LinRegProblem::new(&ds);
        let delays = ExponentialDelays::new(1.0);
        let cfg = CodedConfig {
            eta: 2e-3,
            max_iterations: 500,
            max_time: 0.0,
            seed: 2,
            record_stride: 100,
            r: 2,
        };
        let run = run_coded_gd(
            &mut backend,
            &delays,
            &scheme,
            &vec![0.0f32; 10],
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 1e-3, "{first} -> {last}");
    }

    #[test]
    fn replication_shortens_tail_but_costs_compute() {
        // Per-iteration time: coded waits for X_(n-r+1) scaled by r;
        // r=1 degenerates to waiting for everyone unscaled.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 4, ..Default::default() },
            9,
        );
        let problem = LinRegProblem::new(&ds);
        let delays = ExponentialDelays::new(1.0);
        let time_of = |r: usize| {
            let shards = Shards::partition(&ds, 12);
            let scheme = FrcScheme::new(12, r);
            let mut backend = NativeBackend::new(shards);
            let cfg = CodedConfig {
                eta: 1e-3,
                max_iterations: 300,
                max_time: 0.0,
                seed: 3,
                record_stride: 300,
                r,
            };
            run_coded_gd(
                &mut backend,
                &delays,
                &scheme,
                &vec![0.0f32; 4],
                &cfg,
                &mut |w| problem.error(w),
            )
            .total_time
        };
        let t1 = time_of(1); // exact GD, waits for max of 12
        let t3 = time_of(3); // waits for 10th of 12, but 3x compute
        // The r=3 run pays the 3x scaling: per iteration 3*X_(10) vs X_(12);
        // E[X_(12)]≈3.10, E[X_(10)]≈2.02 → 3*2.02 > 3.10.
        assert!(t3 > t1, "replication is not free: t3={t3} t1={t1}");
    }

    #[test]
    #[should_panic(expected = "need r | n")]
    fn rejects_bad_replication() {
        FrcScheme::new(10, 3);
    }
}
