//! Gradient coding — the redundancy-based straggler-mitigation family the
//! paper positions itself against (§I.A, refs [11]–[27]) — as a
//! first-class engine discipline.
//!
//! The layer splits placement from execution:
//!
//! * [`CodingScheme`] describes the *placement*: which `r` shards each
//!   worker holds, the guaranteed recovery threshold, and a greedy
//!   cover-based `decode(responders) → [CoverPart]` that names which
//!   responders contribute which shards (every shard exactly once ⇒ the
//!   combined update is the **exact** full gradient). Implementations:
//!   [`FrcScheme`] (grouped fractional repetition, Tandon et al. ICML
//!   2017; needs `r | n`), [`CyclicRepetition`] (cyclic windows, any
//!   `r ≤ n`), and [`BernoulliScheme`] (seeded random r-regular
//!   placement, probabilistic decode below the threshold).
//! * [`CodedGather`](crate::engine::CodedGather) is the *execution*: a
//!   [`GatherPolicy`](crate::engine::GatherPolicy) that waits for a
//!   policy-adapted target, then extends along the arrival order to the
//!   first decodable responder set — and thereby inherits the engine's
//!   broadcast pricing, uplink compression + error feedback, shared
//!   ingress clocks, and [`KPolicy`](crate::policy::KPolicy) adaptation.
//!
//! [`run_coded_comm`] is the full-stack driver; [`run_coded_gd`] is the
//! legacy compute-only entry point, now a shim over it (fixed wait
//! target at the recovery threshold, dense zero-cost channel). The
//! trade-off the bench `benches/fig_coding.rs` sweeps: coded GD pays
//! `r ×` compute and waits `X_(n−r+1)` for the exact gradient;
//! fastest-k pays `X_(k)` and accepts gradient noise — §I.A's framing,
//! now on one clock with communication priced.

mod driver;
mod frc;
mod scheme;

pub use driver::{run_coded_comm, run_coded_comm_traced};
pub use frc::{check_scheme, run_coded_gd, CodedConfig, CodedRun, FrcScheme};
pub use scheme::{BernoulliScheme, CodingScheme, CoverPart, CyclicRepetition};
