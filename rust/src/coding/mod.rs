//! Gradient coding — the redundancy-based straggler-mitigation family the
//! paper positions itself against (§I.A, refs [11]–[27]).
//!
//! Implemented scheme: **fractional repetition coding** (Tandon et al.,
//! ICML 2017). With replication factor `r`, the n workers are split into
//! `n/r` groups; every worker in a group holds the *same* r shards and
//! sends a fixed linear combination. The master recovers the **exact**
//! full gradient from any `n − r + 1` responses — i.e. it tolerates
//! `r − 1` stragglers per iteration at an `r×` compute/storage overhead.
//!
//! The bench `ablations`/`coded_vs_adaptive` compares this against
//! fastest-k SGD: coded GD pays `X_(n−r+1)` per iteration and gets the
//! exact gradient; fastest-k pays `X_(k)` and accepts gradient noise —
//! exactly the trade-off the paper's introduction sketches.

mod frc;

pub use frc::{run_coded_gd, CodedConfig, CodedRun, FrcScheme};
