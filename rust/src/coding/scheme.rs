//! The [`CodingScheme`] abstraction: redundant shard placement plus a
//! cover-based decoder.
//!
//! A scheme assigns every worker `r` of the `n` data shards; a coded
//! response carries the worker's *combined* gradient over (a subset of)
//! its shards. The master decodes a responder set into a [`CoverPart`]
//! list — which workers contribute, and which of their shards — such
//! that every shard is covered **exactly once**, making the combined
//! update the exact full gradient.
//!
//! Three placements:
//!
//! * [`FrcScheme`](super::FrcScheme) — grouped fractional repetition
//!   (Tandon et al., ICML 2017): `n/r` groups of `r` workers sharing the
//!   same `r` shards. Requires `r | n`.
//! * [`CyclicRepetition`] — worker `w` holds the cyclic window
//!   `{w, w+1, …, w+r−1} (mod n)`. Works for any `r ≤ n` and decodes
//!   from every `n − r + 1` responders.
//! * [`BernoulliScheme`] — a seeded random `r`-regular assignment (each
//!   worker holds `r` distinct shards, each shard is held by exactly `r`
//!   workers). The *guarantee* is the same `n − r + 1` threshold (at
//!   most `r − 1` absentees cannot silence a shard's `r` holders), but
//!   which smaller responder sets decode is a property of the random
//!   draw — the probabilistic decode the gradient-coding literature
//!   studies (Egger, Kas Hanna & Bitar 2023).
//!
//! Decoding is greedy shard cover in responder order (prefix-stable:
//! extending the responder set never changes the parts already chosen),
//! which is what lets the engine's
//! [`CodedGather`](crate::engine::CodedGather) grow an undecodable set
//! one arrival at a time until it decodes.

use crate::rng::{Pcg64, Rng};

/// One contributing worker in a decoded shard cover: the worker and the
/// subset of its assigned shards whose gradients the master uses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoverPart {
    /// The responding worker whose message the master decodes.
    pub worker: usize,
    /// The shards this worker contributes, ascending and disjoint from
    /// every other part's shards.
    pub shards: Vec<usize>,
}

/// A redundant shard placement with exact-recovery decoding.
///
/// Invariants every implementation must uphold (property-tested in
/// `rust/tests/proptests.rs`):
///
/// * [`assignment`](CodingScheme::assignment) returns `r` distinct shard
///   ids in ascending order, and every shard id in `0..n` is assigned to
///   at least one worker — so the full responder set always decodes.
/// * Any responder set of size ≥
///   [`recovery_threshold`](CodingScheme::recovery_threshold) decodes.
/// * Decoding is monotone: adding responders never breaks decodability.
pub trait CodingScheme {
    /// Workers (= shards) n.
    fn n(&self) -> usize;

    /// Replication factor r: shards per worker, and the compute
    /// multiplier a coded worker pays per round.
    fn r(&self) -> usize;

    /// The shards worker `w` computes, ascending.
    fn assignment(&self, worker: usize) -> &[usize];

    /// The smallest responder count that *guarantees* decoding.
    fn recovery_threshold(&self) -> usize;

    /// Display name for labels/benches, e.g. `frc(r=2)`.
    fn name(&self) -> String;

    /// Greedy shard cover in responder order: each responder contributes
    /// its not-yet-covered shards; succeeds once every shard is covered
    /// exactly once. Returns `None` if the responder set leaves a shard
    /// uncovered. Prefix-stable — the parts chosen for a responder
    /// prefix never change when the set is extended.
    fn decode(&self, responders: &[usize]) -> Option<Vec<CoverPart>> {
        let n = self.n();
        let mut covered = vec![false; n];
        let mut remaining = n;
        let mut parts: Vec<CoverPart> = Vec::new();
        for &w in responders {
            let shards: Vec<usize> = self
                .assignment(w)
                .iter()
                .copied()
                .filter(|&s| !covered[s])
                .collect();
            if shards.is_empty() {
                continue;
            }
            for &s in &shards {
                covered[s] = true;
            }
            remaining -= shards.len();
            parts.push(CoverPart { worker: w, shards });
            if remaining == 0 {
                return Some(parts);
            }
        }
        None
    }
}

/// Cyclic repetition: worker `w` holds the window
/// `{w, w+1, …, w+r−1} (mod n)`.
///
/// No divisibility constraint — this is the placement to reach for when
/// `r ∤ n` rules out [`FrcScheme`](super::FrcScheme). Any shard `s` is
/// held by the `r` workers `{s−r+1, …, s} (mod n)`, so at most `r − 1`
/// missing workers can never silence a shard: every `(n−r+1)`-subset
/// decodes.
#[derive(Debug, Clone)]
pub struct CyclicRepetition {
    n: usize,
    r: usize,
    assign: Vec<Vec<usize>>,
}

impl CyclicRepetition {
    /// Build the cyclic assignment. Requires `1 ≤ r ≤ n`.
    pub fn new(n: usize, r: usize) -> Result<Self, String> {
        if n == 0 {
            return Err("cyclic coding needs n >= 1".into());
        }
        if !(1..=n).contains(&r) {
            return Err(format!(
                "cyclic replication r={r} must be in 1..=n (n={n})"
            ));
        }
        let assign = (0..n)
            .map(|w| {
                let mut shards: Vec<usize> =
                    (0..r).map(|j| (w + j) % n).collect();
                shards.sort_unstable();
                shards
            })
            .collect();
        Ok(Self { n, r, assign })
    }
}

impl CodingScheme for CyclicRepetition {
    fn n(&self) -> usize {
        self.n
    }

    fn r(&self) -> usize {
        self.r
    }

    fn assignment(&self, worker: usize) -> &[usize] {
        &self.assign[worker]
    }

    fn recovery_threshold(&self) -> usize {
        self.n - self.r + 1
    }

    fn name(&self) -> String {
        format!("cyclic(r={})", self.r)
    }
}

/// Seeded random `r`-regular assignment ("Bernoulli" placement).
///
/// Each worker holds `r` distinct shards and each shard is held by
/// exactly `r` workers — built from `r` random permutations (one shard
/// per worker per round) with a duplicate-repair pass. Regularity keeps
/// the worst-case guarantee at `n − r + 1` responders, while the
/// decodability of *smaller* responder sets is a property of the random
/// draw — the probabilistic decode regime.
///
/// The construction is a pure function of `(n, r, seed)`; for `r ≤ n/2`
/// the repair pass provably always finds a swap partner, and for larger
/// `r` the builder retries with fresh permutations and, as a last
/// resort, uses a randomly relabelled cyclic layout — still r-regular,
/// duplicate-free, and seed-sensitive.
#[derive(Debug, Clone)]
pub struct BernoulliScheme {
    n: usize,
    r: usize,
    assign: Vec<Vec<usize>>,
}

impl BernoulliScheme {
    /// Build a random `r`-regular assignment from `seed`. Requires
    /// `1 ≤ r ≤ n`.
    pub fn new(n: usize, r: usize, seed: u64) -> Result<Self, String> {
        if n == 0 {
            return Err("bernoulli coding needs n >= 1".into());
        }
        if !(1..=n).contains(&r) {
            return Err(format!(
                "bernoulli replication r={r} must be in 1..=n (n={n})"
            ));
        }
        let mut rng = Pcg64::seed_stream(seed, 0xA551);
        let mut assign = None;
        // Repair can only fail for r > n/2; a fresh permutation draw
        // almost always clears it, so a handful of retries suffice.
        for _attempt in 0..8 {
            assign = Self::random_regular(n, r, &mut rng);
            if assign.is_some() {
                break;
            }
        }
        let mut assign = assign.unwrap_or_else(|| {
            // Last resort: the cyclic layout relabelled by a random
            // shard permutation σ — worker w holds σ of its window, so
            // the code stays r-regular and duplicate-free while the
            // placement still varies with the seed.
            let mut sigma: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut sigma);
            (0..n)
                .map(|w| (0..r).map(|j| sigma[(w + j) % n]).collect())
                .collect()
        });
        for shards in &mut assign {
            shards.sort_unstable();
        }
        Ok(Self { n, r, assign })
    }

    /// `r` rounds of random permutations; round `j` hands worker `w` the
    /// shard `perm[w]`. A within-worker duplicate is repaired by swapping
    /// with a partner `w2` such that neither side re-duplicates; a
    /// counting argument gives at least `n + 1 − 2r` candidates, so for
    /// `r ≤ n/2` repair always succeeds. Returns `None` if a pass finds
    /// no partner.
    fn random_regular(
        n: usize,
        r: usize,
        rng: &mut Pcg64,
    ) -> Option<Vec<Vec<usize>>> {
        let mut assign: Vec<Vec<usize>> = vec![Vec::with_capacity(r); n];
        for _round in 0..r {
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            for w in 0..n {
                if !assign[w].contains(&perm[w]) {
                    continue;
                }
                let partner = (0..n).find(|&w2| {
                    w2 != w
                        && !assign[w2].contains(&perm[w])
                        && !assign[w].contains(&perm[w2])
                })?;
                perm.swap(w, partner);
            }
            for (w, &shard) in perm.iter().enumerate() {
                assign[w].push(shard);
            }
        }
        Some(assign)
    }
}

impl CodingScheme for BernoulliScheme {
    fn n(&self) -> usize {
        self.n
    }

    fn r(&self) -> usize {
        self.r
    }

    fn assignment(&self, worker: usize) -> &[usize] {
        &self.assign[worker]
    }

    fn recovery_threshold(&self) -> usize {
        self.n - self.r + 1
    }

    fn name(&self) -> String {
        format!("bernoulli(r={})", self.r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::FrcScheme;

    fn assert_regular(scheme: &dyn CodingScheme) {
        let (n, r) = (scheme.n(), scheme.r());
        let mut count = vec![0usize; n];
        for w in 0..n {
            let a = scheme.assignment(w);
            assert_eq!(a.len(), r, "worker {w} holds {a:?}");
            let mut sorted = a.to_vec();
            sorted.dedup();
            assert_eq!(sorted.len(), r, "worker {w} duplicates: {a:?}");
            assert!(
                a.windows(2).all(|p| p[0] < p[1]),
                "worker {w} assignment not ascending: {a:?}"
            );
            for &s in a {
                count[s] += 1;
            }
        }
        assert!(
            count.iter().all(|&c| c == r),
            "{}: not r-regular: {count:?}",
            scheme.name()
        );
    }

    #[test]
    fn cyclic_windows_wrap_and_are_regular() {
        let s = CyclicRepetition::new(5, 2).unwrap();
        assert_eq!(s.assignment(4), &[0, 4]);
        assert_eq!(s.assignment(0), &[0, 1]);
        assert_eq!(s.recovery_threshold(), 4);
        assert_regular(&s);
    }

    #[test]
    fn cyclic_allows_r_not_dividing_n() {
        let s = CyclicRepetition::new(10, 3).unwrap();
        assert_regular(&s);
        assert!(FrcScheme::new(10, 3).is_err(), "frc must reject r ∤ n");
    }

    #[test]
    fn cyclic_rejects_out_of_range_r() {
        assert!(CyclicRepetition::new(5, 0).is_err());
        assert!(CyclicRepetition::new(5, 6).is_err());
    }

    // (Exhaustive (n−r+1)-subset decodability for cyclic codes lives in
    // rust/tests/proptests.rs, which enumerates every n ≤ 10 and r.)

    #[test]
    fn bernoulli_is_regular_deterministic_and_seed_sensitive() {
        for (n, r) in [(10, 2), (12, 4), (7, 3), (6, 5), (5, 5)] {
            let s = BernoulliScheme::new(n, r, 42).unwrap();
            assert_regular(&s);
            assert_eq!(s.recovery_threshold(), n - r + 1);
        }
        let a = BernoulliScheme::new(12, 3, 1).unwrap();
        let b = BernoulliScheme::new(12, 3, 1).unwrap();
        for w in 0..12 {
            assert_eq!(a.assignment(w), b.assignment(w));
        }
        let c = BernoulliScheme::new(12, 3, 2).unwrap();
        let differs =
            (0..12).any(|w| a.assignment(w) != c.assignment(w));
        assert!(differs, "different seeds should draw different layouts");
    }

    #[test]
    fn bernoulli_rejects_out_of_range_r() {
        assert!(BernoulliScheme::new(8, 0, 0).is_err());
        assert!(BernoulliScheme::new(8, 9, 0).is_err());
    }

    #[test]
    fn decode_is_prefix_stable_under_extension() {
        let s = CyclicRepetition::new(9, 3).unwrap();
        let responders = [4usize, 0, 7, 2, 5, 8, 1];
        let full = s.decode(&responders).expect("covers everything");
        // Any successful decode of a prefix must be a prefix of the
        // extended decode (the engine relies on this to grow the set).
        for take in 1..responders.len() {
            if let Some(prefix) = s.decode(&responders[..take]) {
                assert_eq!(prefix, full, "greedy decode must early-return");
            }
        }
    }

    #[test]
    fn decode_skips_redundant_responders() {
        // FRC group mates after the first contribute nothing and must
        // not appear as parts.
        let s = FrcScheme::new(6, 2).unwrap();
        let parts = s.decode(&[0, 1, 2, 4]).expect("full cover");
        let workers: Vec<usize> = parts.iter().map(|p| p.worker).collect();
        assert_eq!(workers, vec![0, 2, 4], "worker 1 duplicates group 0");
    }

    #[test]
    fn schemes_are_object_safe() {
        let schemes: Vec<Box<dyn CodingScheme>> = vec![
            Box::new(FrcScheme::new(12, 3).unwrap()),
            Box::new(CyclicRepetition::new(12, 5).unwrap()),
            Box::new(BernoulliScheme::new(12, 3, 9).unwrap()),
        ];
        for s in &schemes {
            let all: Vec<usize> = (0..s.n()).collect();
            let parts = s.decode(&all).expect("full set always decodes");
            let mut covered: Vec<usize> =
                parts.iter().flat_map(|p| p.shards.clone()).collect();
            covered.sort_unstable();
            assert_eq!(covered, all, "{}", s.name());
        }
    }
}
