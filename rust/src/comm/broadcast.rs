//! Downlink side of the channel: priced model broadcast.
//!
//! PR 1 priced only the uplink — the master's model broadcast was free.
//! This module makes the downlink symmetric: the master encodes the model
//! (or the model *delta* since the last broadcast, with a master-side
//! [`ErrorFeedback`] residual, following the communication-efficient
//! adaptive-SGD line of arXiv 2208.03134) and every worker is charged a
//! download delay from a per-worker [`LinkModel`] before its compute
//! starts. The default — dense encoding over a zero-cost link — prices
//! every download at exactly `0.0` and reconstructs the model bitwise, so
//! drivers using it reproduce the uplink-only trajectories bit for bit.

use super::{Compressor, Dense, ErrorFeedback, LinkModel, WireFormat};
use crate::straggler::RngDyn;

/// How the model is encoded on the downlink.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DownlinkMode {
    /// Encode the full model every round (`decode(encode(w))`). With the
    /// [`Dense`] compressor this is lossless and the workers' view is
    /// bitwise the master's model — the default.
    Full,
    /// Encode the model *delta* since the previous broadcast; a
    /// master-side [`ErrorFeedback`] residual carries what compression
    /// dropped into the next delta, so the workers' view tracks the
    /// master's model with bounded lag. The first broadcast bootstraps
    /// the workers with a dense full model.
    Delta,
}

/// The master's model broadcast: encoder + downlink pricing.
///
/// One instance per cluster; [`Broadcast::push`] encodes the current
/// model and writes the *workers' reconstruction* (what every worker will
/// compute its next gradient against) into the caller's buffer. In
/// [`DownlinkMode::Delta`] the reconstruction satisfies the error-feedback
/// telescoping identity `w − view == residual` (exactly in real
/// arithmetic, to f32 rounding here), so the view's lag behind the master
/// is precisely the residual the next delta re-ships.
pub struct Broadcast {
    compressor: Box<dyn Compressor>,
    link: LinkModel,
    mode: DownlinkMode,
    /// Master-side residual for Delta mode (a single accumulator — the
    /// broadcast has exactly one sender).
    feedback: ErrorFeedback,
    /// Last model a delta was encoded against.
    prev: Vec<f32>,
    /// The workers' reconstructed model view (Delta mode).
    view: Vec<f32>,
    /// Scratch: the feedback-adjusted delta.
    delta: Vec<f32>,
    /// Scratch: the decoded delta.
    decoded: Vec<f32>,
    /// Wire model for the Delta bootstrap (dense full-model) message.
    wire: WireFormat,
    initialized: bool,
}

impl Broadcast {
    /// The free default: dense full-model broadcast over a zero-cost
    /// link. Drivers using it reproduce uplink-only trajectories bit for
    /// bit (the encode is a bitwise copy, every download delay is exactly
    /// `0.0`, and no rng is drawn).
    pub fn free(n: usize) -> Self {
        Self::new(
            Box::new(Dense::new()),
            LinkModel::zero_cost(n),
            DownlinkMode::Full,
        )
    }

    /// Broadcast over `link` (which fixes the worker count) with the
    /// given encoding.
    pub fn new(
        compressor: Box<dyn Compressor>,
        link: LinkModel,
        mode: DownlinkMode,
    ) -> Self {
        Self {
            compressor,
            link,
            mode,
            feedback: ErrorFeedback::new(1),
            prev: Vec::new(),
            view: Vec::new(),
            delta: Vec::new(),
            decoded: Vec::new(),
            wire: WireFormat::default(),
            initialized: false,
        }
    }

    /// Number of workers the downlink is sized for.
    pub fn n(&self) -> usize {
        self.link.n()
    }

    /// The encoding mode.
    pub fn mode(&self) -> DownlinkMode {
        self.mode
    }

    /// True iff the downlink charges no delay for any message.
    pub fn link_is_zero_cost(&self) -> bool {
        self.link.is_zero_cost()
    }

    /// Virtual time worker `i` needs to download a `bytes`-sized model
    /// message (same bandwidth + latency pricing as the uplink, applied
    /// in the other direction).
    pub fn download_delay(&self, worker: usize, bytes: u64) -> f64 {
        self.link.upload_delay(worker, bytes)
    }

    /// Encoded size of the *next* push for a d-dimensional model
    /// (data-independent; the Delta bootstrap round ships dense).
    pub fn message_bytes(&self, d: usize) -> u64 {
        match self.mode {
            DownlinkMode::Full => self.compressor.encoded_bytes(d),
            DownlinkMode::Delta if !self.initialized => self.wire.dense(d),
            DownlinkMode::Delta => self.compressor.encoded_bytes(d),
        }
    }

    /// Encode the master's model `w` and write the workers'
    /// reconstruction into `out`; returns the encoded size in bytes.
    /// Stochastic compressors draw from `rng`; [`Dense`] draws nothing.
    pub fn push(
        &mut self,
        w: &[f32],
        out: &mut [f32],
        rng: &mut dyn RngDyn,
    ) -> u64 {
        debug_assert_eq!(w.len(), out.len());
        match self.mode {
            DownlinkMode::Full => self.compressor.apply(w, out, rng),
            DownlinkMode::Delta => {
                if !self.initialized {
                    // Bootstrap: workers receive the full model dense.
                    self.initialized = true;
                    self.prev.clear();
                    self.prev.extend_from_slice(w);
                    self.view.clear();
                    self.view.extend_from_slice(w);
                    out.copy_from_slice(w);
                    return self.wire.dense(w.len());
                }
                self.delta.clear();
                self.delta
                    .extend(w.iter().zip(&self.prev).map(|(a, b)| a - b));
                self.feedback.add_residual(0, &mut self.delta);
                self.decoded.resize(w.len(), 0.0);
                let bytes =
                    self.compressor.apply(&self.delta, &mut self.decoded, rng);
                self.feedback.update(0, &self.delta, &self.decoded);
                for (v, c) in self.view.iter_mut().zip(&self.decoded) {
                    *v += *c;
                }
                self.prev.copy_from_slice(w);
                out.copy_from_slice(&self.view);
                bytes
            }
        }
    }

    /// `‖residual‖²` of the master-side accumulator — how much model mass
    /// the workers' view currently lags by (0 in Full mode).
    pub fn residual_norm_sq(&self) -> f64 {
        self.feedback.residual_norm_sq(0)
    }

    /// `scheme over link` label for recorders and reports.
    pub fn name(&self) -> String {
        let mut s = match self.mode {
            DownlinkMode::Full => self.compressor.name(),
            DownlinkMode::Delta => format!("delta-{}", self.compressor.name()),
        };
        if !self.link.is_zero_cost() {
            s.push_str(" over ");
            s.push_str(&self.link.name());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{QuantizeQsgd, TopK};
    use crate::rng::{Pcg64, Rng};

    fn model(seed: f32) -> Vec<f32> {
        (0..32).map(|i| (i as f32 * 0.7 - 9.0) * seed.cos()).collect()
    }

    #[test]
    fn free_broadcast_is_bitwise_identity_and_charges_nothing() {
        let mut b = Broadcast::free(4);
        assert!(b.link_is_zero_cost());
        let w = model(1.0);
        let mut out = vec![0.0f32; w.len()];
        let mut rng = Pcg64::seed(1);
        let before = rng.clone().next_u64();
        let bytes = b.push(&w, &mut out, &mut rng);
        assert_eq!(out, w);
        assert_eq!(bytes, WireFormat::default().dense(w.len()));
        assert_eq!(bytes, b.message_bytes(w.len()));
        for i in 0..4 {
            assert_eq!(b.download_delay(i, bytes), 0.0);
        }
        assert_eq!(rng.next_u64(), before, "dense must not consume rng");
        assert_eq!(b.residual_norm_sq(), 0.0);
    }

    #[test]
    fn delta_bootstrap_ships_dense_then_compressed() {
        let mut b = Broadcast::new(
            Box::new(TopK::new(0.25)),
            LinkModel::zero_cost(2),
            DownlinkMode::Delta,
        );
        let w = model(2.0);
        let d = w.len();
        let mut out = vec![0.0f32; d];
        let mut rng = Pcg64::seed(2);
        assert_eq!(b.message_bytes(d), WireFormat::default().dense(d));
        let b0 = b.push(&w, &mut out, &mut rng);
        assert_eq!(b0, WireFormat::default().dense(d));
        assert_eq!(out, w, "bootstrap view is exact");
        // Second push is a compressed delta.
        assert_eq!(b.message_bytes(d), TopK::new(0.25).encoded_bytes(d));
        let w2: Vec<f32> = w.iter().map(|v| v + 1.0).collect();
        let b1 = b.push(&w2, &mut out, &mut rng);
        assert_eq!(b1, TopK::new(0.25).encoded_bytes(d));
        assert!(b1 < b0, "delta messages are smaller than the bootstrap");
    }

    #[test]
    fn delta_view_lag_equals_the_residual() {
        // The error-feedback telescoping identity: w − view == residual
        // (up to f32 rounding) after every push.
        let mut b = Broadcast::new(
            Box::new(QuantizeQsgd::new(4)),
            LinkModel::zero_cost(1),
            DownlinkMode::Delta,
        );
        let mut rng = Pcg64::seed(3);
        let mut out = vec![0.0f32; 32];
        let mut w = model(3.0);
        b.push(&w, &mut out, &mut rng);
        for step in 0..10 {
            for (i, v) in w.iter_mut().enumerate() {
                *v += ((step * 7 + i) as f32 * 0.31).sin() * 0.1;
            }
            b.push(&w, &mut out, &mut rng);
            let gap_sq: f64 = w
                .iter()
                .zip(&out)
                .map(|(a, c)| ((a - c) as f64).powi(2))
                .sum();
            let resid = b.residual_norm_sq();
            // The identity is exact in real arithmetic; f32 rounding in
            // the view accumulation leaves a small slack.
            assert!(
                (gap_sq - resid).abs() <= 1e-3 * (1.0 + resid),
                "step {step}: gap {gap_sq} vs residual {resid}"
            );
        }
    }

    #[test]
    fn delta_topk_converges_to_the_model_when_it_stops_moving() {
        let mut b = Broadcast::new(
            Box::new(TopK::new(0.25)),
            LinkModel::zero_cost(1),
            DownlinkMode::Delta,
        );
        let mut rng = Pcg64::seed(4);
        let w = model(4.0);
        let mut out = vec![0.0f32; w.len()];
        b.push(&w, &mut out, &mut rng);
        let w2: Vec<f32> = w.iter().map(|v| v * 2.0 + 0.5).collect();
        // Push the same target repeatedly: top-k of the residual drains
        // it within ceil(1/frac) rounds.
        for _ in 0..6 {
            b.push(&w2, &mut out, &mut rng);
        }
        let gap: f64 = w2
            .iter()
            .zip(&out)
            .map(|(a, c)| ((a - c) as f64).abs())
            .sum();
        // The residual drains exactly; the remaining gap is only the f32
        // rounding of the view accumulation (~ulp per coordinate).
        assert!(gap < 1e-3, "view failed to converge: gap {gap}");
        assert!(b.residual_norm_sq() < 1e-10);
    }

    #[test]
    fn finite_downlink_prices_downloads() {
        let b = Broadcast::new(
            Box::new(Dense::new()),
            LinkModel::uniform(3, 100.0, 0.5),
            DownlinkMode::Full,
        );
        assert!(!b.link_is_zero_cost());
        assert!((b.download_delay(0, 200) - 2.5).abs() < 1e-12);
        assert!(b.name().contains("over"));
    }
}
