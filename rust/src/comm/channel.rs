//! The communication channel the training drivers route gradients through.

use super::{Compressor, Dense, ErrorFeedback, LinkModel};
use crate::straggler::RngDyn;

/// Running totals of everything a channel moved.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    /// Encoded bytes of every accepted (transmitted) message.
    pub bytes_sent: u64,
    /// Sum of the upload delays of accepted messages. This is total
    /// upload *work*, not critical-path time — the per-iteration critical
    /// path is already folded into the driver's clock via the fastest-k
    /// selection.
    pub comm_time: f64,
    /// Accepted messages.
    pub messages: u64,
}

/// One message's accounting, as returned by [`CommChannel::transmit`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transmission {
    /// Encoded size in bytes.
    pub bytes: u64,
    /// Virtual upload delay the sender's link charged.
    pub upload_delay: f64,
}

/// Compressor + error feedback + link, bundled per cluster.
///
/// Drivers price every worker's upload from the data-independent size
/// model *before* the fastest-k selection (see
/// [`CommChannel::message_bytes`] / [`CommChannel::link_upload_delay`]),
/// then [`CommChannel::transmit`] the gradients of the k accepted workers.
pub struct CommChannel {
    compressor: Box<dyn Compressor>,
    link: LinkModel,
    feedback: Option<ErrorFeedback>,
    /// Scratch for the feedback-adjusted gradient `g + e_i`.
    scratch: Vec<f32>,
    /// Running totals (reset with [`CommChannel::reset_stats`]).
    pub stats: CommStats,
}

impl CommChannel {
    /// Build a channel over `link` (which fixes the worker count). Pass
    /// `error_feedback: false` for lossless schemes to skip the (zero)
    /// residual bookkeeping.
    pub fn new(
        compressor: Box<dyn Compressor>,
        link: LinkModel,
        error_feedback: bool,
    ) -> Self {
        let n = link.n();
        Self {
            compressor,
            link,
            feedback: if error_feedback {
                Some(ErrorFeedback::new(n))
            } else {
                None
            },
            scratch: Vec::new(),
            stats: CommStats::default(),
        }
    }

    /// The zero-cost default: dense encoding over a free link, no error
    /// feedback. Drivers using this reproduce pre-`comm` trajectories bit
    /// for bit (the compressor is the identity and no extra rng is drawn
    /// from the delay stream).
    pub fn dense(n: usize) -> Self {
        Self::new(Box::new(Dense::new()), LinkModel::zero_cost(n), false)
    }

    /// Number of workers the channel is sized for.
    pub fn n(&self) -> usize {
        self.link.n()
    }

    /// Encoded message size for a d-dimensional gradient
    /// (data-independent, so it can be priced before any compute).
    pub fn message_bytes(&self, d: usize) -> u64 {
        self.compressor.encoded_bytes(d)
    }

    /// Upload delay of a `bytes`-sized message on worker `i`'s link.
    pub fn link_upload_delay(&self, worker: usize, bytes: u64) -> f64 {
        self.link.upload_delay(worker, bytes)
    }

    /// True iff the link adds no delay for any message.
    pub fn link_is_zero_cost(&self) -> bool {
        self.link.is_zero_cost()
    }

    /// Whether error feedback is accumulating residuals.
    pub fn error_feedback_enabled(&self) -> bool {
        self.feedback.is_some()
    }

    /// `‖e_i‖²` of worker `i`'s residual (0 without error feedback).
    pub fn residual_norm_sq(&self, worker: usize) -> f64 {
        self.feedback
            .as_ref()
            .map_or(0.0, |fb| fb.residual_norm_sq(worker))
    }

    /// Compress-and-deliver worker `i`'s raw gradient: applies error
    /// feedback, writes the master-side reconstruction into `out`, updates
    /// the worker's residual, and accounts bytes + upload time.
    pub fn transmit(
        &mut self,
        worker: usize,
        g: &[f32],
        out: &mut [f32],
        rng: &mut dyn RngDyn,
    ) -> Transmission {
        debug_assert_eq!(g.len(), out.len());
        let bytes = if let Some(fb) = self.feedback.as_mut() {
            self.scratch.clear();
            self.scratch.extend_from_slice(g);
            fb.add_residual(worker, &mut self.scratch);
            let bytes = self.compressor.apply(&self.scratch, out, rng);
            fb.update(worker, &self.scratch, out);
            bytes
        } else {
            self.compressor.apply(g, out, rng)
        };
        let upload_delay = self.link.upload_delay(worker, bytes);
        self.stats.bytes_sent += bytes;
        self.stats.comm_time += upload_delay;
        self.stats.messages += 1;
        Transmission { bytes, upload_delay }
    }

    /// Zero the running totals (residuals are kept — they are model state,
    /// not metrics).
    pub fn reset_stats(&mut self) {
        self.stats = CommStats::default();
    }

    /// `scheme over link` label for recorders and reports.
    pub fn name(&self) -> String {
        let mut s = self.compressor.name();
        if self.error_feedback_enabled() {
            s.push_str("+ef");
        }
        if !self.link.is_zero_cost() {
            s.push_str(" over ");
            s.push_str(&self.link.name());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{TopK, WireFormat};
    use crate::rng::Pcg64;

    #[test]
    fn dense_channel_is_identity_and_free() {
        let mut ch = CommChannel::dense(4);
        assert!(ch.link_is_zero_cost());
        assert!(!ch.error_feedback_enabled());
        let g = [1.0f32, -2.0, 3.0];
        let mut out = [0.0f32; 3];
        let mut rng = Pcg64::seed(1);
        let tx = ch.transmit(0, &g, &mut out, &mut rng);
        assert_eq!(out, g);
        assert_eq!(tx.upload_delay, 0.0);
        assert_eq!(tx.bytes, WireFormat::default().dense(3));
        assert_eq!(ch.stats.messages, 1);
        assert_eq!(ch.stats.bytes_sent, tx.bytes);
        assert_eq!(ch.stats.comm_time, 0.0);
    }

    #[test]
    fn feedback_channel_recovers_dropped_mass_next_round() {
        // top-1 of 3 coords with feedback: round 1 keeps the largest;
        // round 2's feedback-adjusted gradient re-surfaces the rest.
        let mut ch = CommChannel::new(
            Box::new(TopK::new(1.0 / 3.0)),
            LinkModel::zero_cost(1),
            true,
        );
        let mut rng = Pcg64::seed(2);
        let g = [3.0f32, 2.0, 1.0];
        let mut out = [0.0f32; 3];
        ch.transmit(0, &g, &mut out, &mut rng);
        assert_eq!(out, [3.0, 0.0, 0.0]);
        assert_eq!(ch.residual_norm_sq(0), 5.0);
        // Same raw gradient again: residual (0,2,1) makes coord 1 win.
        ch.transmit(0, &g, &mut out, &mut rng);
        assert_eq!(out, [0.0, 4.0, 0.0]);
        // Residual now (3, 0, 2).
        assert_eq!(ch.residual_norm_sq(0), 13.0);
    }

    #[test]
    fn finite_link_charges_upload_time() {
        let mut ch = CommChannel::new(
            Box::new(Dense::new()),
            LinkModel::uniform(2, 100.0, 0.5),
            false,
        );
        let d = 21; // 16 + 84 = 100 bytes
        assert_eq!(ch.message_bytes(d), 100);
        assert!((ch.link_upload_delay(0, 100) - 1.5).abs() < 1e-12);
        let g = vec![1.0f32; d];
        let mut out = vec![0.0f32; d];
        let mut rng = Pcg64::seed(3);
        let tx = ch.transmit(1, &g, &mut out, &mut rng);
        assert!((tx.upload_delay - 1.5).abs() < 1e-12);
        assert!((ch.stats.comm_time - 1.5).abs() < 1e-12);
    }

    #[test]
    fn reset_stats_keeps_residuals() {
        let mut ch = CommChannel::new(
            Box::new(TopK::new(0.5)),
            LinkModel::zero_cost(1),
            true,
        );
        let mut rng = Pcg64::seed(4);
        let mut out = [0.0f32; 2];
        ch.transmit(0, &[5.0, 1.0], &mut out, &mut rng);
        assert!(ch.stats.messages > 0);
        let resid = ch.residual_norm_sq(0);
        ch.reset_stats();
        assert_eq!(ch.stats, CommStats::default());
        assert_eq!(ch.residual_norm_sq(0), resid);
    }
}
