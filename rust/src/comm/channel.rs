//! The communication channel the training drivers route gradients through.

use super::{
    Broadcast, Compressor, Dense, ErrorFeedback, IngressModel, LinkModel,
};
use crate::straggler::RngDyn;

/// Running totals of everything a channel moved.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    /// Encoded bytes of every accepted (transmitted) message.
    pub bytes_sent: u64,
    /// Sum of the upload delays of accepted messages. This is total
    /// upload *work*, not critical-path time — the per-iteration critical
    /// path is already folded into the driver's clock via the fastest-k
    /// selection.
    pub comm_time: f64,
    /// Accepted messages.
    pub messages: u64,
    /// Encoded bytes of every model download. A sync broadcast counts
    /// once per receiving worker (n downloads of one encoding); an async
    /// unicast push counts once.
    pub bytes_down: u64,
    /// Sum of the per-worker download delays charged (download *work*,
    /// not critical path, mirroring `comm_time`).
    pub down_time: f64,
}

/// One message's accounting, as returned by [`CommChannel::transmit`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transmission {
    /// Encoded size in bytes.
    pub bytes: u64,
    /// Virtual upload delay the sender's link charged.
    pub upload_delay: f64,
}

/// Compressor + error feedback + link + downlink + ingress, bundled per
/// cluster — the full bidirectional channel.
///
/// Drivers price every worker's upload from the data-independent size
/// model *before* the fastest-k selection (see
/// [`CommChannel::message_bytes`] / [`CommChannel::link_upload_delay`]),
/// then [`CommChannel::transmit`] the gradients of the k accepted workers.
/// The downlink side ([`CommChannel::broadcast_model`] /
/// [`CommChannel::push_model`]) encodes the model through a [`Broadcast`]
/// and charges each worker a download delay; the [`IngressModel`] lets a
/// round's accepted uploads contend on the master's shared ingress. Both
/// default to free/unlimited, preserving the uplink-only trajectories bit
/// for bit.
pub struct CommChannel {
    compressor: Box<dyn Compressor>,
    link: LinkModel,
    feedback: Option<ErrorFeedback>,
    /// Downlink: priced model broadcast (free dense by default).
    broadcast: Broadcast,
    /// Shared master-ingress capacity (unlimited by default).
    ingress: IngressModel,
    /// Scratch for the feedback-adjusted gradient `g + e_i`.
    scratch: Vec<f32>,
    /// Running totals (reset with [`CommChannel::reset_stats`]).
    pub stats: CommStats,
}

impl CommChannel {
    /// Build a channel over `link` (which fixes the worker count). Pass
    /// `error_feedback: false` for lossless schemes to skip the (zero)
    /// residual bookkeeping. The downlink starts free and the ingress
    /// unlimited; override with [`CommChannel::with_broadcast`] /
    /// [`CommChannel::with_ingress`].
    pub fn new(
        compressor: Box<dyn Compressor>,
        link: LinkModel,
        error_feedback: bool,
    ) -> Self {
        let n = link.n();
        Self {
            compressor,
            link,
            feedback: if error_feedback {
                Some(ErrorFeedback::new(n))
            } else {
                None
            },
            broadcast: Broadcast::free(n),
            ingress: IngressModel::unlimited(),
            scratch: Vec::new(),
            stats: CommStats::default(),
        }
    }

    /// Replace the downlink broadcast (must be sized for the same n).
    pub fn with_broadcast(mut self, broadcast: Broadcast) -> Self {
        assert_eq!(
            broadcast.n(),
            self.n(),
            "broadcast sized for {} workers, channel has {}",
            broadcast.n(),
            self.n()
        );
        self.broadcast = broadcast;
        self
    }

    /// Replace the master-ingress model.
    pub fn with_ingress(mut self, ingress: IngressModel) -> Self {
        self.ingress = ingress;
        self
    }

    /// The zero-cost default: dense encoding over a free link, no error
    /// feedback. Drivers using this reproduce pre-`comm` trajectories bit
    /// for bit (the compressor is the identity and no extra rng is drawn
    /// from the delay stream).
    pub fn dense(n: usize) -> Self {
        Self::new(Box::new(Dense::new()), LinkModel::zero_cost(n), false)
    }

    /// Number of workers the channel is sized for.
    pub fn n(&self) -> usize {
        self.link.n()
    }

    /// Encoded message size for a d-dimensional gradient
    /// (data-independent, so it can be priced before any compute).
    pub fn message_bytes(&self, d: usize) -> u64 {
        self.compressor.encoded_bytes(d)
    }

    /// Upload delay of a `bytes`-sized message on worker `i`'s link.
    pub fn link_upload_delay(&self, worker: usize, bytes: u64) -> f64 {
        self.link.upload_delay(worker, bytes)
    }

    /// True iff the link adds no delay for any message.
    pub fn link_is_zero_cost(&self) -> bool {
        self.link.is_zero_cost()
    }

    /// Broadcast the model to all `n` workers (sync drivers): encodes
    /// once through the downlink, writes the workers' reconstruction into
    /// `out`, accounts `bytes × n` downloads plus every worker's download
    /// delay, and returns the encoded size for per-worker pricing.
    pub fn broadcast_model(
        &mut self,
        w: &[f32],
        out: &mut [f32],
        rng: &mut dyn RngDyn,
    ) -> u64 {
        let bytes = self.broadcast.push(w, out, rng);
        let n = self.n();
        self.stats.bytes_down += bytes * n as u64;
        // A free downlink charges exactly 0.0 per worker, and down_time
        // is always >= +0.0, so skipping the scan is bitwise neutral —
        // and keeps the O(k) fastpath round from hiding an O(n) loop
        // here at n = 10^6.
        if !self.broadcast.link_is_zero_cost() {
            for i in 0..n {
                let delay = self.broadcast.download_delay(i, bytes);
                self.stats.down_time += delay;
            }
        }
        bytes
    }

    /// Send the model to a single `worker` (async unicast): encodes
    /// through the downlink, writes the workers' reconstruction into
    /// `out`, and returns `(bytes, total download delay)`.
    ///
    /// `replay >= 1` is the number of downlink messages the worker must
    /// pull. In [`super::DownlinkMode::Full`] a message is
    /// self-contained, so `replay` is 1; in [`super::DownlinkMode::Delta`]
    /// the encoder's view state is shared — the master streams one delta
    /// log all workers replay — so a restarting worker downloads every
    /// delta appended since its last restart. Each replayed message is
    /// priced at this push's encoded size (earlier deltas of the same
    /// scheme have the same data-independent size; the one dense
    /// bootstrap is the only approximation).
    pub fn push_model(
        &mut self,
        worker: usize,
        w: &[f32],
        out: &mut [f32],
        replay: u64,
        rng: &mut dyn RngDyn,
    ) -> (u64, f64) {
        debug_assert!(replay >= 1, "a restart pulls at least one message");
        let bytes = self.broadcast.push(w, out, rng);
        let delay =
            self.broadcast.download_delay(worker, bytes) * replay as f64;
        self.stats.bytes_down += bytes * replay;
        self.stats.down_time += delay;
        (bytes, delay)
    }

    /// The downlink encoding mode (drivers branch replay accounting on
    /// it).
    pub fn downlink_mode(&self) -> super::DownlinkMode {
        self.broadcast.mode()
    }

    /// Download delay of a `bytes`-sized model message to worker `i`.
    pub fn download_delay(&self, worker: usize, bytes: u64) -> f64 {
        self.broadcast.download_delay(worker, bytes)
    }

    /// True iff the downlink adds no delay for any message.
    pub fn downlink_is_free(&self) -> bool {
        self.broadcast.link_is_zero_cost()
    }

    /// The shared master-ingress model (Copy — drivers may hoist it out
    /// of the per-iteration channel borrow).
    pub fn ingress(&self) -> &IngressModel {
        &self.ingress
    }

    /// `‖residual‖²` of the master-side broadcast accumulator.
    pub fn broadcast_residual_norm_sq(&self) -> f64 {
        self.broadcast.residual_norm_sq()
    }

    /// Whether error feedback is accumulating residuals.
    pub fn error_feedback_enabled(&self) -> bool {
        self.feedback.is_some()
    }

    /// `‖e_i‖²` of worker `i`'s residual (0 without error feedback).
    pub fn residual_norm_sq(&self, worker: usize) -> f64 {
        self.feedback
            .as_ref()
            .map_or(0.0, |fb| fb.residual_norm_sq(worker))
    }

    /// Compress-and-deliver worker `i`'s raw gradient: applies error
    /// feedback, writes the master-side reconstruction into `out`, updates
    /// the worker's residual, and accounts bytes + upload time.
    pub fn transmit(
        &mut self,
        worker: usize,
        g: &[f32],
        out: &mut [f32],
        rng: &mut dyn RngDyn,
    ) -> Transmission {
        debug_assert_eq!(g.len(), out.len());
        let bytes = if let Some(fb) = self.feedback.as_mut() {
            self.scratch.clear();
            self.scratch.extend_from_slice(g);
            fb.add_residual(worker, &mut self.scratch);
            let bytes = self.compressor.apply(&self.scratch, out, rng);
            fb.update(worker, &self.scratch, out);
            bytes
        } else {
            self.compressor.apply(g, out, rng)
        };
        let upload_delay = self.link.upload_delay(worker, bytes);
        self.stats.bytes_sent += bytes;
        self.stats.comm_time += upload_delay;
        self.stats.messages += 1;
        Transmission { bytes, upload_delay }
    }

    /// Zero the running totals (residuals are kept — they are model state,
    /// not metrics).
    pub fn reset_stats(&mut self) {
        self.stats = CommStats::default();
    }

    /// `scheme over link` label for recorders and reports; non-default
    /// downlink and ingress models are appended.
    pub fn name(&self) -> String {
        let mut s = self.compressor.name();
        if self.error_feedback_enabled() {
            s.push_str("+ef");
        }
        if !self.link.is_zero_cost() {
            s.push_str(" over ");
            s.push_str(&self.link.name());
        }
        let down = self.broadcast.name();
        if down != "dense" {
            s.push_str(" / down:");
            s.push_str(&down);
        }
        if !self.ingress.is_unlimited() {
            s.push_str(" / ");
            s.push_str(&self.ingress.name());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{TopK, WireFormat};
    use crate::rng::Pcg64;

    #[test]
    fn dense_channel_is_identity_and_free() {
        let mut ch = CommChannel::dense(4);
        assert!(ch.link_is_zero_cost());
        assert!(!ch.error_feedback_enabled());
        let g = [1.0f32, -2.0, 3.0];
        let mut out = [0.0f32; 3];
        let mut rng = Pcg64::seed(1);
        let tx = ch.transmit(0, &g, &mut out, &mut rng);
        assert_eq!(out, g);
        assert_eq!(tx.upload_delay, 0.0);
        assert_eq!(tx.bytes, WireFormat::default().dense(3));
        assert_eq!(ch.stats.messages, 1);
        assert_eq!(ch.stats.bytes_sent, tx.bytes);
        assert_eq!(ch.stats.comm_time, 0.0);
    }

    #[test]
    fn feedback_channel_recovers_dropped_mass_next_round() {
        // top-1 of 3 coords with feedback: round 1 keeps the largest;
        // round 2's feedback-adjusted gradient re-surfaces the rest.
        let mut ch = CommChannel::new(
            Box::new(TopK::new(1.0 / 3.0)),
            LinkModel::zero_cost(1),
            true,
        );
        let mut rng = Pcg64::seed(2);
        let g = [3.0f32, 2.0, 1.0];
        let mut out = [0.0f32; 3];
        ch.transmit(0, &g, &mut out, &mut rng);
        assert_eq!(out, [3.0, 0.0, 0.0]);
        assert_eq!(ch.residual_norm_sq(0), 5.0);
        // Same raw gradient again: residual (0,2,1) makes coord 1 win.
        ch.transmit(0, &g, &mut out, &mut rng);
        assert_eq!(out, [0.0, 4.0, 0.0]);
        // Residual now (3, 0, 2).
        assert_eq!(ch.residual_norm_sq(0), 13.0);
    }

    #[test]
    fn finite_link_charges_upload_time() {
        let mut ch = CommChannel::new(
            Box::new(Dense::new()),
            LinkModel::uniform(2, 100.0, 0.5),
            false,
        );
        let d = 21; // 16 + 84 = 100 bytes
        assert_eq!(ch.message_bytes(d), 100);
        assert!((ch.link_upload_delay(0, 100) - 1.5).abs() < 1e-12);
        let g = vec![1.0f32; d];
        let mut out = vec![0.0f32; d];
        let mut rng = Pcg64::seed(3);
        let tx = ch.transmit(1, &g, &mut out, &mut rng);
        assert!((tx.upload_delay - 1.5).abs() < 1e-12);
        assert!((ch.stats.comm_time - 1.5).abs() < 1e-12);
    }

    #[test]
    fn default_channel_downlink_is_free_and_ingress_unlimited() {
        let mut ch = CommChannel::dense(4);
        assert!(ch.downlink_is_free());
        assert!(ch.ingress().is_unlimited());
        let w = [1.0f32, -2.0, 3.0];
        let mut view = [0.0f32; 3];
        let mut rng = Pcg64::seed(11);
        let bytes = ch.broadcast_model(&w, &mut view, &mut rng);
        assert_eq!(view, w, "free dense broadcast is bitwise");
        assert_eq!(bytes, WireFormat::default().dense(3));
        assert_eq!(ch.stats.bytes_down, bytes * 4);
        assert_eq!(ch.stats.down_time, 0.0);
        assert_eq!(ch.download_delay(2, bytes), 0.0);
    }

    #[test]
    fn priced_downlink_charges_downloads() {
        use crate::comm::{Broadcast, DownlinkMode, IngressModel};
        let mut ch = CommChannel::dense(2)
            .with_broadcast(Broadcast::new(
                Box::new(Dense::new()),
                LinkModel::uniform(2, 100.0, 0.0),
                DownlinkMode::Full,
            ))
            .with_ingress(IngressModel::new(500.0));
        assert!(!ch.downlink_is_free());
        assert!(!ch.ingress().is_unlimited());
        let w = vec![1.0f32; 21]; // dense message = 100 bytes
        let mut view = vec![0.0f32; 21];
        let mut rng = Pcg64::seed(12);
        let (bytes, delay) = ch.push_model(0, &w, &mut view, 1, &mut rng);
        assert_eq!(bytes, 100);
        assert!((delay - 1.0).abs() < 1e-12);
        assert_eq!(ch.stats.bytes_down, 100);
        assert!((ch.stats.down_time - 1.0).abs() < 1e-12);
        // A replay of 3 messages charges 3x bytes and 3x delay.
        let (_, d3) = ch.push_model(1, &w, &mut view, 3, &mut rng);
        assert!((d3 - 3.0).abs() < 1e-12);
        assert_eq!(ch.stats.bytes_down, 100 + 300);
        assert!((ch.stats.down_time - 4.0).abs() < 1e-12);
        let b2 = ch.broadcast_model(&w, &mut view, &mut rng);
        assert_eq!(ch.stats.bytes_down, 400 + 2 * b2);
        assert!((ch.stats.down_time - 6.0).abs() < 1e-12);
        assert!(ch.name().contains("ingress"));
        assert!(ch.name().contains("down:"));
    }

    #[test]
    #[should_panic(expected = "broadcast sized for")]
    fn mismatched_broadcast_size_is_rejected() {
        use crate::comm::Broadcast;
        let _ = CommChannel::dense(4).with_broadcast(Broadcast::free(3));
    }

    #[test]
    fn reset_stats_keeps_residuals() {
        let mut ch = CommChannel::new(
            Box::new(TopK::new(0.5)),
            LinkModel::zero_cost(1),
            true,
        );
        let mut rng = Pcg64::seed(4);
        let mut out = [0.0f32; 2];
        ch.transmit(0, &[5.0, 1.0], &mut out, &mut rng);
        assert!(ch.stats.messages > 0);
        let resid = ch.residual_norm_sq(0);
        ch.reset_stats();
        assert_eq!(ch.stats, CommStats::default());
        assert_eq!(ch.residual_norm_sq(0), resid);
    }
}
