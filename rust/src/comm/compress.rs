//! Concrete gradient compression schemes.
//!
//! A [`Compressor`] is evaluated in simulation as the composite
//! `decode ∘ encode`: [`Compressor::apply`] writes what the master would
//! reconstruct after the round trip and returns the exact number of bytes
//! the encoded message occupies on the wire. Sizes are data-independent by
//! design — the drivers price every worker's upload *before* computing any
//! gradient, so the fastest-k selection can include upload delays without
//! doing the stragglers' work.
//!
//! The sparsifiers keep surviving coordinates **unscaled** (biased); the
//! usual `d/k` unbiasing rescale is deliberately omitted because the
//! drivers pair compression with [`ErrorFeedback`](super::ErrorFeedback),
//! which both corrects the bias over time and makes the residual identity
//! `decoded + residual == g` exact in f32.

use super::WireFormat;
use crate::rng::Rng;
use crate::straggler::{DynRng, RngDyn};

/// A gradient encoding scheme with an exact wire-size model.
pub trait Compressor: Send + Sync {
    /// Write `decode(encode(g))` into `out` (same length as `g`) and
    /// return the encoded message size in bytes. Stochastic schemes draw
    /// from `rng`; deterministic schemes must not touch it. Takes
    /// `&mut self` so schemes can reuse internal scratch across the many
    /// calls per iteration.
    fn apply(&mut self, g: &[f32], out: &mut [f32], rng: &mut dyn RngDyn)
        -> u64;

    /// Encoded size in bytes for a d-dimensional gradient. Must be
    /// data-independent and agree with what [`Compressor::apply`] returns.
    fn encoded_bytes(&self, d: usize) -> u64;

    /// Scheme name for labels/reports.
    fn name(&self) -> String;
}

/// Identity encoding: full-precision f32 payload. The zero-loss baseline
/// every driver uses by default.
#[derive(Debug, Clone, Default)]
pub struct Dense {
    wire: WireFormat,
}

impl Dense {
    /// Dense scheme with the default wire format.
    pub fn new() -> Self {
        Self::default()
    }

    /// Dense scheme with an explicit wire format.
    pub fn with_wire(wire: WireFormat) -> Self {
        Self { wire }
    }
}

impl Compressor for Dense {
    fn apply(
        &mut self,
        g: &[f32],
        out: &mut [f32],
        _rng: &mut dyn RngDyn,
    ) -> u64 {
        debug_assert_eq!(g.len(), out.len());
        if self.wire.value_bytes >= 4 {
            // Full-precision wire: bitwise identity (the default path).
            out.copy_from_slice(g);
        } else {
            // 2-byte wire: every value rounds through f16.
            for (o, &v) in out.iter_mut().zip(g) {
                *o = self.wire.decode_value(v);
            }
        }
        self.wire.dense(g.len())
    }

    fn encoded_bytes(&self, d: usize) -> u64 {
        self.wire.dense(d)
    }

    fn name(&self) -> String {
        "dense".into()
    }
}

/// QSGD-style stochastic s-level quantization (Alistarh et al. 2017).
///
/// Each coordinate is mapped to `‖g‖₂ · sign(gᵢ) · ξᵢ/s` where
/// `ξᵢ ∈ {0..s}` stochastically rounds `s·|gᵢ|/‖g‖₂`, so the scheme is
/// unbiased and the per-coordinate reconstruction error is at most
/// `‖g‖₂ / s`.
#[derive(Debug, Clone)]
pub struct QuantizeQsgd {
    levels: u32,
    wire: WireFormat,
}

impl QuantizeQsgd {
    /// `levels = s >= 1` quantization levels per sign.
    pub fn new(levels: u32) -> Self {
        Self::with_wire(levels, WireFormat::default())
    }

    /// With an explicit wire format.
    pub fn with_wire(levels: u32, wire: WireFormat) -> Self {
        assert!(levels >= 1, "QSGD needs at least one level");
        Self { levels, wire }
    }

    /// The level count s.
    pub fn levels(&self) -> u32 {
        self.levels
    }
}

impl Compressor for QuantizeQsgd {
    fn apply(
        &mut self,
        g: &[f32],
        out: &mut [f32],
        rng: &mut dyn RngDyn,
    ) -> u64 {
        debug_assert_eq!(g.len(), out.len());
        let mut rng = DynRng(rng);
        let norm = g.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
        let s = self.levels as f64;
        if norm == 0.0 {
            out.iter_mut().for_each(|o| *o = 0.0);
            // Draw nothing: the all-zero message is its own encoding, but
            // the wire still carries the full frame in this size model.
            return self.wire.quantized(g.len(), self.levels);
        }
        // The ‖g‖ scale factor is the one full value the scheme ships; on
        // a 2-byte wire it rounds through f16. The *levels* are still
        // drawn against the sender's full-precision norm — that keeps
        // ξ ∈ {0..s}, the alphabet the wire's ceil(log2(2s+1)) bits per
        // symbol actually price — and only the master's reconstruction
        // uses the rounded scalar. The clamp keeps an f64 norm beyond
        // f32 range *finite* through the cast so it saturates to F16_MAX
        // like every other finite value (an inf recon_norm would decode
        // ξ = 0 coordinates as inf·0 = NaN and poison error feedback).
        // Identity on the default 4-byte wire.
        let recon_norm = if self.wire.value_bytes < 4 {
            super::f16_round_trip(norm.min(f32::MAX as f64) as f32) as f64
        } else {
            norm
        };
        for (o, &v) in out.iter_mut().zip(g) {
            let a = (v.abs() as f64) / norm * s; // in [0, s]
            let low = a.floor();
            let xi = if rng.next_f64() < a - low { low + 1.0 } else { low };
            *o = (recon_norm * (xi / s)) as f32 * v.signum();
        }
        self.wire.quantized(g.len(), self.levels)
    }

    fn encoded_bytes(&self, d: usize) -> u64 {
        self.wire.quantized(d, self.levels)
    }

    fn name(&self) -> String {
        format!("qsgd(s={})", self.levels)
    }
}

/// Kept-coordinate count shared by both sparsifiers: `ceil(frac·d)`, at
/// least 1 for non-empty d. The schemes' `apply` and `encoded_bytes` (and
/// therefore the drivers' precomputed upload pricing) must all agree on
/// this rounding, so it lives in exactly one place.
fn sparse_nnz(frac: f64, d: usize) -> usize {
    ((frac * d as f64).ceil() as usize).clamp(d.min(1), d)
}

fn assert_frac(frac: f64) {
    assert!(frac > 0.0 && frac <= 1.0, "frac must be in (0, 1]");
}

/// Top-k magnitude sparsification: keep the `ceil(frac·d)` coordinates of
/// largest magnitude (ties broken toward the lower index, so the scheme is
/// deterministic), zero the rest, and ship explicit (index, value) pairs.
#[derive(Debug, Clone)]
pub struct TopK {
    frac: f64,
    wire: WireFormat,
    /// Index scratch reused across calls (one transmit per accepted
    /// worker per iteration — avoid a d-length allocation in each).
    scratch: Vec<usize>,
}

impl TopK {
    /// Keep fraction `frac ∈ (0, 1]` of the coordinates.
    pub fn new(frac: f64) -> Self {
        Self::with_wire(frac, WireFormat::default())
    }

    /// With an explicit wire format.
    pub fn with_wire(frac: f64, wire: WireFormat) -> Self {
        assert_frac(frac);
        Self { frac, wire, scratch: Vec::new() }
    }

    /// Kept coordinates for dimension d (at least 1 for non-empty d).
    pub fn nnz(&self, d: usize) -> usize {
        sparse_nnz(self.frac, d)
    }
}

impl Compressor for TopK {
    fn apply(
        &mut self,
        g: &[f32],
        out: &mut [f32],
        _rng: &mut dyn RngDyn,
    ) -> u64 {
        debug_assert_eq!(g.len(), out.len());
        let d = g.len();
        assert!(
            d == 0 || (d - 1) as u64 <= self.wire.max_index(),
            "wire format's {}-byte indices cannot address d={d}",
            self.wire.index_bytes
        );
        let nnz = self.nnz(d);
        out.iter_mut().for_each(|o| *o = 0.0);
        if nnz == 0 {
            return self.wire.sparse(0);
        }
        let idx = &mut self.scratch;
        idx.clear();
        idx.extend(0..d);
        if nnz < d {
            // total_cmp: a NaN coordinate (diverged run) must not feed an
            // inconsistent order into select_nth — NaNs sort as largest
            // magnitude and get selected, never panic the selection.
            idx.select_nth_unstable_by(nnz - 1, |&a, &b| {
                g[b].abs()
                    .total_cmp(&g[a].abs())
                    .then_with(|| a.cmp(&b))
            });
        }
        for &i in &idx[..nnz] {
            // decode_value is the bitwise identity on the default 4-byte
            // wire; the 2-byte wire rounds survivors through f16.
            out[i] = self.wire.decode_value(g[i]);
        }
        self.wire.sparse(nnz)
    }

    fn encoded_bytes(&self, d: usize) -> u64 {
        self.wire.sparse(self.nnz(d))
    }

    fn name(&self) -> String {
        format!("topk(frac={})", self.frac)
    }
}

/// Random-k sparsification: keep `ceil(frac·d)` uniformly random distinct
/// coordinates. The index set is derived from a PRNG stream the master
/// shares, so only the values and a seed go on the wire.
#[derive(Debug, Clone)]
pub struct RandK {
    frac: f64,
    wire: WireFormat,
    /// Index scratch reused across calls.
    scratch: Vec<usize>,
}

impl RandK {
    /// Keep fraction `frac ∈ (0, 1]` of the coordinates.
    pub fn new(frac: f64) -> Self {
        Self::with_wire(frac, WireFormat::default())
    }

    /// With an explicit wire format.
    pub fn with_wire(frac: f64, wire: WireFormat) -> Self {
        assert_frac(frac);
        Self { frac, wire, scratch: Vec::new() }
    }

    /// Kept coordinates for dimension d (at least 1 for non-empty d).
    pub fn nnz(&self, d: usize) -> usize {
        sparse_nnz(self.frac, d)
    }
}

impl Compressor for RandK {
    fn apply(
        &mut self,
        g: &[f32],
        out: &mut [f32],
        rng: &mut dyn RngDyn,
    ) -> u64 {
        debug_assert_eq!(g.len(), out.len());
        let d = g.len();
        let nnz = self.nnz(d);
        out.iter_mut().for_each(|o| *o = 0.0);
        let mut rng = DynRng(rng);
        // Partial Fisher–Yates: the first nnz slots become a uniform
        // sample of distinct indices.
        let idx = &mut self.scratch;
        idx.clear();
        idx.extend(0..d);
        for i in 0..nnz.min(d.saturating_sub(1)) {
            let j = i + rng.next_below((d - i) as u64) as usize;
            idx.swap(i, j);
        }
        for &i in &idx[..nnz] {
            // Identity on the default wire; f16 rounding on the 2-byte
            // wire. (RandK ships a seed, not indices, so the index width
            // does not constrain d here.)
            out[i] = self.wire.decode_value(g[i]);
        }
        self.wire.seeded_sparse(nnz)
    }

    fn encoded_bytes(&self, d: usize) -> u64 {
        self.wire.seeded_sparse(self.nnz(d))
    }

    fn name(&self) -> String {
        format!("randk(frac={})", self.frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn gradient() -> Vec<f32> {
        (0..64)
            .map(|i| ((i as f32) * 0.37 - 11.0) * if i % 3 == 0 { -1.0 } else { 1.0 })
            .collect()
    }

    #[test]
    fn dense_is_identity_and_prices_full_payload() {
        let g = gradient();
        let mut out = vec![0.0f32; g.len()];
        let mut rng = Pcg64::seed(1);
        let mut c = Dense::new();
        let bytes = c.apply(&g, &mut out, &mut rng);
        assert_eq!(out, g);
        assert_eq!(bytes, c.encoded_bytes(g.len()));
        assert_eq!(bytes, 16 + 4 * 64);
    }

    #[test]
    fn topk_keeps_largest_magnitudes_exactly() {
        let g = gradient();
        let mut out = vec![0.0f32; g.len()];
        let mut rng = Pcg64::seed(2);
        let mut c = TopK::new(0.25);
        let bytes = c.apply(&g, &mut out, &mut rng);
        let nnz = c.nnz(g.len());
        assert_eq!(nnz, 16);
        assert_eq!(bytes, c.encoded_bytes(g.len()));
        let kept: Vec<usize> =
            (0..g.len()).filter(|&i| out[i] != 0.0).collect();
        assert_eq!(kept.len(), nnz);
        // Every kept coordinate is bitwise the input...
        for &i in &kept {
            assert_eq!(out[i], g[i]);
        }
        // ...and no dropped magnitude exceeds a kept one.
        let min_kept =
            kept.iter().map(|&i| g[i].abs()).fold(f32::INFINITY, f32::min);
        for i in 0..g.len() {
            if !kept.contains(&i) {
                assert!(g[i].abs() <= min_kept);
            }
        }
    }

    #[test]
    fn topk_is_deterministic_and_rng_free() {
        let g = gradient();
        let mut c = TopK::new(0.1);
        let mut rng = Pcg64::seed(3);
        let before = rng.clone().next_u64();
        let mut a = vec![0.0f32; g.len()];
        let mut b = vec![0.0f32; g.len()];
        c.apply(&g, &mut a, &mut rng);
        c.apply(&g, &mut b, &mut rng);
        assert_eq!(a, b);
        assert_eq!(rng.next_u64(), before, "TopK must not consume rng");
    }

    #[test]
    fn topk_survives_nan_gradients_without_panicking() {
        // A diverged run can hand the channel NaN coordinates; selection
        // must stay a total order (total_cmp), not panic mid-run.
        let g = vec![1.0f32, f32::NAN, -3.0, 2.0, f32::NAN, 0.5];
        let mut c = TopK::new(0.5);
        let mut rng = Pcg64::seed(8);
        let mut out = vec![0.0f32; g.len()];
        let bytes = c.apply(&g, &mut out, &mut rng);
        assert_eq!(bytes, c.encoded_bytes(g.len()));
        // NaNs order above every finite magnitude, so both are selected.
        assert!(out[1].is_nan() && out[4].is_nan());
        assert_eq!(out[2], -3.0);
    }

    #[test]
    fn randk_keeps_exactly_nnz_distinct_unscaled_coords() {
        let g: Vec<f32> = (0..100).map(|i| 1.0 + i as f32).collect();
        let mut c = RandK::new(0.1);
        let mut rng = Pcg64::seed(4);
        let mut out = vec![0.0f32; g.len()];
        let bytes = c.apply(&g, &mut out, &mut rng);
        assert_eq!(bytes, c.encoded_bytes(g.len()));
        let kept: Vec<usize> =
            (0..g.len()).filter(|&i| out[i] != 0.0).collect();
        assert_eq!(kept.len(), 10);
        for &i in &kept {
            assert_eq!(out[i], g[i]);
        }
        // A different rng state picks a different subset (overwhelmingly).
        let mut rng2 = Pcg64::seed(5);
        let mut out2 = vec![0.0f32; g.len()];
        c.apply(&g, &mut out2, &mut rng2);
        assert_ne!(out, out2);
    }

    #[test]
    fn qsgd_is_within_the_per_coordinate_bound() {
        let g = gradient();
        let norm = g.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
        for levels in [1u32, 2, 4, 16] {
            let mut c = QuantizeQsgd::new(levels);
            let mut rng = Pcg64::seed(6 + levels as u64);
            let mut out = vec![0.0f32; g.len()];
            let bytes = c.apply(&g, &mut out, &mut rng);
            assert_eq!(bytes, c.encoded_bytes(g.len()));
            let bound = norm / levels as f64 + 1e-4 * norm;
            for (o, &v) in out.iter().zip(&g) {
                assert!(
                    ((*o as f64) - (v as f64)).abs() <= bound,
                    "levels={levels}: |{o} - {v}| > {bound}"
                );
                // Sign is preserved or the coordinate collapsed to zero.
                assert!(*o == 0.0 || o.signum() == v.signum());
            }
        }
    }

    #[test]
    fn qsgd_zero_gradient_stays_zero() {
        let g = vec![0.0f32; 16];
        let mut c = QuantizeQsgd::new(4);
        let mut rng = Pcg64::seed(9);
        let mut out = vec![1.0f32; 16];
        c.apply(&g, &mut out, &mut rng);
        assert!(out.iter().all(|&o| o == 0.0));
    }

    #[test]
    fn f16_wire_rounds_values_and_halves_the_payload() {
        use crate::comm::{f16_round_trip, WireFormat};
        let g = gradient();
        let mut rng = Pcg64::seed(11);
        let mut out = vec![0.0f32; g.len()];
        let mut c = Dense::with_wire(WireFormat::default().f16_values());
        let bytes = c.apply(&g, &mut out, &mut rng);
        assert_eq!(bytes, 16 + 2 * 64);
        assert_eq!(bytes, c.encoded_bytes(g.len()));
        for (o, &v) in out.iter().zip(&g) {
            assert_eq!(o.to_bits(), f16_round_trip(v).to_bits());
            // f16 keeps ~3 decimal digits: the loss is bounded.
            assert!((o - v).abs() <= v.abs() * 1e-3 + 1e-7);
        }
        // TopK on the same wire rounds only the survivors.
        let mut t =
            TopK::with_wire(0.25, WireFormat::default().f16_values());
        let tb = t.apply(&g, &mut out, &mut rng);
        assert_eq!(tb, 16 + 16 * (4 + 2));
        for (i, o) in out.iter().enumerate() {
            assert!(
                *o == 0.0 || o.to_bits() == f16_round_trip(g[i]).to_bits()
            );
        }
    }

    #[test]
    fn u16_indices_halve_sparse_index_cost() {
        use crate::comm::WireFormat;
        let g = gradient();
        let mut rng = Pcg64::seed(12);
        let mut out = vec![0.0f32; g.len()];
        let mut c =
            TopK::with_wire(0.25, WireFormat::default().compact_indices());
        let bytes = c.apply(&g, &mut out, &mut rng);
        assert_eq!(bytes, 16 + 16 * (2 + 4));
        // Values are untouched on the full-precision value wire.
        let kept: Vec<usize> =
            (0..g.len()).filter(|&i| out[i] != 0.0).collect();
        for &i in &kept {
            assert_eq!(out[i].to_bits(), g[i].to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "cannot address")]
    fn u16_indices_reject_oversized_dimensions() {
        let g = vec![1.0f32; 70_000];
        let mut out = vec![0.0f32; 70_000];
        let mut rng = Pcg64::seed(13);
        let mut c = TopK::with_wire(
            0.01,
            crate::comm::WireFormat::default().compact_indices(),
        );
        let _ = c.apply(&g, &mut out, &mut rng);
    }

    #[test]
    fn qsgd_f16_wire_rounds_the_norm_only() {
        use crate::comm::{f16_round_trip, WireFormat};
        let g = gradient();
        let norm =
            g.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
        let mut c =
            QuantizeQsgd::with_wire(4, WireFormat::default().f16_values());
        let mut rng = Pcg64::seed(14);
        let mut out = vec![0.0f32; g.len()];
        let bytes = c.apply(&g, &mut out, &mut rng);
        // Norm scalar is 2 bytes now: 16 + 2 + ceil(64·4/8).
        assert_eq!(bytes, 16 + 2 + 32);
        // Every nonzero reconstruction is a multiple of the f16 norm / s.
        let f16_norm = f16_round_trip(norm as f32) as f64;
        for o in out.iter().filter(|o| **o != 0.0) {
            let ratio = (o.abs() as f64) / (f16_norm / 4.0);
            assert!(
                (ratio - ratio.round()).abs() < 1e-3,
                "{o} is not a level multiple of the f16 norm"
            );
        }
    }

    #[test]
    fn schemes_order_by_wire_size_as_expected() {
        let d = 100;
        let dense = Dense::new().encoded_bytes(d);
        let topk = TopK::new(0.1).encoded_bytes(d);
        let randk = RandK::new(0.1).encoded_bytes(d);
        let qsgd = QuantizeQsgd::new(4).encoded_bytes(d);
        assert!(topk < dense);
        assert!(randk < topk, "seeded indices beat explicit pairs");
        assert!(qsgd < dense);
    }
}
