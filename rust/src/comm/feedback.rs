//! Per-worker error-feedback residual accumulators.
//!
//! Compressed SGD applies `decode(encode(g))`, discarding
//! `g − decode(encode(g))` every round. Error feedback (Seide et al. 2014;
//! Stich et al. 2018) keeps that residual per worker and adds it to the
//! next gradient *before* compression, so dropped mass is delayed, not
//! lost — the property that lets biased compressors such as unscaled
//! top-k/rand-k converge like dense SGD.
//!
//! In fastest-k training only the k accepted workers' residuals update in
//! a round: a straggler whose result is discarded never transmitted, so
//! its accumulator is untouched (and its gradient is recomputed at a
//! fresher model next time).

/// Per-worker compression residuals `e_i`.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    residual: Vec<Vec<f32>>,
}

impl ErrorFeedback {
    /// Zero residuals for `n` workers (buffers sized lazily on first use).
    pub fn new(n: usize) -> Self {
        Self { residual: vec![Vec::new(); n] }
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.residual.len()
    }

    /// Add worker `i`'s residual into `g` in place: `g ← g + e_i`.
    pub fn add_residual(&mut self, worker: usize, g: &mut [f32]) {
        let e = &mut self.residual[worker];
        if e.is_empty() {
            return;
        }
        debug_assert_eq!(e.len(), g.len(), "residual/gradient dim mismatch");
        for (gv, ev) in g.iter_mut().zip(e.iter()) {
            *gv += *ev;
        }
    }

    /// Record what compression dropped this round: `e_i ← g_fb − decoded`,
    /// where `g_fb` is the feedback-adjusted gradient that was compressed.
    pub fn update(&mut self, worker: usize, g_fb: &[f32], decoded: &[f32]) {
        debug_assert_eq!(g_fb.len(), decoded.len());
        let e = &mut self.residual[worker];
        e.resize(g_fb.len(), 0.0);
        for ((ev, gv), dv) in e.iter_mut().zip(g_fb).zip(decoded) {
            *ev = *gv - *dv;
        }
    }

    /// Worker `i`'s current residual (empty before its first update).
    pub fn residual(&self, worker: usize) -> &[f32] {
        &self.residual[worker]
    }

    /// `‖e_i‖²` — diagnostic for how much mass feedback is carrying.
    pub fn residual_norm_sq(&self, worker: usize) -> f64 {
        self.residual[worker]
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_identity_is_exact_for_sparsifiers() {
        // decoded keeps coords {0, 2} and zeroes the rest.
        let g = [1.5f32, -2.25, 0.5, 4.0];
        let decoded = [1.5f32, 0.0, 0.5, 0.0];
        let mut fb = ErrorFeedback::new(1);
        fb.update(0, &g, &decoded);
        assert_eq!(fb.residual(0), &[0.0, -2.25, 0.0, 4.0]);
        // Next round: the residual rides along.
        let mut g2 = [0.0f32, 1.0, 0.0, -1.0];
        fb.add_residual(0, &mut g2);
        assert_eq!(g2, [0.0, -1.25, 0.0, 3.0]);
    }

    #[test]
    fn untouched_workers_keep_empty_residuals() {
        let mut fb = ErrorFeedback::new(3);
        fb.update(1, &[1.0, 2.0], &[1.0, 0.0]);
        assert!(fb.residual(0).is_empty());
        assert_eq!(fb.residual(1), &[0.0, 2.0]);
        assert_eq!(fb.residual_norm_sq(1), 4.0);
        let mut g = [10.0f32, 10.0];
        fb.add_residual(2, &mut g); // no-op before first update
        assert_eq!(g, [10.0, 10.0]);
    }
}
