//! Per-worker link model: bandwidth + latency → virtual transfer delay,
//! plus the shared master-ingress capacity concurrent uploads contend on.
//!
//! The comm analogue of [`DelayModel`](crate::straggler::DelayModel):
//! queried once per (iteration, worker) with the encoded message size and
//! returning the virtual time the transfer occupies. Deterministic — the
//! stochasticity of a round lives in the compute-delay model; the link
//! prices bytes. The same model serves both directions: the uplink of
//! gradient messages and (via [`Broadcast`](super::Broadcast)) the
//! downlink of model messages.

/// Per-worker link bandwidth and latency (one direction).
#[derive(Debug, Clone, PartialEq)]
pub struct LinkModel {
    /// Bytes per unit of virtual time; `f64::INFINITY` = free link.
    bandwidth: Vec<f64>,
    /// Fixed per-message latency in virtual time units.
    latency: Vec<f64>,
}

impl LinkModel {
    /// A link that costs nothing — the default every driver starts from;
    /// with it, comm-aware runs match the pre-comm trajectories exactly.
    pub fn zero_cost(n: usize) -> Self {
        Self { bandwidth: vec![f64::INFINITY; n], latency: vec![0.0; n] }
    }

    /// Identical links: `bandwidth` bytes per virtual-time unit
    /// (`<= 0` means infinite; NaN is rejected) and fixed per-message
    /// `latency`.
    pub fn uniform(n: usize, bandwidth: f64, latency: f64) -> Self {
        assert!(!bandwidth.is_nan(), "bandwidth must not be NaN");
        assert!(latency >= 0.0, "latency must be non-negative");
        let bw = if bandwidth > 0.0 { bandwidth } else { f64::INFINITY };
        Self { bandwidth: vec![bw; n], latency: vec![latency; n] }
    }

    /// Fully heterogeneous links. NaN bandwidth is rejected the same way
    /// NaN latency already is (it fails the `>= 0` check) — a NaN must
    /// not silently map to "infinite" via the `> 0` test.
    pub fn per_worker(bandwidth: Vec<f64>, latency: Vec<f64>) -> Self {
        assert_eq!(bandwidth.len(), latency.len(), "per-worker lens differ");
        assert!(!bandwidth.is_empty(), "need at least one worker");
        assert!(bandwidth.iter().all(|b| !b.is_nan()), "NaN bandwidth");
        assert!(latency.iter().all(|&l| l >= 0.0), "negative latency");
        let bandwidth = bandwidth
            .into_iter()
            .map(|b| if b > 0.0 { b } else { f64::INFINITY })
            .collect();
        Self { bandwidth, latency }
    }

    /// Uniform links with the last `n_slow` workers' bandwidth divided by
    /// `slow_factor` — the bimodal-cluster idiom from `straggler/`.
    ///
    /// With `n_slow > 0` the base `bandwidth` must be finite and positive:
    /// a non-positive bandwidth means *infinite* in this model, and
    /// `∞ / slow_factor` is still `∞`, so the "slow" tail would silently
    /// be exactly as free as everyone else.
    pub fn uniform_with_slow(
        n: usize,
        bandwidth: f64,
        latency: f64,
        n_slow: usize,
        slow_factor: f64,
    ) -> Self {
        assert!(n_slow <= n, "n_slow must be <= n");
        assert!(slow_factor >= 1.0, "slow_factor must be >= 1");
        assert!(
            n_slow == 0 || (bandwidth > 0.0 && bandwidth.is_finite()),
            "uniform_with_slow: bandwidth {bandwidth} means an infinite \
             link, which cannot be slowed — pass a finite bandwidth > 0"
        );
        let mut link = Self::uniform(n, bandwidth, latency);
        for b in link.bandwidth[n - n_slow..].iter_mut() {
            *b /= slow_factor;
        }
        link
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.bandwidth.len()
    }

    /// Virtual time worker `i`'s uplink needs for a `bytes`-sized message.
    pub fn upload_delay(&self, worker: usize, bytes: u64) -> f64 {
        let bw = self.bandwidth[worker];
        let transfer =
            if bw.is_finite() { bytes as f64 / bw } else { 0.0 };
        self.latency[worker] + transfer
    }

    /// True iff every upload is free (infinite bandwidth, zero latency) —
    /// the drivers use this to skip per-worker delay adjustments entirely.
    pub fn is_zero_cost(&self) -> bool {
        self.bandwidth.iter().all(|b| b.is_infinite())
            && self.latency.iter().all(|&l| l == 0.0)
    }

    /// Human-readable description for labels.
    pub fn name(&self) -> String {
        if self.is_zero_cost() {
            return "free-link".into();
        }
        let b0 = self.bandwidth[0];
        let l0 = self.latency[0];
        let uniform = self.bandwidth.iter().all(|&b| b == b0)
            && self.latency.iter().all(|&l| l == l0);
        if uniform {
            format!("link(bw={b0}, lat={l0})")
        } else {
            format!("link(heterogeneous, n={})", self.n())
        }
    }
}

/// Shared master-ingress capacity: concurrent uploads contend on the
/// master's NIC instead of arriving independently.
///
/// The contention discipline is **FIFO store-and-forward** (not processor
/// sharing): a message first traverses its sender's own link (the
/// [`LinkModel`] pricing, bandwidth + latency), *arrives* at the master's
/// ingress, and then queues in arrival order, occupying the ingress for
/// `bytes / capacity` time units before it is decoded. FIFO was chosen
/// over processor sharing because the round completion has a closed form
/// over the sorted arrivals and it matches the one-message-at-a-time
/// decode loop every driver already runs; both disciplines agree on the
/// completion time of the *last* message when all messages are equal
/// sized, which is the quantity the round clock needs.
///
/// With infinite capacity ([`IngressModel::unlimited`], the default) the
/// completion of each message is exactly its arrival — the independent-
/// upload model of PR 1, preserved bit for bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IngressModel {
    /// Bytes per virtual-time unit; `f64::INFINITY` = no contention.
    capacity: f64,
}

impl Default for IngressModel {
    fn default() -> Self {
        Self::unlimited()
    }
}

impl IngressModel {
    /// No contention: every upload completes at its arrival time.
    pub fn unlimited() -> Self {
        Self { capacity: f64::INFINITY }
    }

    /// Shared ingress of `capacity` bytes per virtual-time unit
    /// (`<= 0` means unlimited, mirroring [`LinkModel::uniform`]; NaN is
    /// rejected).
    pub fn new(capacity: f64) -> Self {
        assert!(!capacity.is_nan(), "ingress capacity must not be NaN");
        let capacity =
            if capacity > 0.0 { capacity } else { f64::INFINITY };
        Self { capacity }
    }

    /// True iff uploads never contend (the PR-1 independent model).
    pub fn is_unlimited(&self) -> bool {
        self.capacity.is_infinite()
    }

    /// Ingress service time of one `bytes`-sized message.
    pub fn service_time(&self, bytes: u64) -> f64 {
        if self.capacity.is_finite() {
            bytes as f64 / self.capacity
        } else {
            0.0
        }
    }

    /// Completion time of the *last* message of a round: sorts `arrivals`
    /// in place (total order — NaN arrivals sort last rather than
    /// corrupting the order) and serializes them FIFO through the
    /// ingress, each occupying it for `bytes / capacity`.
    ///
    /// Invariants (tested in `proptests.rs`): the result is ≥ the max
    /// arrival (the independent-upload round time), strictly greater for
    /// any finite capacity with `bytes > 0`, and equal when unlimited.
    pub fn round_completion(&self, arrivals: &mut [f64], bytes: u64) -> f64 {
        assert!(!arrivals.is_empty(), "a round needs at least one arrival");
        arrivals.sort_unstable_by(|a, b| a.total_cmp(b));
        let per = self.service_time(bytes);
        if per == 0.0 {
            return arrivals[arrivals.len() - 1];
        }
        let mut free = f64::NEG_INFINITY;
        for &a in arrivals.iter() {
            free = if a > free { a } else { free } + per;
        }
        free
    }

    /// Serve one message arriving at `arrival` when the ingress frees at
    /// `free_at` (the async driver's running state): completion is
    /// `max(arrival, free_at) + bytes/capacity`. With unlimited capacity
    /// this is bitwise `arrival` for any `free_at <= arrival`.
    pub fn serve_at(&self, arrival: f64, free_at: f64, bytes: u64) -> f64 {
        let start = if arrival > free_at { arrival } else { free_at };
        start + self.service_time(bytes)
    }

    /// Human-readable description for labels.
    pub fn name(&self) -> String {
        if self.is_unlimited() {
            "ingress(unlimited)".into()
        } else {
            format!("ingress(bw={})", self.capacity)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_cost_is_free_everywhere() {
        let l = LinkModel::zero_cost(8);
        assert!(l.is_zero_cost());
        for i in 0..8 {
            assert_eq!(l.upload_delay(i, 1 << 30), 0.0);
        }
    }

    #[test]
    fn uniform_prices_bytes_linearly() {
        let l = LinkModel::uniform(4, 100.0, 0.5);
        assert!(!l.is_zero_cost());
        assert!((l.upload_delay(0, 200) - 2.5).abs() < 1e-12);
        assert!((l.upload_delay(3, 0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn nonpositive_bandwidth_means_infinite() {
        let l = LinkModel::uniform(2, 0.0, 0.0);
        assert!(l.is_zero_cost());
        let l2 = LinkModel::per_worker(vec![100.0, -1.0], vec![0.0, 0.0]);
        assert_eq!(l2.upload_delay(1, 1_000_000), 0.0);
        assert!(l2.upload_delay(0, 100) > 0.0);
    }

    #[test]
    fn slow_tail_is_slower() {
        let l = LinkModel::uniform_with_slow(10, 100.0, 0.0, 3, 10.0);
        assert!((l.upload_delay(0, 100) - 1.0).abs() < 1e-12);
        assert!((l.upload_delay(9, 100) - 10.0).abs() < 1e-12);
        assert_eq!(l.upload_delay(6, 100), l.upload_delay(0, 100));
    }

    #[test]
    #[should_panic(expected = "cannot be slowed")]
    fn uniform_with_slow_rejects_infinite_bandwidth() {
        // bandwidth <= 0 means infinite; a "slow" tail on an infinite
        // link would silently be as free as everyone else.
        let _ = LinkModel::uniform_with_slow(10, 0.0, 0.0, 3, 10.0);
    }

    #[test]
    #[should_panic(expected = "cannot be slowed")]
    fn uniform_with_slow_rejects_explicit_infinity() {
        let _ =
            LinkModel::uniform_with_slow(4, f64::INFINITY, 0.0, 1, 2.0);
    }

    #[test]
    fn uniform_with_slow_allows_free_link_without_slow_tail() {
        // n_slow == 0 keeps the old "0 = infinite" semantics.
        let l = LinkModel::uniform_with_slow(4, 0.0, 0.0, 0, 10.0);
        assert!(l.is_zero_cost());
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn per_worker_rejects_nan_bandwidth() {
        let _ = LinkModel::per_worker(vec![100.0, f64::NAN], vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn uniform_rejects_nan_bandwidth() {
        let _ = LinkModel::uniform(2, f64::NAN, 0.0);
    }

    #[test]
    fn unlimited_ingress_is_the_independent_model() {
        let ing = IngressModel::unlimited();
        assert!(ing.is_unlimited());
        assert_eq!(ing.service_time(1 << 30), 0.0);
        let mut arrivals = vec![3.0, 1.0, 2.0];
        assert_eq!(ing.round_completion(&mut arrivals, 1 << 20), 3.0);
        assert_eq!(ing.serve_at(5.0, 1.0, 1 << 20), 5.0);
        // Nonpositive capacity means unlimited, as in LinkModel.
        assert!(IngressModel::new(0.0).is_unlimited());
        assert!(IngressModel::new(-3.0).is_unlimited());
    }

    #[test]
    fn finite_ingress_serializes_fifo() {
        // capacity 100 B/t, 100-B messages -> 1.0 service each.
        let ing = IngressModel::new(100.0);
        assert!(!ing.is_unlimited());
        // Arrivals 0, 0.2, 5: first two queue back-to-back (finish 1, 2),
        // the third finds the ingress idle (finish 6).
        let mut arrivals = vec![5.0, 0.0, 0.2];
        let t = ing.round_completion(&mut arrivals, 100);
        assert!((t - 6.0).abs() < 1e-12);
        // A fully bunched round degenerates to pure serialization.
        let mut bunched = vec![1.0; 4];
        let t = ing.round_completion(&mut bunched, 100);
        assert!((t - 5.0).abs() < 1e-12);
    }

    #[test]
    fn finite_ingress_strictly_exceeds_independent_time() {
        let ing = IngressModel::new(50.0);
        let mut arrivals = vec![0.5, 1.5, 4.0];
        let independent = 4.0;
        let t = ing.round_completion(&mut arrivals, 100);
        assert!(t > independent, "{t} must exceed {independent}");
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn ingress_rejects_nan_capacity() {
        let _ = IngressModel::new(f64::NAN);
    }
}
