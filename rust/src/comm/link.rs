//! Per-worker link model: bandwidth + latency → virtual transfer delay,
//! plus the shared master-ingress capacity concurrent uploads contend on.
//!
//! The comm analogue of [`DelayModel`](crate::straggler::DelayModel):
//! queried once per (iteration, worker) with the encoded message size and
//! returning the virtual time the transfer occupies. Deterministic — the
//! stochasticity of a round lives in the compute-delay model; the link
//! prices bytes. The same model serves both directions: the uplink of
//! gradient messages and (via [`Broadcast`](super::Broadcast)) the
//! downlink of model messages.

/// Per-worker link bandwidth and latency (one direction).
#[derive(Debug, Clone, PartialEq)]
pub struct LinkModel {
    /// Bytes per unit of virtual time; `f64::INFINITY` = free link.
    bandwidth: Vec<f64>,
    /// Fixed per-message latency in virtual time units.
    latency: Vec<f64>,
    /// Cached [`LinkModel::is_zero_cost`]: the round drivers check it
    /// every iteration, and rescanning both vectors is O(n) — ruinous
    /// once the fastpath makes the rest of the round O(k) at n = 10⁶.
    /// Derived from the vectors at construction, so the derived
    /// `PartialEq` stays consistent.
    zero_cost: bool,
}

impl LinkModel {
    /// Assemble from validated parts, deriving the cached zero-cost
    /// flag. Every constructor funnels through here so the flag can
    /// never drift from the vectors.
    fn from_parts(bandwidth: Vec<f64>, latency: Vec<f64>) -> Self {
        let zero_cost = bandwidth.iter().all(|b| b.is_infinite())
            && latency.iter().all(|&l| l == 0.0);
        Self { bandwidth, latency, zero_cost }
    }

    /// A link that costs nothing — the default every driver starts from;
    /// with it, comm-aware runs match the pre-comm trajectories exactly.
    pub fn zero_cost(n: usize) -> Self {
        Self::from_parts(vec![f64::INFINITY; n], vec![0.0; n])
    }

    /// Identical links: `bandwidth` bytes per virtual-time unit
    /// (`<= 0` means infinite; NaN is rejected) and fixed per-message
    /// `latency`.
    pub fn uniform(n: usize, bandwidth: f64, latency: f64) -> Self {
        assert!(!bandwidth.is_nan(), "bandwidth must not be NaN");
        assert!(latency >= 0.0, "latency must be non-negative");
        let bw = if bandwidth > 0.0 { bandwidth } else { f64::INFINITY };
        Self::from_parts(vec![bw; n], vec![latency; n])
    }

    /// Fully heterogeneous links. NaN bandwidth is rejected the same way
    /// NaN latency already is (it fails the `>= 0` check) — a NaN must
    /// not silently map to "infinite" via the `> 0` test.
    pub fn per_worker(bandwidth: Vec<f64>, latency: Vec<f64>) -> Self {
        assert_eq!(bandwidth.len(), latency.len(), "per-worker lens differ");
        assert!(!bandwidth.is_empty(), "need at least one worker");
        assert!(bandwidth.iter().all(|b| !b.is_nan()), "NaN bandwidth");
        assert!(latency.iter().all(|&l| l >= 0.0), "negative latency");
        let bandwidth = bandwidth
            .into_iter()
            .map(|b| if b > 0.0 { b } else { f64::INFINITY })
            .collect();
        Self::from_parts(bandwidth, latency)
    }

    /// Uniform links with the last `n_slow` workers' bandwidth divided by
    /// `slow_factor` — the bimodal-cluster idiom from `straggler/`.
    ///
    /// With `n_slow > 0` the base `bandwidth` must be finite and positive:
    /// a non-positive bandwidth means *infinite* in this model, and
    /// `∞ / slow_factor` is still `∞`, so the "slow" tail would silently
    /// be exactly as free as everyone else.
    pub fn uniform_with_slow(
        n: usize,
        bandwidth: f64,
        latency: f64,
        n_slow: usize,
        slow_factor: f64,
    ) -> Self {
        assert!(n_slow <= n, "n_slow must be <= n");
        assert!(slow_factor >= 1.0, "slow_factor must be >= 1");
        assert!(
            n_slow == 0 || (bandwidth > 0.0 && bandwidth.is_finite()),
            "uniform_with_slow: bandwidth {bandwidth} means an infinite \
             link, which cannot be slowed — pass a finite bandwidth > 0"
        );
        let mut link = Self::uniform(n, bandwidth, latency);
        for b in link.bandwidth[n - n_slow..].iter_mut() {
            *b /= slow_factor;
        }
        // Re-derive the cached flag after mutating the bandwidths.
        Self::from_parts(link.bandwidth, link.latency)
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.bandwidth.len()
    }

    /// Virtual time worker `i`'s uplink needs for a `bytes`-sized message.
    pub fn upload_delay(&self, worker: usize, bytes: u64) -> f64 {
        let bw = self.bandwidth[worker];
        let transfer =
            if bw.is_finite() { bytes as f64 / bw } else { 0.0 };
        self.latency[worker] + transfer
    }

    /// True iff every upload is free (infinite bandwidth, zero latency) —
    /// the drivers use this to skip per-worker delay adjustments
    /// entirely. O(1): cached at construction.
    pub fn is_zero_cost(&self) -> bool {
        self.zero_cost
    }

    /// Human-readable description for labels.
    pub fn name(&self) -> String {
        if self.is_zero_cost() {
            return "free-link".into();
        }
        let b0 = self.bandwidth[0];
        let l0 = self.latency[0];
        let uniform = self.bandwidth.iter().all(|&b| b == b0)
            && self.latency.iter().all(|&l| l == l0);
        if uniform {
            format!("link(bw={b0}, lat={l0})")
        } else {
            format!("link(heterogeneous, n={})", self.n())
        }
    }
}

/// How concurrent uploads share the master's ingress capacity.
///
/// Both disciplines are work-conserving (the NIC never idles while a
/// message is in flight), so for equal-sized messages they agree on the
/// time the *last* message of a round completes — the quantity the sync
/// round clock needs (a property test asserts this makespan invariance).
/// They differ on *per-message* completion times, which is observable in
/// the async driver: under FIFO the first of a bunch of simultaneous
/// arrivals is decoded one service time in, while under PS the whole
/// bunch drains together and every apply lands near the makespan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IngressDiscipline {
    /// Store-and-forward: messages queue in arrival order, each occupying
    /// the full capacity for `bytes / capacity` time units.
    #[default]
    Fifo,
    /// Processor sharing: all in-flight messages drain simultaneously,
    /// each receiving `capacity / m` while `m` are active.
    Ps,
}

/// Shared master-ingress capacity: concurrent uploads contend on the
/// master's NIC instead of arriving independently.
///
/// A message first traverses its sender's own link (the [`LinkModel`]
/// pricing, bandwidth + latency), *arrives* at the master's ingress, and
/// then contends under an [`IngressDiscipline`]: **FIFO
/// store-and-forward** (the default — completion has a closed form over
/// the sorted arrivals and matches the one-message-at-a-time decode loop
/// the round drivers run) or **processor sharing** (all in-flight
/// messages drain together). Equal-sized messages make the two agree on
/// the round makespan; per-message completions differ (see
/// [`IngressDiscipline`]).
///
/// With infinite capacity ([`IngressModel::unlimited`], the default) the
/// completion of each message is exactly its arrival — the independent-
/// upload model of PR 1, preserved bit for bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IngressModel {
    /// Bytes per virtual-time unit; `f64::INFINITY` = no contention.
    capacity: f64,
    /// Queueing discipline for concurrent arrivals.
    discipline: IngressDiscipline,
}

impl Default for IngressModel {
    fn default() -> Self {
        Self::unlimited()
    }
}

impl IngressModel {
    /// No contention: every upload completes at its arrival time.
    pub fn unlimited() -> Self {
        Self {
            capacity: f64::INFINITY,
            discipline: IngressDiscipline::Fifo,
        }
    }

    /// Shared ingress of `capacity` bytes per virtual-time unit
    /// (`<= 0` means unlimited, mirroring [`LinkModel::uniform`]; NaN is
    /// rejected). FIFO store-and-forward; see
    /// [`IngressModel::with_discipline`] for processor sharing.
    pub fn new(capacity: f64) -> Self {
        Self::with_discipline(capacity, IngressDiscipline::Fifo)
    }

    /// Shared ingress with an explicit queueing discipline.
    pub fn with_discipline(
        capacity: f64,
        discipline: IngressDiscipline,
    ) -> Self {
        assert!(!capacity.is_nan(), "ingress capacity must not be NaN");
        let capacity =
            if capacity > 0.0 { capacity } else { f64::INFINITY };
        Self { capacity, discipline }
    }

    /// The queueing discipline for concurrent arrivals.
    pub fn discipline(&self) -> IngressDiscipline {
        self.discipline
    }

    /// True iff uploads never contend (the PR-1 independent model).
    pub fn is_unlimited(&self) -> bool {
        self.capacity.is_infinite()
    }

    /// Ingress service time of one `bytes`-sized message.
    pub fn service_time(&self, bytes: u64) -> f64 {
        if self.capacity.is_finite() {
            bytes as f64 / self.capacity
        } else {
            0.0
        }
    }

    /// Completion time of the *last* message of a round: sorts `arrivals`
    /// in place (total order — NaN arrivals sort last rather than
    /// corrupting the order) and drains them through the ingress under
    /// the configured discipline, each needing `bytes / capacity` of
    /// service.
    ///
    /// Invariants (tested in `proptests.rs`): the result is ≥ the max
    /// arrival (the independent-upload round time), strictly greater for
    /// any finite capacity with `bytes > 0`, equal when unlimited, and —
    /// because both disciplines are work-conserving over equal-sized
    /// messages — FIFO and PS agree on it up to float associativity.
    pub fn round_completion(&self, arrivals: &mut [f64], bytes: u64) -> f64 {
        assert!(!arrivals.is_empty(), "a round needs at least one arrival");
        arrivals.sort_unstable_by(|a, b| a.total_cmp(b));
        let per = self.service_time(bytes);
        if per == 0.0 {
            return arrivals[arrivals.len() - 1];
        }
        match self.discipline {
            IngressDiscipline::Fifo => {
                let mut free = f64::NEG_INFINITY;
                for &a in arrivals.iter() {
                    free = if a > free { a } else { free } + per;
                }
                free
            }
            IngressDiscipline::Ps => ps_completion(arrivals, per),
        }
    }

    /// Serve one message arriving at `arrival` when the ingress frees at
    /// `free_at` — the **FIFO** running state the async driver keeps:
    /// completion is `max(arrival, free_at) + bytes/capacity`. With
    /// unlimited capacity this is bitwise `arrival` for any
    /// `free_at <= arrival`. (The PS discipline has no single-scalar
    /// running state; the engine's async gather simulates it exactly with
    /// completion events — see `engine::StalenessGather`.)
    pub fn serve_at(&self, arrival: f64, free_at: f64, bytes: u64) -> f64 {
        let start = if arrival > free_at { arrival } else { free_at };
        start + self.service_time(bytes)
    }

    /// Human-readable description for labels.
    pub fn name(&self) -> String {
        if self.is_unlimited() {
            "ingress(unlimited)".into()
        } else {
            match self.discipline {
                IngressDiscipline::Fifo => {
                    format!("ingress(bw={})", self.capacity)
                }
                IngressDiscipline::Ps => {
                    format!("ingress(bw={}, ps)", self.capacity)
                }
            }
        }
    }
}

/// Incremental processor-sharing server: the ONE implementation of the
/// shared fluid drain, used both by the batch
/// [`IngressModel::round_completion`] (sync/threaded round clock) and by
/// the engine's event-driven async gather (per-message apply times).
///
/// All in-flight messages drain simultaneously, each at rate `1/m` of
/// the server while `m` are active. With equal service requirements the
/// oldest message always holds the least remaining work, so completions
/// happen in arrival order and only the front's completion ever needs
/// projecting. The caller owns the clock: [`PsServer::advance`] between
/// events, [`PsServer::admit`] on arrival, [`PsServer::next_completion`]
/// to project, [`PsServer::complete_front`] at a completion.
#[derive(Debug, Clone, Default)]
pub struct PsServer {
    /// (caller tag, remaining full-rate service), oldest first.
    active: std::collections::VecDeque<(usize, f64)>,
    /// Clock of the last advance.
    last: f64,
}

impl PsServer {
    /// An idle server at clock 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// True when nothing is in flight.
    pub fn is_empty(&self) -> bool {
        self.active.is_empty()
    }

    /// Drain to `to`: each of the m in-flight messages progressed at
    /// rate 1/m since the last event (clamped against float slop; a
    /// non-increasing or NaN step is a no-op). Must not cross a
    /// completion — project those with [`PsServer::next_completion`] and
    /// deliver them first.
    pub fn advance(&mut self, to: f64) {
        let dt = to - self.last;
        self.last = to;
        if !(dt > 0.0) || self.active.is_empty() {
            return;
        }
        let share = dt / self.active.len() as f64;
        for m in self.active.iter_mut() {
            m.1 = (m.1 - share).max(0.0);
        }
    }

    /// Admit a message needing `service` full-rate time at the current
    /// clock ([`PsServer::advance`] there first).
    pub fn admit(&mut self, tag: usize, service: f64) {
        self.active.push_back((tag, service));
    }

    /// Projected completion time of the oldest in-flight message under
    /// the *current* active set — exact until the next admission, which
    /// reshares the drain and invalidates it.
    pub fn next_completion(&self) -> Option<f64> {
        let &(_, rem) = self.active.front()?;
        Some(self.last + rem * self.active.len() as f64)
    }

    /// Pop the completed oldest message ([`PsServer::advance`] to its
    /// completion time first), returning its tag.
    pub fn complete_front(&mut self) -> Option<usize> {
        self.active.pop_front().map(|(tag, _)| tag)
    }
}

/// Batch fluid drain over sorted `arrivals`, each message needing `per`
/// time units of dedicated service, via [`PsServer`]. The returned time
/// is the last completion — the busy-period end, which work conservation
/// makes agree with the FIFO chain.
fn ps_completion(arrivals: &[f64], per: f64) -> f64 {
    let mut srv = PsServer::new();
    let mut next = 0usize;
    let mut t = f64::NEG_INFINITY;
    while next < arrivals.len() || !srv.is_empty() {
        if srv.is_empty() && arrivals[next] > t {
            // Idle gap: jump to the next arrival.
            t = arrivals[next];
        }
        // Admit everything due. The negated comparison also admits NaN
        // arrivals (sorted last): they fail every comparison and join
        // immediately, exactly as the FIFO chain serves them — without
        // this, a NaN would neither advance `next` nor enter the server
        // and the drain would spin forever.
        while next < arrivals.len() && !(arrivals[next] > t) {
            srv.advance(t);
            srv.admit(next, per);
            next += 1;
        }
        let t_complete =
            srv.next_completion().expect("server has in-flight work");
        if next < arrivals.len() && arrivals[next] < t_complete {
            // An arrival interrupts the drain: advance to it and admit
            // (next loop iteration).
            t = arrivals[next];
        } else {
            // The front message finishes before the next arrival.
            srv.advance(t_complete);
            srv.complete_front();
            t = t_complete;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_cost_is_free_everywhere() {
        let l = LinkModel::zero_cost(8);
        assert!(l.is_zero_cost());
        for i in 0..8 {
            assert_eq!(l.upload_delay(i, 1 << 30), 0.0);
        }
    }

    #[test]
    fn uniform_prices_bytes_linearly() {
        let l = LinkModel::uniform(4, 100.0, 0.5);
        assert!(!l.is_zero_cost());
        assert!((l.upload_delay(0, 200) - 2.5).abs() < 1e-12);
        assert!((l.upload_delay(3, 0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn nonpositive_bandwidth_means_infinite() {
        let l = LinkModel::uniform(2, 0.0, 0.0);
        assert!(l.is_zero_cost());
        let l2 = LinkModel::per_worker(vec![100.0, -1.0], vec![0.0, 0.0]);
        assert_eq!(l2.upload_delay(1, 1_000_000), 0.0);
        assert!(l2.upload_delay(0, 100) > 0.0);
    }

    #[test]
    fn slow_tail_is_slower() {
        let l = LinkModel::uniform_with_slow(10, 100.0, 0.0, 3, 10.0);
        assert!((l.upload_delay(0, 100) - 1.0).abs() < 1e-12);
        assert!((l.upload_delay(9, 100) - 10.0).abs() < 1e-12);
        assert_eq!(l.upload_delay(6, 100), l.upload_delay(0, 100));
    }

    #[test]
    #[should_panic(expected = "cannot be slowed")]
    fn uniform_with_slow_rejects_infinite_bandwidth() {
        // bandwidth <= 0 means infinite; a "slow" tail on an infinite
        // link would silently be as free as everyone else.
        let _ = LinkModel::uniform_with_slow(10, 0.0, 0.0, 3, 10.0);
    }

    #[test]
    #[should_panic(expected = "cannot be slowed")]
    fn uniform_with_slow_rejects_explicit_infinity() {
        let _ =
            LinkModel::uniform_with_slow(4, f64::INFINITY, 0.0, 1, 2.0);
    }

    #[test]
    fn uniform_with_slow_allows_free_link_without_slow_tail() {
        // n_slow == 0 keeps the old "0 = infinite" semantics.
        let l = LinkModel::uniform_with_slow(4, 0.0, 0.0, 0, 10.0);
        assert!(l.is_zero_cost());
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn per_worker_rejects_nan_bandwidth() {
        let _ = LinkModel::per_worker(vec![100.0, f64::NAN], vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn uniform_rejects_nan_bandwidth() {
        let _ = LinkModel::uniform(2, f64::NAN, 0.0);
    }

    #[test]
    fn unlimited_ingress_is_the_independent_model() {
        let ing = IngressModel::unlimited();
        assert!(ing.is_unlimited());
        assert_eq!(ing.service_time(1 << 30), 0.0);
        let mut arrivals = vec![3.0, 1.0, 2.0];
        assert_eq!(ing.round_completion(&mut arrivals, 1 << 20), 3.0);
        assert_eq!(ing.serve_at(5.0, 1.0, 1 << 20), 5.0);
        // Nonpositive capacity means unlimited, as in LinkModel.
        assert!(IngressModel::new(0.0).is_unlimited());
        assert!(IngressModel::new(-3.0).is_unlimited());
    }

    #[test]
    fn finite_ingress_serializes_fifo() {
        // capacity 100 B/t, 100-B messages -> 1.0 service each.
        let ing = IngressModel::new(100.0);
        assert!(!ing.is_unlimited());
        // Arrivals 0, 0.2, 5: first two queue back-to-back (finish 1, 2),
        // the third finds the ingress idle (finish 6).
        let mut arrivals = vec![5.0, 0.0, 0.2];
        let t = ing.round_completion(&mut arrivals, 100);
        assert!((t - 6.0).abs() < 1e-12);
        // A fully bunched round degenerates to pure serialization.
        let mut bunched = vec![1.0; 4];
        let t = ing.round_completion(&mut bunched, 100);
        assert!((t - 5.0).abs() < 1e-12);
    }

    #[test]
    fn finite_ingress_strictly_exceeds_independent_time() {
        let ing = IngressModel::new(50.0);
        let mut arrivals = vec![0.5, 1.5, 4.0];
        let independent = 4.0;
        let t = ing.round_completion(&mut arrivals, 100);
        assert!(t > independent, "{t} must exceed {independent}");
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn ingress_rejects_nan_capacity() {
        let _ = IngressModel::new(f64::NAN);
    }

    #[test]
    fn ps_agrees_with_fifo_on_the_round_makespan() {
        // Both disciplines are work-conserving, so the completion of the
        // last equal-sized message — the sync round clock — matches.
        let fifo = IngressModel::new(100.0);
        let ps =
            IngressModel::with_discipline(100.0, IngressDiscipline::Ps);
        for arrivals in [
            vec![0.0, 0.2, 5.0],
            vec![1.0; 4],
            vec![0.5, 1.5, 4.0, 4.1, 9.0],
            vec![3.0],
        ] {
            let mut a = arrivals.clone();
            let mut b = arrivals.clone();
            let tf = fifo.round_completion(&mut a, 100);
            let tp = ps.round_completion(&mut b, 100);
            assert!(
                (tf - tp).abs() < 1e-9,
                "{arrivals:?}: fifo {tf} vs ps {tp}"
            );
        }
    }

    #[test]
    fn ps_drains_idle_gaps_like_fifo() {
        // Arrivals 0 and 5 with 1.0 service each never overlap: both
        // disciplines finish at 6.
        let ps =
            IngressModel::with_discipline(100.0, IngressDiscipline::Ps);
        let mut arrivals = vec![5.0, 0.0];
        let t = ps.round_completion(&mut arrivals, 100);
        assert!((t - 6.0).abs() < 1e-12);
    }

    #[test]
    fn ps_server_incremental_drain_matches_hand_computation() {
        // Two unit-service messages: A arrives at t=0, B at t=0.5. From
        // 0.5 they share the server, so A's remaining 0.5 drains at rate
        // 1/2 → A completes at 1.5 (FIFO: 1.0); B drained 0.5 over
        // [0.5, 1.5] and finishes its last 0.5 alone at 2.0 — the same
        // makespan as FIFO (work conservation), later first completion.
        let mut srv = PsServer::new();
        srv.advance(0.0);
        srv.admit(0, 1.0);
        assert_eq!(srv.next_completion(), Some(1.0));
        srv.advance(0.5);
        srv.admit(1, 1.0);
        // A has 0.5 remaining, two sharing: projected 0.5 + 0.5·2 = 1.5.
        assert_eq!(srv.next_completion(), Some(1.5));
        srv.advance(1.5);
        assert_eq!(srv.complete_front(), Some(0));
        // B drained 0.5 over [0.5, 1.5] at rate 1/2: 0.5 left, alone.
        assert_eq!(srv.next_completion(), Some(2.0));
        srv.advance(2.0);
        assert_eq!(srv.complete_front(), Some(1));
        assert!(srv.is_empty());
        assert_eq!(srv.next_completion(), None);
    }

    #[test]
    fn ps_survives_nan_arrivals_like_fifo() {
        // Regression: a NaN arrival (sorted last under total_cmp) used
        // to leave the PS fluid drain spinning forever — it neither
        // compared due nor advanced the cursor. Both disciplines must
        // serve it immediately at the busy-period end, like the FIFO
        // chain where NaN fails the `a > free` test.
        let fifo = IngressModel::new(100.0);
        let ps =
            IngressModel::with_discipline(100.0, IngressDiscipline::Ps);
        let mut a = vec![0.0, f64::NAN, 0.2];
        let tf = fifo.round_completion(&mut a, 100);
        let mut b = vec![0.0, f64::NAN, 0.2];
        let tp = ps.round_completion(&mut b, 100);
        assert!(tf.is_finite() && tp.is_finite());
        assert!((tf - tp).abs() < 1e-9, "fifo {tf} vs ps {tp}");
        // Finite arrivals 0, 0.2 chain to 1, 2; the NaN is served next.
        assert!((tf - 3.0).abs() < 1e-12);
    }

    #[test]
    fn discipline_defaults_to_fifo_and_labels_ps() {
        assert_eq!(
            IngressModel::new(50.0).discipline(),
            IngressDiscipline::Fifo
        );
        let ps =
            IngressModel::with_discipline(50.0, IngressDiscipline::Ps);
        assert_eq!(ps.discipline(), IngressDiscipline::Ps);
        assert!(ps.name().contains("ps"));
        assert!(!IngressModel::new(50.0).name().contains("ps"));
        // Unlimited PS is still the independent model.
        let free = IngressModel::with_discipline(0.0, IngressDiscipline::Ps);
        assert!(free.is_unlimited());
        let mut arrivals = vec![3.0, 1.0];
        assert_eq!(free.round_completion(&mut arrivals, 1 << 20), 3.0);
    }
}
