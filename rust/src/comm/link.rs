//! Per-worker uplink model: bandwidth + latency → virtual upload delay.
//!
//! The comm analogue of [`DelayModel`](crate::straggler::DelayModel):
//! queried once per (iteration, worker) with the encoded message size and
//! returning the virtual time the upload occupies. Deterministic — the
//! stochasticity of a round lives in the compute-delay model; the link
//! prices bytes.

/// Per-worker uplink bandwidth and latency.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkModel {
    /// Bytes per unit of virtual time; `f64::INFINITY` = free uplink.
    bandwidth: Vec<f64>,
    /// Fixed per-message latency in virtual time units.
    latency: Vec<f64>,
}

impl LinkModel {
    /// A link that costs nothing — the default every driver starts from;
    /// with it, comm-aware runs match the pre-comm trajectories exactly.
    pub fn zero_cost(n: usize) -> Self {
        Self { bandwidth: vec![f64::INFINITY; n], latency: vec![0.0; n] }
    }

    /// Identical links: `bandwidth` bytes per virtual-time unit
    /// (`<= 0` means infinite) and fixed per-message `latency`.
    pub fn uniform(n: usize, bandwidth: f64, latency: f64) -> Self {
        assert!(latency >= 0.0, "latency must be non-negative");
        let bw = if bandwidth > 0.0 { bandwidth } else { f64::INFINITY };
        Self { bandwidth: vec![bw; n], latency: vec![latency; n] }
    }

    /// Fully heterogeneous links.
    pub fn per_worker(bandwidth: Vec<f64>, latency: Vec<f64>) -> Self {
        assert_eq!(bandwidth.len(), latency.len(), "per-worker lens differ");
        assert!(!bandwidth.is_empty(), "need at least one worker");
        assert!(latency.iter().all(|&l| l >= 0.0), "negative latency");
        let bandwidth = bandwidth
            .into_iter()
            .map(|b| if b > 0.0 { b } else { f64::INFINITY })
            .collect();
        Self { bandwidth, latency }
    }

    /// Uniform links with the last `n_slow` workers' bandwidth divided by
    /// `slow_factor` — the bimodal-cluster idiom from `straggler/`.
    pub fn uniform_with_slow(
        n: usize,
        bandwidth: f64,
        latency: f64,
        n_slow: usize,
        slow_factor: f64,
    ) -> Self {
        assert!(n_slow <= n, "n_slow must be <= n");
        assert!(slow_factor >= 1.0, "slow_factor must be >= 1");
        let mut link = Self::uniform(n, bandwidth, latency);
        for b in link.bandwidth[n - n_slow..].iter_mut() {
            *b /= slow_factor;
        }
        link
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.bandwidth.len()
    }

    /// Virtual time worker `i`'s uplink needs for a `bytes`-sized message.
    pub fn upload_delay(&self, worker: usize, bytes: u64) -> f64 {
        let bw = self.bandwidth[worker];
        let transfer =
            if bw.is_finite() { bytes as f64 / bw } else { 0.0 };
        self.latency[worker] + transfer
    }

    /// True iff every upload is free (infinite bandwidth, zero latency) —
    /// the drivers use this to skip per-worker delay adjustments entirely.
    pub fn is_zero_cost(&self) -> bool {
        self.bandwidth.iter().all(|b| b.is_infinite())
            && self.latency.iter().all(|&l| l == 0.0)
    }

    /// Human-readable description for labels.
    pub fn name(&self) -> String {
        if self.is_zero_cost() {
            return "free-link".into();
        }
        let b0 = self.bandwidth[0];
        let l0 = self.latency[0];
        let uniform = self.bandwidth.iter().all(|&b| b == b0)
            && self.latency.iter().all(|&l| l == l0);
        if uniform {
            format!("link(bw={b0}, lat={l0})")
        } else {
            format!("link(heterogeneous, n={})", self.n())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_cost_is_free_everywhere() {
        let l = LinkModel::zero_cost(8);
        assert!(l.is_zero_cost());
        for i in 0..8 {
            assert_eq!(l.upload_delay(i, 1 << 30), 0.0);
        }
    }

    #[test]
    fn uniform_prices_bytes_linearly() {
        let l = LinkModel::uniform(4, 100.0, 0.5);
        assert!(!l.is_zero_cost());
        assert!((l.upload_delay(0, 200) - 2.5).abs() < 1e-12);
        assert!((l.upload_delay(3, 0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn nonpositive_bandwidth_means_infinite() {
        let l = LinkModel::uniform(2, 0.0, 0.0);
        assert!(l.is_zero_cost());
        let l2 = LinkModel::per_worker(vec![100.0, -1.0], vec![0.0, 0.0]);
        assert_eq!(l2.upload_delay(1, 1_000_000), 0.0);
        assert!(l2.upload_delay(0, 100) > 0.0);
    }

    #[test]
    fn slow_tail_is_slower() {
        let l = LinkModel::uniform_with_slow(10, 100.0, 0.0, 3, 10.0);
        assert!((l.upload_delay(0, 100) - 1.0).abs() < 1e-12);
        assert!((l.upload_delay(9, 100) - 10.0).abs() < 1e-12);
        assert_eq!(l.upload_delay(6, 100), l.upload_delay(0, 100));
    }
}
