//! Gradient communication: compression, error feedback, and a
//! bytes-on-the-wire cost model.
//!
//! The paper treats a worker's response time as a single scalar, but in a
//! real cluster that delay is compute **plus** upload, and the upload cost
//! depends on how the gradient is encoded (cf. the same authors' follow-up,
//! arXiv 2208.03134). This module makes that axis explicit:
//!
//! * [`Compressor`] — lossy/lossless gradient encodings ([`Dense`],
//!   [`QuantizeQsgd`], [`TopK`], [`RandK`]), each reporting its exact
//!   encoded size through a shared [`WireFormat`] size model;
//! * [`ErrorFeedback`] — the per-worker residual accumulator that carries
//!   what compression dropped into the next round, preserving convergence
//!   (Seide et al. 2014; Stich et al. 2018);
//! * [`LinkModel`] — per-worker bandwidth + latency (the comm analogue
//!   of [`DelayModel`](crate::straggler::DelayModel)) converting encoded
//!   bytes into a virtual transfer delay, used by both directions;
//! * [`Broadcast`] — the **downlink**: the master's model broadcast,
//!   encoded dense or as compressed model deltas with a master-side
//!   error-feedback residual ([`DownlinkMode`]), each worker charged a
//!   download delay before its compute starts (cf. arXiv 2208.03134);
//! * [`IngressModel`] — shared master-ingress capacity: a round's
//!   accepted uploads contend on the master's NIC instead of arriving
//!   independently — FIFO store-and-forward or processor sharing
//!   ([`IngressDiscipline`]) — so the round's critical path becomes
//!   compute + *congested* transfer;
//! * [`CommChannel`] — the bundle the training drivers route gradients
//!   through. [`CommChannel::dense`] is the zero-cost default (free
//!   dense downlink, unlimited ingress), and with it every driver
//!   reproduces the pre-`comm` trajectories bit for bit.
//!
//! Because the download + upload delays are added to the compute delay
//! **before** the fastest-k gather, compression genuinely changes which
//! workers land in the top k — the error-runtime trade-off the
//! `fig_comm_tradeoff` and `fig_bidirectional` benches sweep.

mod broadcast;
mod channel;
mod compress;
mod feedback;
mod link;

pub use broadcast::{Broadcast, DownlinkMode};
pub use channel::{CommChannel, CommStats, Transmission};
pub use compress::{Compressor, Dense, QuantizeQsgd, RandK, TopK};
pub use feedback::ErrorFeedback;
pub use link::{IngressDiscipline, IngressModel, LinkModel, PsServer};

/// Byte-accounting model for encoded gradient messages.
///
/// Kept separate from the compressors so every scheme prices its payload
/// with the same framing assumptions and the benches can sweep the model
/// (e.g. 2-byte indices for d < 65536).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireFormat {
    /// Fixed per-message framing: generation tag, worker id, payload
    /// length, checksum.
    pub header_bytes: u64,
    /// Bytes per dense value (f32 on the wire).
    pub value_bytes: u64,
    /// Bytes per coordinate index in a sparse message.
    pub index_bytes: u64,
    /// Bytes for a PRNG seed shipped in place of explicit indices.
    pub seed_bytes: u64,
}

impl Default for WireFormat {
    fn default() -> Self {
        Self { header_bytes: 16, value_bytes: 4, index_bytes: 4, seed_bytes: 8 }
    }
}

impl WireFormat {
    /// 2-byte coordinate indices (`u16` on the wire): halves the
    /// per-coordinate index cost of sparse messages for any `d ≤ 65536`.
    /// The sparsifiers assert the dimension fits at encode time.
    pub fn compact_indices(mut self) -> Self {
        self.index_bytes = 2;
        self
    }

    /// 2-byte values (IEEE 754 binary16 on the wire): halves the
    /// per-coordinate value cost. Value-preserving schemes
    /// ([`Dense`]/[`TopK`]/[`RandK`]) round each shipped value through
    /// f16 (round-to-nearest-even), so the reconstruction loss is
    /// modelled, not just the bytes; [`ErrorFeedback`] recovers the
    /// rounding residual like any other compression error.
    pub fn f16_values(mut self) -> Self {
        self.value_bytes = 2;
        self
    }

    /// Largest coordinate index this format can address.
    pub fn max_index(&self) -> u64 {
        if self.index_bytes >= 8 {
            u64::MAX
        } else {
            (1u64 << (8 * self.index_bytes)) - 1
        }
    }

    /// What a shipped value decodes to under this format: the identity
    /// for full-precision (`value_bytes >= 4`) wires, the f16 round trip
    /// for 2-byte wires. Exactly bitwise for the default format.
    pub fn decode_value(&self, x: f32) -> f32 {
        if self.value_bytes >= 4 {
            x
        } else {
            f16_round_trip(x)
        }
    }

    /// Size of a dense d-vector message.
    pub fn dense(&self, d: usize) -> u64 {
        self.header_bytes + self.value_bytes * d as u64
    }

    /// Size of a sparse message with explicit (index, value) pairs.
    pub fn sparse(&self, nnz: usize) -> u64 {
        self.header_bytes + (self.index_bytes + self.value_bytes) * nnz as u64
    }

    /// Size of a sparse message whose indices are reconstructed from a
    /// shared PRNG seed (values only + the seed).
    pub fn seeded_sparse(&self, nnz: usize) -> u64 {
        self.header_bytes + self.seed_bytes + self.value_bytes * nnz as u64
    }

    /// Size of an s-level stochastically quantized d-vector: one f32 norm
    /// plus `ceil(log2(2s+1))` bits per coordinate (sign ⊗ level ∪ zero),
    /// rounded up to whole bytes.
    pub fn quantized(&self, d: usize, levels: u32) -> u64 {
        let bits = Self::bits_per_symbol(levels) * d as u64;
        self.header_bytes + self.value_bytes + (bits + 7) / 8
    }

    /// Bits to address the `2·levels + 1` quantization symbols.
    pub fn bits_per_symbol(levels: u32) -> u64 {
        let symbols = 2 * levels as u64 + 1;
        // ceil(log2(symbols)) for symbols >= 2.
        64 - (symbols - 1).leading_zeros() as u64
    }
}

/// Largest finite IEEE 754 binary16 value (the f16 saturation point).
pub const F16_MAX: f32 = 65504.0;

/// Convert an `f32` to IEEE 754 binary16 bits, round-to-nearest-even.
///
/// Finite inputs beyond the f16 range **saturate** to ±[`F16_MAX`]
/// (the convention of ML accelerators) instead of rounding to ±inf — an
/// infinite decode would poison the error-feedback residual forever,
/// turning one oversized coordinate into a permanently broken worker.
/// Actual ±inf and NaN inputs keep their class.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN: preserve the class (NaN keeps a quiet payload bit).
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15; // rebias
    if e >= 0x1f {
        return sign | 0x7bff; // finite overflow saturates to ±F16_MAX
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow to ±0
        }
        // Subnormal: shift the 24-bit significand into place,
        // round-to-nearest-even.
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32; // in 14..=24
        let half = 1u32 << (shift - 1);
        let rounded = (m + half - 1 + ((m >> shift) & 1)) >> shift;
        return sign | rounded as u16;
    }
    // Normal: round the 23-bit mantissa to 10 bits, nearest-even; a
    // mantissa carry propagates into the exponent through the packing.
    let half = 0x0fff + ((mant >> 13) & 1);
    let packed = ((e as u32) << 10) + ((mant + half) >> 13);
    if packed >= 0x7c00 {
        return sign | 0x7bff; // carry past the top exponent saturates
    }
    sign | packed as u16
}

/// Convert IEEE 754 binary16 bits back to `f32` (exact — every f16 value
/// is representable in f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (mant << 13));
    }
    if exp == 0 {
        // ±0 and subnormals: mant · 2⁻²⁴, exact in f32 (≤ 10 significant
        // bits times an exact power of two).
        let mag = mant as f32 * f32::from_bits(0x3380_0000); // 2⁻²⁴
        return if sign != 0 { -mag } else { mag };
    }
    f32::from_bits(sign | ((exp + 127 - 15) << 23) | (mant << 13))
}

/// `decode(encode(x))` through the 2-byte wire: what the master
/// reconstructs from an f16-shipped value.
pub fn f16_round_trip(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_sizes_are_exact() {
        let w = WireFormat::default();
        assert_eq!(w.dense(100), 16 + 400);
        assert_eq!(w.sparse(10), 16 + 80);
        assert_eq!(w.seeded_sparse(10), 16 + 8 + 40);
        // 4 levels -> 9 symbols -> 4 bits/coord -> 50 payload bytes.
        assert_eq!(w.quantized(100, 4), 16 + 4 + 50);
    }

    #[test]
    fn bits_per_symbol_is_ceil_log2() {
        assert_eq!(WireFormat::bits_per_symbol(1), 2); // 3 symbols
        assert_eq!(WireFormat::bits_per_symbol(2), 3); // 5 symbols
        assert_eq!(WireFormat::bits_per_symbol(4), 4); // 9 symbols
        assert_eq!(WireFormat::bits_per_symbol(127), 8); // 255 symbols
        assert_eq!(WireFormat::bits_per_symbol(128), 9); // 257 symbols
    }

    #[test]
    fn sparsification_beats_dense_only_below_half_density() {
        let w = WireFormat::default();
        // (index, value) pairs double the per-coordinate cost.
        assert!(w.sparse(50) < w.dense(100) + w.header_bytes);
        assert!(w.sparse(10) * 4 < w.dense(100));
    }

    #[test]
    fn compact_wire_formats_price_exactly() {
        let w = WireFormat::default().compact_indices();
        assert_eq!(w.index_bytes, 2);
        assert_eq!(w.sparse(10), 16 + 10 * (2 + 4));
        assert_eq!(w.max_index(), 65535);
        let w = WireFormat::default().f16_values();
        assert_eq!(w.value_bytes, 2);
        assert_eq!(w.dense(100), 16 + 200);
        assert_eq!(w.sparse(10), 16 + 10 * (4 + 2));
        let both = WireFormat::default().compact_indices().f16_values();
        assert_eq!(both.sparse(10), 16 + 10 * 4);
        // The default format addresses any dimension and decodes bitwise.
        assert_eq!(WireFormat::default().max_index(), u64::MAX >> 32);
        assert_eq!(WireFormat::default().decode_value(1.2345), 1.2345);
    }

    #[test]
    fn f16_round_trip_is_exact_on_representable_values() {
        for &x in &[
            0.0f32,
            -0.0,
            1.0,
            -1.0,
            0.5,
            2.0,
            65504.0,
            -65504.0,
            f32::powi(2.0, -14), // smallest f16 normal
            f32::powi(2.0, -24), // smallest f16 subnormal
            1.5,
            -0.25,
            1024.0,
        ] {
            let y = f16_round_trip(x);
            assert_eq!(y.to_bits(), x.to_bits(), "{x} -> {y}");
        }
    }

    #[test]
    fn f16_rounds_to_nearest_even_and_saturates() {
        // 1 + 2^-11 is exactly between 1.0 and the next f16 (1 + 2^-10):
        // nearest-even rounds down to 1.0.
        assert_eq!(f16_round_trip(1.0 + f32::powi(2.0, -11)), 1.0);
        // 1 + 3·2^-11 is between 1+2^-10 and 1+2^-9: rounds to even, up.
        assert_eq!(
            f16_round_trip(1.0 + 3.0 * f32::powi(2.0, -11)),
            1.0 + 2.0 * f32::powi(2.0, -10)
        );
        // Finite overflow saturates instead of producing inf.
        assert_eq!(f16_round_trip(1e30), F16_MAX);
        assert_eq!(f16_round_trip(-1e30), -F16_MAX);
        assert_eq!(f16_round_trip(65520.0), F16_MAX);
        // True infinities and NaN keep their class.
        assert!(f16_round_trip(f32::INFINITY).is_infinite());
        assert!(f16_round_trip(f32::NEG_INFINITY) < 0.0);
        assert!(f16_round_trip(f32::NAN).is_nan());
        // Tiny values underflow to signed zero.
        assert_eq!(f16_round_trip(1e-10).to_bits(), 0.0f32.to_bits());
        assert_eq!(f16_round_trip(-1e-10).to_bits(), (-0.0f32).to_bits());
        // decode_value is the identity on the 4-byte wire and the f16
        // round trip on the 2-byte wire.
        let w2 = WireFormat::default().f16_values();
        assert_eq!(w2.decode_value(1.2345), f16_round_trip(1.2345));
    }
}
