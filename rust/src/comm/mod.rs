//! Gradient communication: compression, error feedback, and a
//! bytes-on-the-wire cost model.
//!
//! The paper treats a worker's response time as a single scalar, but in a
//! real cluster that delay is compute **plus** upload, and the upload cost
//! depends on how the gradient is encoded (cf. the same authors' follow-up,
//! arXiv 2208.03134). This module makes that axis explicit:
//!
//! * [`Compressor`] — lossy/lossless gradient encodings ([`Dense`],
//!   [`QuantizeQsgd`], [`TopK`], [`RandK`]), each reporting its exact
//!   encoded size through a shared [`WireFormat`] size model;
//! * [`ErrorFeedback`] — the per-worker residual accumulator that carries
//!   what compression dropped into the next round, preserving convergence
//!   (Seide et al. 2014; Stich et al. 2018);
//! * [`LinkModel`] — per-worker bandwidth + latency (the comm analogue
//!   of [`DelayModel`](crate::straggler::DelayModel)) converting encoded
//!   bytes into a virtual transfer delay, used by both directions;
//! * [`Broadcast`] — the **downlink**: the master's model broadcast,
//!   encoded dense or as compressed model deltas with a master-side
//!   error-feedback residual ([`DownlinkMode`]), each worker charged a
//!   download delay before its compute starts (cf. arXiv 2208.03134);
//! * [`IngressModel`] — shared master-ingress capacity: a round's
//!   accepted uploads serialize FIFO through the master's NIC instead of
//!   arriving independently, so the round's critical path becomes
//!   compute + *congested* transfer;
//! * [`CommChannel`] — the bundle the training drivers route gradients
//!   through. [`CommChannel::dense`] is the zero-cost default (free
//!   dense downlink, unlimited ingress), and with it every driver
//!   reproduces the pre-`comm` trajectories bit for bit.
//!
//! Because the download + upload delays are added to the compute delay
//! **before** the fastest-k gather, compression genuinely changes which
//! workers land in the top k — the error-runtime trade-off the
//! `fig_comm_tradeoff` and `fig_bidirectional` benches sweep.

mod broadcast;
mod channel;
mod compress;
mod feedback;
mod link;

pub use broadcast::{Broadcast, DownlinkMode};
pub use channel::{CommChannel, CommStats, Transmission};
pub use compress::{Compressor, Dense, QuantizeQsgd, RandK, TopK};
pub use feedback::ErrorFeedback;
pub use link::{IngressModel, LinkModel};

/// Byte-accounting model for encoded gradient messages.
///
/// Kept separate from the compressors so every scheme prices its payload
/// with the same framing assumptions and the benches can sweep the model
/// (e.g. 2-byte indices for d < 65536).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireFormat {
    /// Fixed per-message framing: generation tag, worker id, payload
    /// length, checksum.
    pub header_bytes: u64,
    /// Bytes per dense value (f32 on the wire).
    pub value_bytes: u64,
    /// Bytes per coordinate index in a sparse message.
    pub index_bytes: u64,
    /// Bytes for a PRNG seed shipped in place of explicit indices.
    pub seed_bytes: u64,
}

impl Default for WireFormat {
    fn default() -> Self {
        Self { header_bytes: 16, value_bytes: 4, index_bytes: 4, seed_bytes: 8 }
    }
}

impl WireFormat {
    /// Size of a dense d-vector message.
    pub fn dense(&self, d: usize) -> u64 {
        self.header_bytes + self.value_bytes * d as u64
    }

    /// Size of a sparse message with explicit (index, value) pairs.
    pub fn sparse(&self, nnz: usize) -> u64 {
        self.header_bytes + (self.index_bytes + self.value_bytes) * nnz as u64
    }

    /// Size of a sparse message whose indices are reconstructed from a
    /// shared PRNG seed (values only + the seed).
    pub fn seeded_sparse(&self, nnz: usize) -> u64 {
        self.header_bytes + self.seed_bytes + self.value_bytes * nnz as u64
    }

    /// Size of an s-level stochastically quantized d-vector: one f32 norm
    /// plus `ceil(log2(2s+1))` bits per coordinate (sign ⊗ level ∪ zero),
    /// rounded up to whole bytes.
    pub fn quantized(&self, d: usize, levels: u32) -> u64 {
        let bits = Self::bits_per_symbol(levels) * d as u64;
        self.header_bytes + self.value_bytes + (bits + 7) / 8
    }

    /// Bits to address the `2·levels + 1` quantization symbols.
    pub fn bits_per_symbol(levels: u32) -> u64 {
        let symbols = 2 * levels as u64 + 1;
        // ceil(log2(symbols)) for symbols >= 2.
        64 - (symbols - 1).leading_zeros() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_sizes_are_exact() {
        let w = WireFormat::default();
        assert_eq!(w.dense(100), 16 + 400);
        assert_eq!(w.sparse(10), 16 + 80);
        assert_eq!(w.seeded_sparse(10), 16 + 8 + 40);
        // 4 levels -> 9 symbols -> 4 bits/coord -> 50 payload bytes.
        assert_eq!(w.quantized(100, 4), 16 + 4 + 50);
    }

    #[test]
    fn bits_per_symbol_is_ceil_log2() {
        assert_eq!(WireFormat::bits_per_symbol(1), 2); // 3 symbols
        assert_eq!(WireFormat::bits_per_symbol(2), 3); // 5 symbols
        assert_eq!(WireFormat::bits_per_symbol(4), 4); // 9 symbols
        assert_eq!(WireFormat::bits_per_symbol(127), 8); // 255 symbols
        assert_eq!(WireFormat::bits_per_symbol(128), 9); // 257 symbols
    }

    #[test]
    fn sparsification_beats_dense_only_below_half_density() {
        let w = WireFormat::default();
        // (index, value) pairs double the per-coordinate cost.
        assert!(w.sparse(50) < w.dense(100) + w.header_bytes);
        assert!(w.sparse(10) * 4 < w.dense(100));
    }
}
