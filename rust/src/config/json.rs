//! Minimal recursive-descent JSON parser (RFC 8259 subset: no \u escapes
//! beyond BMP surrogate pairs are combined, numbers are f64).
//!
//! Only what the artifact manifest needs — but complete enough to parse
//! any manifest the exporter can emit.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any number (f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array elements.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }
}

/// Parse failure with byte offset.
#[derive(Debug, PartialEq)]
pub struct JsonError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// Description.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    let s = self
                        .bytes
                        .get(start..start + len)
                        .and_then(|b| std::str::from_utf8(b).ok())
                        .ok_or_else(|| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            v = v * 16
                + (c as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err("bad hex"))?;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
 "version": 1,
 "entries": [
  {"name": "linreg_grad_s40_d100", "file": "a.hlo.txt",
   "inputs": [{"shape": [40, 100], "dtype": "float32"}],
   "meta": {"kind": "linreg_grad", "s": 40}}
 ]
}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let entries = j.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(
            entries[0].get("name").unwrap().as_str(),
            Some("linreg_grad_s40_d100")
        );
        let shape = entries[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize(), Some(100));
    }

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn nested_and_empty() {
        let j = Json::parse(r#"{"a": [], "b": {}, "c": [[1], [2, 3]]}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(
            j.get("c").unwrap().as_arr().unwrap()[1].as_arr().unwrap().len(),
            2
        );
    }

    #[test]
    fn utf8_strings() {
        let j = Json::parse(r#""héllo — ✓""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo — ✓"));
    }

    #[test]
    fn errors_carry_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos >= 5, "{e:?}");
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
    }
}
