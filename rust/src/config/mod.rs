//! Configuration substrate.
//!
//! Offline build means no serde: this module provides the two parsers the
//! system needs —
//!
//! * [`json`] — a minimal JSON parser for `artifacts/manifest.json`
//!   (written by `python/compile/aot.py`),
//! * [`toml`] — a TOML-subset parser for experiment configs
//!   (`adasgd train --config exp.toml`),
//!
//! plus the typed [`ExperimentConfig`] schema with validation.

pub mod json;
pub mod toml;

mod schema;

pub use schema::{
    CodingSchemeSpec, CodingSpec, CommSpec, CompressorSpec, DelaySpec,
    ExperimentConfig, PolicySpec, WorkloadSpec,
};
