//! Typed experiment configuration with TOML loading + validation.

use super::toml::TomlDoc;
use crate::comm::IngressDiscipline;
use crate::policy::PflugParams;

/// Which delay model to simulate.
#[derive(Debug, Clone, PartialEq)]
pub enum DelaySpec {
    /// iid exp(λ).
    Exponential {
        /// Rate λ.
        lambda: f64,
    },
    /// Δ + exp(λ).
    ShiftedExponential {
        /// Constant shift Δ.
        shift: f64,
        /// Rate λ.
        lambda: f64,
    },
    /// Pareto(xm, α).
    Pareto {
        /// Scale xm.
        xm: f64,
        /// Shape α.
        alpha: f64,
    },
    /// Weibull(λ, k).
    Weibull {
        /// Scale λ.
        lambda: f64,
        /// Shape k.
        k: f64,
    },
    /// Bimodal with persistent slow nodes.
    Bimodal {
        /// Base rate λ.
        lambda: f64,
        /// Number of persistently slow workers.
        n_slow: usize,
        /// Slow-down multiplier.
        slow_factor: f64,
        /// Transient straggle probability for fast workers.
        p_transient: f64,
    },
    /// Replay a CSV trace file.
    Trace {
        /// Path to the CSV.
        path: String,
    },
}

impl DelaySpec {
    /// Instantiate the delay model.
    pub fn build(&self) -> Result<Box<dyn crate::straggler::DelayModel>, String> {
        use crate::straggler::*;
        Ok(match self {
            DelaySpec::Exponential { lambda } => {
                Box::new(ExponentialDelays::new(*lambda))
            }
            DelaySpec::ShiftedExponential { shift, lambda } => {
                Box::new(ShiftedExponentialDelays::new(*shift, *lambda))
            }
            DelaySpec::Pareto { xm, alpha } => {
                Box::new(ParetoDelays::new(*xm, *alpha))
            }
            DelaySpec::Weibull { lambda, k } => {
                Box::new(WeibullDelays::new(*lambda, *k))
            }
            DelaySpec::Bimodal { lambda, n_slow, slow_factor, p_transient } => {
                Box::new(BimodalDelays::new(
                    *lambda,
                    *n_slow,
                    *slow_factor,
                    *p_transient,
                ))
            }
            DelaySpec::Trace { path } => Box::new(
                TraceDelays::from_file(std::path::Path::new(path))?,
            ),
        })
    }
}

/// Which gradient compression scheme to apply on the uplink.
#[derive(Debug, Clone, PartialEq)]
pub enum CompressorSpec {
    /// Full-precision f32 payload (the default; lossless).
    Dense,
    /// QSGD stochastic quantization with `levels` levels per sign.
    Qsgd {
        /// Quantization levels s >= 1.
        levels: u32,
    },
    /// Top-k magnitude sparsification keeping fraction `frac`.
    TopK {
        /// Kept coordinate fraction in (0, 1].
        frac: f64,
    },
    /// Seeded random sparsification keeping fraction `frac`.
    RandK {
        /// Kept coordinate fraction in (0, 1].
        frac: f64,
    },
}

/// Bidirectional communication model: uplink scheme + error feedback +
/// link parameters, downlink (model broadcast) scheme + link, and the
/// shared master-ingress capacity.
#[derive(Debug, Clone, PartialEq)]
pub struct CommSpec {
    /// Uplink compression scheme.
    pub scheme: CompressorSpec,
    /// Carry compression residuals across rounds (ignored for `Dense`).
    pub error_feedback: bool,
    /// Uplink bandwidth in bytes per virtual-time unit (0 = infinite).
    pub bandwidth: f64,
    /// Fixed per-message upload latency in virtual-time units.
    pub latency: f64,
    /// Number of workers (the *last* `slow_workers` ids) whose uplink
    /// bandwidth is divided by `slow_factor` — the bimodal-cluster link
    /// idiom ([`LinkModel::uniform_with_slow`]). 0 = uniform uplink.
    /// Requires a finite positive `bandwidth` when non-zero.
    ///
    /// [`LinkModel::uniform_with_slow`]:
    ///     crate::comm::LinkModel::uniform_with_slow
    pub slow_workers: usize,
    /// Uplink slowdown factor of the slow tail (>= 1; only observable
    /// with `slow_workers > 0`).
    pub slow_factor: f64,
    /// Downlink (model broadcast) scheme. `Dense` broadcasts the full
    /// model; any compressed scheme broadcasts *model deltas* with a
    /// master-side error-feedback residual.
    pub downlink: CompressorSpec,
    /// Downlink bandwidth in bytes per virtual-time unit (0 = infinite).
    pub down_bandwidth: f64,
    /// Per-worker downlink bandwidths (bytes per virtual-time unit,
    /// 0 = infinite for that worker). Empty = uniform `down_bandwidth`
    /// for everyone; non-empty must have exactly `n` entries and
    /// overrides `down_bandwidth`.
    pub down_bandwidths: Vec<f64>,
    /// Fixed per-message download latency in virtual-time units.
    pub down_latency: f64,
    /// Shared master-ingress capacity in bytes per virtual-time unit
    /// (0 = infinite, i.e. independent uploads).
    pub ingress_bw: f64,
    /// Queueing discipline of the shared ingress (FIFO store-and-forward
    /// or processor sharing; only observable with a finite `ingress_bw`).
    pub ingress: IngressDiscipline,
}

impl Default for CommSpec {
    /// Dense over free links both ways, unlimited ingress — the paper's
    /// compute-only timing.
    fn default() -> Self {
        Self {
            scheme: CompressorSpec::Dense,
            error_feedback: true,
            bandwidth: 0.0,
            latency: 0.0,
            slow_workers: 0,
            slow_factor: 1.0,
            downlink: CompressorSpec::Dense,
            down_bandwidth: 0.0,
            down_bandwidths: Vec::new(),
            down_latency: 0.0,
            ingress_bw: 0.0,
            ingress: IngressDiscipline::Fifo,
        }
    }
}

/// Build the compressor named by a [`CompressorSpec`].
fn build_compressor(spec: &CompressorSpec) -> Box<dyn crate::comm::Compressor> {
    use crate::comm::{Dense, QuantizeQsgd, RandK, TopK};
    match spec {
        CompressorSpec::Dense => Box::new(Dense::new()),
        CompressorSpec::Qsgd { levels } => Box::new(QuantizeQsgd::new(*levels)),
        CompressorSpec::TopK { frac } => Box::new(TopK::new(*frac)),
        CompressorSpec::RandK { frac } => Box::new(RandK::new(*frac)),
    }
}

/// Scheme-parameter checks shared by the uplink and downlink fields.
fn validate_scheme(spec: &CompressorSpec, key: &str) -> Result<(), String> {
    match *spec {
        CompressorSpec::Qsgd { levels } if levels == 0 => {
            Err(format!("comm.{key}: levels must be >= 1"))
        }
        CompressorSpec::TopK { frac } | CompressorSpec::RandK { frac }
            if !(frac > 0.0 && frac <= 1.0) =>
        {
            Err(format!("comm.{key}: frac={frac} must be in (0, 1]"))
        }
        _ => Ok(()),
    }
}

/// Finite non-negative check for a link/ingress rate parameter.
fn validate_rate(value: f64, key: &str) -> Result<(), String> {
    // Finiteness matters: NaN slips past a `< 0.0` check and +inf
    // panics deep in the drivers instead of failing here.
    if !value.is_finite() || value < 0.0 {
        return Err(format!(
            "comm.{key}={value} must be finite and >= 0 (0 = infinite/free)"
        ));
    }
    Ok(())
}

impl CommSpec {
    /// Instantiate the channel for `n` workers.
    pub fn build(&self, n: usize) -> crate::comm::CommChannel {
        use crate::comm::{
            Broadcast, CommChannel, DownlinkMode, IngressModel, LinkModel,
        };
        let compressor = build_compressor(&self.scheme);
        let link = if self.slow_workers > 0 {
            LinkModel::uniform_with_slow(
                n,
                self.bandwidth,
                self.latency,
                self.slow_workers,
                self.slow_factor,
            )
        } else if self.bandwidth <= 0.0 && self.latency <= 0.0 {
            LinkModel::zero_cost(n)
        } else {
            LinkModel::uniform(n, self.bandwidth, self.latency)
        };
        let feedback = self.error_feedback
            && !matches!(self.scheme, CompressorSpec::Dense);
        let down_link = if !self.down_bandwidths.is_empty() {
            // Heterogeneous downlinks: one bandwidth per worker (0 =
            // infinite for that worker), shared latency.
            assert_eq!(
                self.down_bandwidths.len(),
                n,
                "down_bandwidths must list all {n} workers (validate() \
                 reports this as a config error)"
            );
            LinkModel::per_worker(
                self.down_bandwidths.clone(),
                vec![self.down_latency; n],
            )
        } else if self.down_bandwidth <= 0.0 && self.down_latency <= 0.0 {
            LinkModel::zero_cost(n)
        } else {
            LinkModel::uniform(n, self.down_bandwidth, self.down_latency)
        };
        let mode = if matches!(self.downlink, CompressorSpec::Dense) {
            DownlinkMode::Full
        } else {
            DownlinkMode::Delta
        };
        CommChannel::new(compressor, link, feedback)
            .with_broadcast(Broadcast::new(
                build_compressor(&self.downlink),
                down_link,
                mode,
            ))
            .with_ingress(IngressModel::with_discipline(
                self.ingress_bw,
                self.ingress,
            ))
    }

    /// Check scheme/link/ingress parameters. `n` = 0 skips the
    /// per-worker length check (callers without a worker count).
    pub fn validate(&self, n: usize) -> Result<(), String> {
        validate_scheme(&self.scheme, "scheme")?;
        validate_scheme(&self.downlink, "downlink")?;
        validate_rate(self.bandwidth, "bandwidth")?;
        validate_rate(self.latency, "latency")?;
        if !self.slow_factor.is_finite() || self.slow_factor < 1.0 {
            return Err(format!(
                "comm.slow_factor={} must be finite and >= 1",
                self.slow_factor
            ));
        }
        if self.slow_workers > 0 {
            if n > 0 && self.slow_workers > n {
                return Err(format!(
                    "comm.slow_workers={} exceeds n={n}",
                    self.slow_workers
                ));
            }
            if self.bandwidth <= 0.0 {
                return Err(format!(
                    "comm.slow_workers={} needs a finite positive \
                     comm.bandwidth (0 = infinite, which cannot be slowed)",
                    self.slow_workers
                ));
            }
        }
        validate_rate(self.down_bandwidth, "down_bandwidth")?;
        validate_rate(self.down_latency, "down_latency")?;
        validate_rate(self.ingress_bw, "ingress_bw")?;
        for (i, &bw) in self.down_bandwidths.iter().enumerate() {
            validate_rate(bw, &format!("down_bandwidths[{i}]"))?;
        }
        if !self.down_bandwidths.is_empty()
            && n > 0
            && self.down_bandwidths.len() != n
        {
            return Err(format!(
                "comm.down_bandwidths has {} entries but n={n}",
                self.down_bandwidths.len()
            ));
        }
        Ok(())
    }
}

/// Which gradient-coding placement assigns redundant shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodingSchemeSpec {
    /// Grouped fractional repetition (requires `r | n`).
    Frc,
    /// Cyclic windows (any `r <= n`).
    Cyclic,
    /// Seeded random r-regular placement (probabilistic decode below
    /// the threshold).
    Bernoulli,
}

impl std::fmt::Display for CodingSchemeSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            CodingSchemeSpec::Frc => "frc",
            CodingSchemeSpec::Cyclic => "cyclic",
            CodingSchemeSpec::Bernoulli => "bernoulli",
        })
    }
}

/// Gradient-coding configuration: placement family + replication factor.
/// When present, the experiment runs the engine's
/// [`CodedGather`](crate::engine::CodedGather) discipline — the k policy
/// adapts the *wait target*, and each round applies the exact full
/// gradient decoded from the first decodable responder set.
#[derive(Debug, Clone, PartialEq)]
pub struct CodingSpec {
    /// Placement family.
    pub scheme: CodingSchemeSpec,
    /// Replication factor r (shards per worker, compute multiplier).
    pub r: usize,
}

impl CodingSpec {
    /// Instantiate the scheme for `n` workers (the Bernoulli placement
    /// derives its assignment from `seed`).
    pub fn build(
        &self,
        n: usize,
        seed: u64,
    ) -> Result<Box<dyn crate::coding::CodingScheme>, String> {
        use crate::coding::{BernoulliScheme, CyclicRepetition, FrcScheme};
        let scheme: Box<dyn crate::coding::CodingScheme> = match self.scheme
        {
            CodingSchemeSpec::Frc => Box::new(FrcScheme::new(n, self.r)?),
            CodingSchemeSpec::Cyclic => {
                Box::new(CyclicRepetition::new(n, self.r)?)
            }
            CodingSchemeSpec::Bernoulli => {
                Box::new(BernoulliScheme::new(n, self.r, seed)?)
            }
        };
        Ok(scheme)
    }

    /// Check the placement against the worker count — user-supplied
    /// `r ∤ n` (frc) or out-of-range r fail here with an actionable
    /// message instead of panicking mid-run.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        self.build(n, 0).map(|_| ()).map_err(|e| format!("coding: {e}"))
    }
}

/// Which k policy to run.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    /// Non-adaptive fastest-k.
    Fixed {
        /// The fixed k.
        k: usize,
    },
    /// Algorithm 1.
    Adaptive(PflugParams),
    /// Asynchronous SGD baseline (no k).
    Async,
}

/// Which workload to train.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// Synthetic linear regression (paper §V).
    LinReg {
        /// Data rows m.
        m: usize,
        /// Feature dimension d.
        d: usize,
    },
    /// Transformer LM via the AOT artifact with the given tag.
    Transformer {
        /// Artifact tag ("tiny" / "large").
        tag: String,
    },
}

/// A full experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Run label.
    pub label: String,
    /// Workers n.
    pub n: usize,
    /// Step size η.
    pub eta: f64,
    /// Iteration cap.
    pub max_iterations: u64,
    /// Virtual-time budget (0 = none).
    pub max_time: f64,
    /// RNG seed.
    pub seed: u64,
    /// Record stride.
    pub record_stride: u64,
    /// Delay model.
    pub delays: DelaySpec,
    /// Policy.
    pub policy: PolicySpec,
    /// Workload.
    pub workload: WorkloadSpec,
    /// Uplink communication model.
    pub comm: CommSpec,
    /// Gradient coding (None = the uncoded fastest-k / async paths).
    pub coding: Option<CodingSpec>,
    /// Sweep parallelism for multi-run commands driven by this config
    /// (`repeat`, figure regeneration): worker threads, `0` = all
    /// available cores. TOML: `[run] jobs`. Never part of the
    /// experiment's identity — `jobs = 1` and `jobs = N` produce
    /// byte-identical results (see [`crate::sweep`]).
    pub jobs: usize,
    /// Intra-round parallelism: worker threads fanned out *inside* one
    /// round (responder gradients, d-dimensional merge/apply blocks),
    /// `1` = strictly serial, `0` = all available cores. TOML:
    /// `[run] intra_jobs`; CLI: `--intra-jobs`. Like `jobs`, never part
    /// of the experiment's identity — every value produces byte-identical
    /// results (see [`crate::exec::par`]), and the two compose on one
    /// shared pool without oversubscription.
    pub intra_jobs: usize,
    /// Event-trace output directory (`None` = tracing off). TOML:
    /// `[trace] dir`; CLI: `--trace <dir>`. When set, every run records
    /// a binary event trace to `<dir>/<sanitized-label>.trace` (see
    /// [`crate::trace`]). Never part of the experiment's identity —
    /// tracing changes no RNG draw, clock value, or output byte.
    pub trace: Option<String>,
    /// Opt-in O(k) order-statistics fast path for synchronous rounds
    /// (see [`crate::engine::FastpathGather`]): sample the first-k
    /// arrival times directly instead of drawing all n delays. TOML:
    /// `[run] fastpath`; CLI: `--fastpath`. Distributionally — not
    /// bitwise — equivalent to the exhaustive gather, so unlike `jobs`
    /// it *is* part of the experiment's identity; off by default keeps
    /// every existing trajectory bit-identical.
    pub fastpath: bool,
}

impl Default for ExperimentConfig {
    /// Paper Fig. 2 adaptive run.
    fn default() -> Self {
        Self {
            label: "fig2-adaptive".into(),
            n: 50,
            eta: 5e-4,
            max_iterations: 100_000,
            max_time: 2500.0,
            seed: 0,
            record_stride: 20,
            delays: DelaySpec::Exponential { lambda: 1.0 },
            policy: PolicySpec::Adaptive(PflugParams::default()),
            workload: WorkloadSpec::LinReg { m: 2000, d: 100 },
            comm: CommSpec::default(),
            coding: None,
            jobs: 0,
            intra_jobs: 1,
            trace: None,
            fastpath: false,
        }
    }
}

impl ExperimentConfig {
    /// Parse from TOML text. Missing keys take the defaults.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ExperimentConfig::default();

        if let Some(v) = doc.get("", "label") {
            cfg.label = v.as_str().ok_or("label must be a string")?.into();
        }
        if let Some(v) = doc.get("", "n") {
            cfg.n = v.as_int().ok_or("n must be an int")? as usize;
        }
        if let Some(v) = doc.get("", "eta") {
            cfg.eta = v.as_float().ok_or("eta must be a float")?;
        }
        if let Some(v) = doc.get("", "max_iterations") {
            cfg.max_iterations =
                v.as_int().ok_or("max_iterations must be an int")? as u64;
        }
        if let Some(v) = doc.get("", "max_time") {
            cfg.max_time = v.as_float().ok_or("max_time must be a float")?;
        }
        if let Some(v) = doc.get("", "seed") {
            cfg.seed = v.as_int().ok_or("seed must be an int")? as u64;
        }
        if let Some(v) = doc.get("", "record_stride") {
            cfg.record_stride =
                v.as_int().ok_or("record_stride must be an int")? as u64;
        }

        if let Some(sec) = doc.section("delays") {
            let kind = sec
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or("delays.kind is required in [delays]")?;
            let f = |key: &str, dflt: f64| {
                sec.get(key).and_then(|v| v.as_float()).unwrap_or(dflt)
            };
            cfg.delays = match kind {
                "exponential" => {
                    DelaySpec::Exponential { lambda: f("lambda", 1.0) }
                }
                "shifted-exponential" => DelaySpec::ShiftedExponential {
                    shift: f("shift", 1.0),
                    lambda: f("lambda", 1.0),
                },
                "pareto" => {
                    DelaySpec::Pareto { xm: f("xm", 1.0), alpha: f("alpha", 2.5) }
                }
                "weibull" => {
                    DelaySpec::Weibull { lambda: f("lambda", 1.0), k: f("k", 1.0) }
                }
                "bimodal" => DelaySpec::Bimodal {
                    lambda: f("lambda", 1.0),
                    n_slow: f("n_slow", 0.0) as usize,
                    slow_factor: f("slow_factor", 10.0),
                    p_transient: f("p_transient", 0.0),
                },
                "trace" => DelaySpec::Trace {
                    path: sec
                        .get("path")
                        .and_then(|v| v.as_str())
                        .ok_or("delays.path required for trace")?
                        .into(),
                },
                other => return Err(format!("unknown delays.kind '{other}'")),
            };
        }

        if let Some(sec) = doc.section("policy") {
            let kind = sec
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or("policy.kind is required in [policy]")?;
            let i = |key: &str, dflt: i64| {
                sec.get(key).and_then(|v| v.as_int()).unwrap_or(dflt)
            };
            cfg.policy = match kind {
                "fixed" => PolicySpec::Fixed { k: i("k", 10) as usize },
                "adaptive" => PolicySpec::Adaptive(PflugParams {
                    k0: i("k0", 10) as usize,
                    step: i("step", 10) as usize,
                    thresh: i("thresh", 10),
                    burnin: i("burnin", 200) as u64,
                    k_max: i("k_max", cfg.n as i64) as usize,
                }),
                "async" => PolicySpec::Async,
                other => return Err(format!("unknown policy.kind '{other}'")),
            };
        }

        if let Some(sec) = doc.section("comm") {
            let f = |key: &str, dflt: f64| {
                sec.get(key).and_then(|v| v.as_float()).unwrap_or(dflt)
            };
            // Shared scheme parser for the uplink (`kind`/`levels`/`frac`)
            // and downlink (`downlink`/`down_levels`/`down_frac`) keys.
            let scheme = |kind_key: &str,
                          levels_key: &str,
                          frac_key: &str|
             -> Result<CompressorSpec, String> {
                let kind = sec
                    .get(kind_key)
                    .and_then(|v| v.as_str())
                    .unwrap_or("dense");
                Ok(match kind {
                    "dense" => CompressorSpec::Dense,
                    "qsgd" => {
                        let levels = sec
                            .get(levels_key)
                            .and_then(|v| v.as_int())
                            .unwrap_or(4);
                        // Check the i64 before narrowing: `levels = -1`
                        // must not wrap into a 4-billion-level scheme.
                        if !(1..=i64::from(u32::MAX)).contains(&levels) {
                            return Err(format!(
                                "comm.{levels_key}={levels} must be in 1..={}",
                                u32::MAX
                            ));
                        }
                        CompressorSpec::Qsgd { levels: levels as u32 }
                    }
                    "topk" => {
                        CompressorSpec::TopK { frac: f(frac_key, 0.1) }
                    }
                    "randk" => {
                        CompressorSpec::RandK { frac: f(frac_key, 0.1) }
                    }
                    other => {
                        return Err(format!(
                            "unknown comm.{kind_key} '{other}'"
                        ))
                    }
                })
            };
            cfg.comm.scheme = scheme("kind", "levels", "frac")?;
            cfg.comm.downlink =
                scheme("downlink", "down_levels", "down_frac")?;
            cfg.comm.error_feedback = sec
                .get("error_feedback")
                .and_then(|v| v.as_bool())
                .unwrap_or(true);
            cfg.comm.bandwidth = f("bandwidth", 0.0);
            cfg.comm.latency = f("latency", 0.0);
            if let Some(v) = sec.get("slow_workers") {
                let sw = v
                    .as_int()
                    .ok_or("comm.slow_workers must be an integer")?;
                if sw < 0 {
                    return Err(format!(
                        "comm.slow_workers={sw} must be >= 0"
                    ));
                }
                cfg.comm.slow_workers = sw as usize;
            }
            cfg.comm.slow_factor = f("slow_factor", 1.0);
            cfg.comm.down_bandwidth = f("down_bandwidth", 0.0);
            cfg.comm.down_latency = f("down_latency", 0.0);
            cfg.comm.ingress_bw = f("ingress_bw", 0.0);
            if let Some(v) = sec.get("down_bandwidths") {
                let arr = v
                    .as_arr()
                    .ok_or("comm.down_bandwidths must be an array")?;
                cfg.comm.down_bandwidths = arr
                    .iter()
                    .map(|x| {
                        x.as_float().ok_or_else(|| {
                            "comm.down_bandwidths entries must be numbers"
                                .to_string()
                        })
                    })
                    .collect::<Result<Vec<f64>, String>>()?;
            }
            if let Some(v) = sec.get("ingress") {
                cfg.comm.ingress = match v.as_str() {
                    Some("fifo") => IngressDiscipline::Fifo,
                    Some("ps") => IngressDiscipline::Ps,
                    other => {
                        return Err(format!(
                            "comm.ingress must be \"fifo\" or \"ps\", got \
                             {other:?}"
                        ))
                    }
                };
            }
        }

        if let Some(sec) = doc.section("coding") {
            // Wrong-typed values are errors, not silent defaults — a
            // coded run must never execute a scheme/r the user did not
            // choose.
            let scheme = match sec.get("scheme") {
                None => CodingSchemeSpec::Frc,
                Some(v) => match v.as_str() {
                    Some("frc") => CodingSchemeSpec::Frc,
                    Some("cyclic") => CodingSchemeSpec::Cyclic,
                    Some("bernoulli") => CodingSchemeSpec::Bernoulli,
                    Some(other) => {
                        return Err(format!(
                            "unknown coding.scheme '{other}' (frc | \
                             cyclic | bernoulli)"
                        ))
                    }
                    None => {
                        return Err("coding.scheme must be a string \
                                    (frc | cyclic | bernoulli)"
                            .into())
                    }
                },
            };
            let r = match sec.get("r") {
                None => 2,
                Some(v) => {
                    v.as_int().ok_or("coding.r must be an integer")?
                }
            };
            if r < 1 {
                return Err(format!("coding.r={r} must be >= 1"));
            }
            cfg.coding = Some(CodingSpec { scheme, r: r as usize });
        }

        if let Some(sec) = doc.section("run") {
            if let Some(v) = sec.get("jobs") {
                let jobs =
                    v.as_int().ok_or("run.jobs must be an integer")?;
                if jobs < 0 {
                    return Err(format!(
                        "run.jobs={jobs} must be >= 0 (0 = available \
                         parallelism)"
                    ));
                }
                cfg.jobs = jobs as usize;
            }
            if let Some(v) = sec.get("intra_jobs") {
                let intra =
                    v.as_int().ok_or("run.intra_jobs must be an integer")?;
                if intra < 0 {
                    return Err(format!(
                        "run.intra_jobs={intra} must be >= 0 (0 = available \
                         parallelism)"
                    ));
                }
                cfg.intra_jobs = intra as usize;
            }
            if let Some(v) = sec.get("fastpath") {
                cfg.fastpath = v
                    .as_bool()
                    .ok_or("run.fastpath must be a boolean")?;
            }
        }

        if let Some(sec) = doc.section("trace") {
            if let Some(v) = sec.get("dir") {
                let dir = v
                    .as_str()
                    .ok_or("trace.dir must be a string (directory path)")?;
                if dir.is_empty() {
                    return Err("trace.dir must not be empty".into());
                }
                cfg.trace = Some(dir.into());
            }
        }

        if let Some(sec) = doc.section("workload") {
            let kind = sec
                .get("kind")
                .and_then(|v| v.as_str())
                .unwrap_or("linreg");
            cfg.workload = match kind {
                "linreg" => WorkloadSpec::LinReg {
                    m: sec.get("m").and_then(|v| v.as_int()).unwrap_or(2000)
                        as usize,
                    d: sec.get("d").and_then(|v| v.as_int()).unwrap_or(100)
                        as usize,
                },
                "transformer" => WorkloadSpec::Transformer {
                    tag: sec
                        .get("tag")
                        .and_then(|v| v.as_str())
                        .unwrap_or("tiny")
                        .into(),
                },
                other => return Err(format!("unknown workload.kind '{other}'")),
            };
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check cross-field constraints.
    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("n must be >= 1".into());
        }
        if self.eta <= 0.0 {
            return Err("eta must be positive".into());
        }
        if let WorkloadSpec::LinReg { m, d } = self.workload {
            if m == 0 || d == 0 {
                return Err("m and d must be positive".into());
            }
            if m % self.n != 0 {
                return Err(format!(
                    "n={} must divide m={m} (horizontal partition)",
                    self.n
                ));
            }
        }
        if let PolicySpec::Fixed { k } = self.policy {
            if k == 0 || k > self.n {
                return Err(format!("fixed k={k} must be in 1..={}", self.n));
            }
        }
        if let PolicySpec::Adaptive(p) = &self.policy {
            if p.k0 == 0 || p.k0 > self.n {
                return Err(format!("k0={} must be in 1..={}", p.k0, self.n));
            }
            if p.k_max > self.n {
                return Err(format!(
                    "k_max={} must be <= n={}",
                    p.k_max, self.n
                ));
            }
        }
        self.comm.validate(self.n)?;
        if let Some(coding) = &self.coding {
            if self.policy == PolicySpec::Async {
                return Err(
                    "coded gather runs in rounds; [policy] kind = \
                     \"async\" cannot be combined with [coding]"
                        .into(),
                );
            }
            coding.validate(self.n)?;
        }
        if self.fastpath {
            // The fast path samples the merged first-k order statistics
            // of the per-class response-time distributions directly,
            // which is only the round time when (a) rounds are
            // synchronous, (b) each delay/link class is i.i.d. with a
            // closed-form sampler, and (c) every comm cost decomposes
            // into per-class constants plus the shared O(k) FIFO ingress
            // chain. Each remaining incompatibility gets its own error
            // naming the knob to change.
            if self.policy == PolicySpec::Async {
                return Err(
                    "run.fastpath samples synchronous fastest-k rounds; \
                     [policy] kind = \"async\" cannot use it"
                        .into(),
                );
            }
            if self.coding.is_some() {
                return Err(
                    "run.fastpath samples the fastest-k arrivals \
                     directly; it cannot be combined with [coding]"
                        .into(),
                );
            }
            match self.delays {
                DelaySpec::Exponential { .. }
                | DelaySpec::ShiftedExponential { .. }
                | DelaySpec::Pareto { .. }
                | DelaySpec::Weibull { .. } => {}
                DelaySpec::Bimodal { p_transient, .. } => {
                    // A fixed slow group is two homogeneous classes; a
                    // *transient* straggler is a per-draw mixture no
                    // class partition captures.
                    if p_transient > 0.0 {
                        return Err(format!(
                            "run.fastpath supports bimodal delays only \
                             with a fixed slow group; \
                             delays.p_transient={p_transient} makes \
                             straggling a per-draw mixture — set \
                             p_transient = 0"
                        ));
                    }
                }
                DelaySpec::Trace { .. } => {
                    return Err(
                        "run.fastpath needs a closed-form per-class \
                         delay model (exponential, shifted_exponential, \
                         pareto, weibull, bimodal with p_transient = 0); \
                         trace delays are per-worker sequences"
                            .into(),
                    );
                }
            }
            // Comm gates, one per unsupported feature. Uniform(-with-
            // slow-class) uplinks, any compression scheme without error
            // feedback, priced uniform downlinks, and finite FIFO
            // ingress are all supported.
            if self.comm.error_feedback
                && !matches!(self.comm.scheme, CompressorSpec::Dense)
            {
                return Err(format!(
                    "run.fastpath cannot carry error feedback: residuals \
                     are per-worker O(n) state, but only k of n workers \
                     materialize per round; set comm.error_feedback = \
                     false (comm.scheme = {:?} stays lossy-compressed)",
                    self.comm.scheme
                ));
            }
            if self.comm.ingress == IngressDiscipline::Ps
                && self.comm.ingress_bw > 0.0
            {
                return Err(
                    "run.fastpath prices ingress with the O(k) FIFO \
                     completion chain; processor sharing has no \
                     closed-form prefix completion — set comm.ingress = \
                     \"fifo\""
                        .into(),
                );
            }
            if !self.comm.down_bandwidths.is_empty() {
                return Err(
                    "run.fastpath needs a uniform downlink (one download \
                     constant shifts every merged arrival); per-worker \
                     comm.down_bandwidths break the constant-shift \
                     composition — use comm.down_bandwidth"
                        .into(),
                );
            }
            if self.trace.is_some() {
                return Err(
                    "run.fastpath never materializes per-worker delay \
                     draws, so it cannot record an event trace; drop \
                     [trace] / --trace"
                        .into(),
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_fig2() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.n, 50);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn full_toml_round_trip() {
        let text = r#"
label = "custom"
n = 25
eta = 0.001
seed = 9

[delays]
kind = "pareto"
xm = 0.5
alpha = 2.2

[policy]
kind = "adaptive"
k0 = 5
step = 5
thresh = 8
burnin = 100
k_max = 20

[workload]
kind = "linreg"
m = 1000
d = 50
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.label, "custom");
        assert_eq!(cfg.n, 25);
        assert_eq!(cfg.delays, DelaySpec::Pareto { xm: 0.5, alpha: 2.2 });
        match &cfg.policy {
            PolicySpec::Adaptive(p) => {
                assert_eq!(p.k0, 5);
                assert_eq!(p.k_max, 20);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut cfg = ExperimentConfig::default();
        cfg.n = 7; // 7 does not divide 2000
        assert!(cfg.validate().is_err());

        let text = "n = 10\n[policy]\nkind = \"fixed\"\nk = 20\n";
        assert!(ExperimentConfig::from_toml(text).is_err());

        assert!(ExperimentConfig::from_toml("[delays]\nkind = \"nope\"\n")
            .is_err());
    }

    #[test]
    fn fastpath_parses_and_gates_incompatible_configs() {
        let text = "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\n\
                    d = 10\n[run]\nfastpath = true\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert!(cfg.fastpath);
        assert!(cfg.validate().is_ok());
        assert!(!ExperimentConfig::default().fastpath, "opt-in only");

        let mut bad = cfg.clone();
        bad.policy = PolicySpec::Async;
        assert!(bad.validate().unwrap_err().contains("async"));

        let mut bad = cfg.clone();
        bad.coding =
            Some(CodingSpec { scheme: CodingSchemeSpec::Cyclic, r: 2 });
        assert!(bad.validate().unwrap_err().contains("coding"));

        // A fixed bimodal slow group is two homogeneous classes — now
        // supported; a transient mixture is not, and the error says
        // which knob to change.
        let mut ok = cfg.clone();
        ok.delays = DelaySpec::Bimodal {
            lambda: 1.0,
            n_slow: 1,
            slow_factor: 10.0,
            p_transient: 0.0,
        };
        assert!(ok.validate().is_ok());
        let mut bad = ok.clone();
        bad.delays = DelaySpec::Bimodal {
            lambda: 1.0,
            n_slow: 1,
            slow_factor: 10.0,
            p_transient: 0.1,
        };
        assert!(bad.validate().unwrap_err().contains("p_transient"));

        // Priced uniform uplinks (with or without a slow link class),
        // compression without error feedback, priced uniform downlinks,
        // and finite FIFO ingress are all supported now.
        let mut ok = cfg.clone();
        ok.comm.bandwidth = 100.0;
        ok.comm.latency = 0.1;
        ok.comm.slow_workers = 3;
        ok.comm.slow_factor = 8.0;
        ok.comm.scheme = CompressorSpec::TopK { frac: 0.3 };
        ok.comm.error_feedback = false;
        ok.comm.down_bandwidth = 200.0;
        ok.comm.ingress_bw = 400.0;
        assert!(ok.validate().is_ok());

        let mut bad = cfg.clone();
        bad.trace = Some("results/traces".into());
        assert!(bad.validate().unwrap_err().contains("trace"));

        assert!(ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n\
             [run]\nfastpath = 1\n"
        )
        .unwrap_err()
        .contains("boolean"));
    }

    /// Base fastpath config the per-feature gate tests mutate.
    fn fastpath_cfg() -> ExperimentConfig {
        let text = "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\n\
                    d = 10\n[run]\nfastpath = true\n";
        ExperimentConfig::from_toml(text).unwrap()
    }

    #[test]
    fn fastpath_gate_error_feedback_names_the_knob() {
        let mut bad = fastpath_cfg();
        bad.comm.scheme = CompressorSpec::TopK { frac: 0.5 };
        bad.comm.error_feedback = true;
        let err = bad.validate().unwrap_err();
        assert!(err.contains("error feedback"), "{err}");
        assert!(err.contains("error_feedback = false"), "{err}");
        // Dense + error_feedback=true is the (inert) default: no
        // residuals are ever built, so the gate must not fire.
        let mut ok = fastpath_cfg();
        ok.comm.error_feedback = true;
        assert!(ok.validate().is_ok());
        // And dropping EF makes the lossy scheme legal.
        let mut ok = fastpath_cfg();
        ok.comm.scheme = CompressorSpec::TopK { frac: 0.5 };
        ok.comm.error_feedback = false;
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn fastpath_gate_ps_ingress_names_the_knob() {
        let mut bad = fastpath_cfg();
        bad.comm.ingress_bw = 100.0;
        bad.comm.ingress = IngressDiscipline::Ps;
        let err = bad.validate().unwrap_err();
        assert!(err.contains("fifo"), "{err}");
        // An unlimited PS ingress is the independent-upload model, so
        // it stays legal; finite FIFO is the supported contention case.
        let mut ok = fastpath_cfg();
        ok.comm.ingress = IngressDiscipline::Ps;
        assert!(ok.validate().is_ok());
        let mut ok = fastpath_cfg();
        ok.comm.ingress_bw = 100.0;
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn fastpath_gate_heterogeneous_downlinks_names_the_knob() {
        let mut bad = fastpath_cfg();
        bad.comm.down_bandwidths = vec![100.0; 10];
        let err = bad.validate().unwrap_err();
        assert!(err.contains("down_bandwidths"), "{err}");
        assert!(err.contains("down_bandwidth"), "{err}");
        // The uniform downlink (even compressed) is supported.
        let mut ok = fastpath_cfg();
        ok.comm.down_bandwidth = 100.0;
        ok.comm.downlink = CompressorSpec::Qsgd { levels: 8 };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn fastpath_gate_transient_bimodal_names_the_knob() {
        let mut bad = fastpath_cfg();
        bad.delays = DelaySpec::Bimodal {
            lambda: 1.0,
            n_slow: 2,
            slow_factor: 5.0,
            p_transient: 0.05,
        };
        let err = bad.validate().unwrap_err();
        assert!(err.contains("p_transient = 0"), "{err}");
    }

    #[test]
    fn fastpath_gate_trace_delays_names_the_model() {
        let mut bad = fastpath_cfg();
        bad.delays = DelaySpec::Trace { path: "delays.csv".into() };
        let err = bad.validate().unwrap_err();
        assert!(err.contains("trace delays"), "{err}");
    }

    #[test]
    fn slow_link_class_parses_builds_and_validates() {
        let text = r#"
n = 10

[workload]
kind = "linreg"
m = 200
d = 10

[comm]
bandwidth = 100.0
slow_workers = 3
slow_factor = 10.0
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.comm.slow_workers, 3);
        assert_eq!(cfg.comm.slow_factor, 10.0);
        let channel = cfg.comm.build(cfg.n);
        let msg = channel.message_bytes(10);
        // The last slow_workers ids pay slow_factor x the transfer time.
        let fast = channel.link_upload_delay(0, msg);
        let slow = channel.link_upload_delay(9, msg);
        assert!((slow - 10.0 * fast).abs() < 1e-12, "{fast} vs {slow}");
        assert_eq!(
            channel.link_upload_delay(6, msg).to_bits(),
            fast.to_bits()
        );

        // slow_workers needs a finite positive bandwidth...
        let mut bad = cfg.clone();
        bad.comm.bandwidth = 0.0;
        assert!(bad.validate().unwrap_err().contains("slow_workers"));
        // ...must not exceed n...
        let mut bad = cfg.clone();
        bad.comm.slow_workers = 11;
        assert!(bad.validate().unwrap_err().contains("exceeds"));
        // ...and the factor must be a finite >= 1.
        let mut bad = cfg.clone();
        bad.comm.slow_factor = 0.5;
        assert!(bad.validate().unwrap_err().contains("slow_factor"));
        let mut bad = cfg.clone();
        bad.comm.slow_factor = f64::NAN;
        assert!(bad.validate().unwrap_err().contains("slow_factor"));
        // Negative counts are a parse error, not a wrap-around.
        assert!(ExperimentConfig::from_toml(
            "[comm]\nslow_workers = -1\n"
        )
        .unwrap_err()
        .contains("slow_workers"));
    }

    #[test]
    fn delay_spec_builds_models() {
        let spec = DelaySpec::Exponential { lambda: 2.0 };
        let model = spec.build().unwrap();
        assert!(model.name().contains("exp"));
    }

    #[test]
    fn comm_section_parses_and_builds() {
        let text = r#"
n = 10

[workload]
kind = "linreg"
m = 200
d = 10

[comm]
kind = "topk"
frac = 0.25
bandwidth = 500.0
latency = 0.05
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(
            cfg.comm,
            CommSpec {
                scheme: CompressorSpec::TopK { frac: 0.25 },
                error_feedback: true,
                bandwidth: 500.0,
                latency: 0.05,
                ..Default::default()
            }
        );
        let channel = cfg.comm.build(cfg.n);
        assert_eq!(channel.n(), 10);
        assert!(channel.error_feedback_enabled());
        assert!(!channel.link_is_zero_cost());
        // 25% of d=10 -> 3 (index, value) pairs + 16-byte header.
        assert_eq!(channel.message_bytes(10), 16 + 3 * 8);
    }

    #[test]
    fn comm_defaults_to_dense_free_link() {
        let cfg = ExperimentConfig::from_toml("n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n").unwrap();
        assert_eq!(cfg.comm, CommSpec::default());
        let channel = cfg.comm.build(cfg.n);
        assert!(channel.link_is_zero_cost());
        assert!(!channel.error_feedback_enabled());
        assert_eq!(channel.name(), "dense");
    }

    #[test]
    fn comm_validation_rejects_bad_params() {
        let mut cfg = ExperimentConfig::default();
        cfg.comm.scheme = CompressorSpec::TopK { frac: 0.0 };
        assert!(cfg.validate().is_err());
        cfg.comm.scheme = CompressorSpec::TopK { frac: 1.5 };
        assert!(cfg.validate().is_err());
        cfg.comm.scheme = CompressorSpec::Qsgd { levels: 0 };
        assert!(cfg.validate().is_err());
        cfg.comm.scheme = CompressorSpec::Dense;
        cfg.comm.bandwidth = -1.0;
        assert!(cfg.validate().is_err());
        assert!(ExperimentConfig::from_toml("[comm]\nkind = \"zip\"\n")
            .is_err());
        // Negative levels must be rejected, not wrapped through `as u32`.
        assert!(ExperimentConfig::from_toml(
            "[comm]\nkind = \"qsgd\"\nlevels = -1\n"
        )
        .is_err());
        // NaN/inf link parameters must fail validation, not panic later.
        let mut cfg = ExperimentConfig::default();
        cfg.comm.latency = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.comm.latency = 0.0;
        cfg.comm.bandwidth = f64::INFINITY;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn downlink_and_ingress_parse_and_build() {
        let text = r#"
n = 10

[workload]
kind = "linreg"
m = 200
d = 10

[comm]
kind = "dense"
downlink = "qsgd"
down_levels = 8
down_bandwidth = 400.0
down_latency = 0.02
ingress_bw = 1000.0
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.comm.downlink, CompressorSpec::Qsgd { levels: 8 });
        assert_eq!(cfg.comm.down_bandwidth, 400.0);
        assert_eq!(cfg.comm.down_latency, 0.02);
        assert_eq!(cfg.comm.ingress_bw, 1000.0);
        let channel = cfg.comm.build(cfg.n);
        assert!(!channel.downlink_is_free());
        assert!(!channel.ingress().is_unlimited());
        assert!(channel.name().contains("down:delta-qsgd"));
        assert!(channel.name().contains("ingress"));
    }

    #[test]
    fn downlink_and_ingress_default_to_free() {
        let cfg = ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n",
        )
        .unwrap();
        assert_eq!(cfg.comm.downlink, CompressorSpec::Dense);
        let channel = cfg.comm.build(cfg.n);
        assert!(channel.downlink_is_free());
        assert!(channel.ingress().is_unlimited());
        assert_eq!(channel.name(), "dense");
    }

    #[test]
    fn downlink_and_ingress_validation_rejects_bad_params() {
        let mut cfg = ExperimentConfig::default();
        cfg.comm.ingress_bw = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.comm.ingress_bw = f64::INFINITY;
        assert!(cfg.validate().is_err());
        cfg.comm.ingress_bw = 0.0;
        cfg.comm.down_bandwidth = -2.0;
        assert!(cfg.validate().is_err());
        cfg.comm.down_bandwidth = 0.0;
        cfg.comm.down_latency = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.comm.down_latency = 0.0;
        cfg.comm.downlink = CompressorSpec::TopK { frac: 2.0 };
        assert!(cfg.validate().is_err());
        assert!(ExperimentConfig::from_toml(
            "[comm]\ndownlink = \"zip\"\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[comm]\ndownlink = \"qsgd\"\ndown_levels = -1\n"
        )
        .is_err());
    }

    #[test]
    fn ingress_discipline_parses_and_builds() {
        let text = r#"
n = 10
[workload]
kind = "linreg"
m = 200
d = 10
[comm]
ingress_bw = 500.0
ingress = "ps"
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.comm.ingress, IngressDiscipline::Ps);
        let channel = cfg.comm.build(cfg.n);
        assert_eq!(
            channel.ingress().discipline(),
            IngressDiscipline::Ps
        );
        assert!(channel.name().contains("ps"));
        // Default is FIFO; junk is rejected.
        let dflt = ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n",
        )
        .unwrap();
        assert_eq!(dflt.comm.ingress, IngressDiscipline::Fifo);
        assert!(ExperimentConfig::from_toml(
            "[comm]\ningress = \"roundrobin\"\n"
        )
        .is_err());
        assert!(
            ExperimentConfig::from_toml("[comm]\ningress = 3\n").is_err()
        );
    }

    #[test]
    fn per_worker_downlinks_parse_validate_and_build() {
        let text = r#"
n = 4
[workload]
kind = "linreg"
m = 200
d = 10
[comm]
down_bandwidths = [100.0, 200, 0, 50.0]
down_latency = 0.5
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(
            cfg.comm.down_bandwidths,
            vec![100.0, 200.0, 0.0, 50.0]
        );
        let channel = cfg.comm.build(cfg.n);
        assert!(!channel.downlink_is_free());
        // Worker 1's downlink is twice worker 0's bandwidth; worker 2's
        // 0 means infinite (latency only).
        let b = 1000u64;
        let d0 = channel.download_delay(0, b);
        let d1 = channel.download_delay(1, b);
        let d2 = channel.download_delay(2, b);
        let d3 = channel.download_delay(3, b);
        assert!((d0 - (0.5 + 10.0)).abs() < 1e-12);
        assert!((d1 - (0.5 + 5.0)).abs() < 1e-12);
        assert!((d2 - 0.5).abs() < 1e-12);
        assert!((d3 - (0.5 + 20.0)).abs() < 1e-12);

        // Wrong length fails validation against n.
        let mut bad = ExperimentConfig::default();
        bad.comm.down_bandwidths = vec![100.0, 200.0];
        assert!(bad.validate().unwrap_err().contains("down_bandwidths"));
        // NaN entries are rejected.
        let mut nan = ExperimentConfig::default();
        nan.comm.down_bandwidths = vec![f64::NAN; nan.n];
        assert!(nan.validate().is_err());
        // Non-array TOML is rejected.
        assert!(ExperimentConfig::from_toml(
            "[comm]\ndown_bandwidths = 7\n"
        )
        .is_err());
    }

    #[test]
    fn coding_section_parses_and_builds() {
        use crate::coding::CodingScheme;
        let text = r#"
n = 10
[workload]
kind = "linreg"
m = 200
d = 10
[policy]
kind = "fixed"
k = 9
[coding]
scheme = "cyclic"
r = 3
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        let coding = cfg.coding.clone().expect("coding parsed");
        assert_eq!(coding.scheme, CodingSchemeSpec::Cyclic);
        assert_eq!(coding.r, 3);
        let scheme = coding.build(cfg.n, cfg.seed).unwrap();
        assert_eq!(scheme.n(), 10);
        assert_eq!(scheme.recovery_threshold(), 8);
        assert_eq!(format!("{}", coding.scheme), "cyclic");
        // Scheme defaults to frc; r defaults to 2. (The TOML-subset
        // parser only materialises a section once it has a key, so the
        // minimal coding section is `r = 2`.)
        let dflt = ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n\
             [coding]\nr = 2\n",
        )
        .unwrap();
        assert_eq!(
            dflt.coding,
            Some(CodingSpec { scheme: CodingSchemeSpec::Frc, r: 2 })
        );
    }

    #[test]
    fn coding_frc_with_r_not_dividing_n_errs_at_parse_time() {
        // The r ∤ n case used to panic inside FrcScheme::new; it must
        // surface as an actionable config error instead.
        let text = "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\n\
                    d = 10\n[coding]\nscheme = \"frc\"\nr = 3\n";
        let err = ExperimentConfig::from_toml(text).unwrap_err();
        assert!(err.contains("divide"), "{err}");
        assert!(err.contains("cyclic"), "should point at the fix: {err}");
        // Out-of-range r and junk schemes are rejected too.
        assert!(ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n\
             [coding]\nr = 11\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml("[coding]\nr = 0\n").is_err());
        assert!(ExperimentConfig::from_toml(
            "[coding]\nscheme = \"mds\"\n"
        )
        .is_err());
        // Wrong-typed values must error, not silently default.
        assert!(
            ExperimentConfig::from_toml("[coding]\nscheme = 3\n").is_err()
        );
        assert!(
            ExperimentConfig::from_toml("[coding]\nr = 2.5\n").is_err()
        );
    }

    #[test]
    fn coding_cannot_combine_with_the_async_policy() {
        let text = "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\n\
                    d = 10\n[policy]\nkind = \"async\"\n[coding]\nr = 2\n";
        let err = ExperimentConfig::from_toml(text).unwrap_err();
        assert!(err.contains("async"), "{err}");
    }

    #[test]
    fn run_jobs_parses_defaults_and_rejects_negatives() {
        // Default: 0 = available parallelism (results are identical for
        // every jobs value, so the fast default is safe).
        let dflt = ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n",
        )
        .unwrap();
        assert_eq!(dflt.jobs, 0);
        let cfg = ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n\
             [run]\njobs = 4\n",
        )
        .unwrap();
        assert_eq!(cfg.jobs, 4);
        let err =
            ExperimentConfig::from_toml("[run]\njobs = -1\n").unwrap_err();
        assert!(err.contains(">= 0"), "{err}");
        assert!(
            ExperimentConfig::from_toml("[run]\njobs = \"all\"\n").is_err()
        );
    }

    #[test]
    fn run_intra_jobs_parses_defaults_and_rejects_negatives() {
        // Default 1 = strictly serial, exactly the pre-intra behavior.
        let dflt = ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n",
        )
        .unwrap();
        assert_eq!(dflt.intra_jobs, 1);
        let cfg = ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n\
             [run]\njobs = 2\nintra_jobs = 4\n",
        )
        .unwrap();
        assert_eq!(cfg.intra_jobs, 4);
        assert_eq!(cfg.jobs, 2);
        // 0 = available parallelism, mirroring the jobs convention.
        let all = ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n\
             [run]\nintra_jobs = 0\n",
        )
        .unwrap();
        assert_eq!(all.intra_jobs, 0);
        let err = ExperimentConfig::from_toml("[run]\nintra_jobs = -2\n")
            .unwrap_err();
        assert!(err.contains(">= 0"), "{err}");
        assert!(ExperimentConfig::from_toml(
            "[run]\nintra_jobs = \"all\"\n"
        )
        .is_err());
    }

    #[test]
    fn trace_section_parses_and_defaults_off() {
        // Off by default — existing configs keep byte-identical outputs.
        let dflt = ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n",
        )
        .unwrap();
        assert_eq!(dflt.trace, None);
        let cfg = ExperimentConfig::from_toml(
            "n = 10\n[workload]\nkind = \"linreg\"\nm = 200\nd = 10\n\
             [trace]\ndir = \"traces/out\"\n",
        )
        .unwrap();
        assert_eq!(cfg.trace.as_deref(), Some("traces/out"));
        assert!(
            ExperimentConfig::from_toml("[trace]\ndir = \"\"\n").is_err()
        );
        assert!(ExperimentConfig::from_toml("[trace]\ndir = 3\n").is_err());
    }

    #[test]
    fn comm_error_feedback_can_be_disabled() {
        let text = "[comm]\nkind = \"qsgd\"\nlevels = 8\nerror_feedback = false\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.comm.scheme, CompressorSpec::Qsgd { levels: 8 });
        assert!(!cfg.comm.error_feedback);
        assert!(!cfg.comm.build(cfg.n).error_feedback_enabled());
    }
}
