//! TOML-subset parser for experiment configs.
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / bool / homogeneous primitive arrays, `#` comments. Enough for
//! `examples/*.toml` experiment files; anything else is a parse error.

use std::collections::BTreeMap;

/// A TOML-subset scalar/array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous array of scalars.
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As i64 (ints only).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As f64 (ints coerce).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array.
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: `section -> key -> value`. Top-level keys live under
/// the empty-string section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or(format!(
                    "line {}: unterminated section header",
                    lineno + 1
                ))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or(format!(
                "line {}: expected 'key = value'",
                lineno + 1
            ))?;
            let value = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(doc)
    }

    /// Look up `section.key` (empty section = top level).
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// All keys of a section.
    pub fn section(&self, name: &str) -> Option<&BTreeMap<String, TomlValue>> {
        self.sections.get(name)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(tok: &str) -> Result<TomlValue, String> {
    if tok.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = tok.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = tok.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(Vec::new()));
        }
        let items: Result<Vec<TomlValue>, String> =
            inner.split(',').map(|t| parse_value(t.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    match tok {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !tok.contains(['.', 'e', 'E']) {
        if let Ok(i) = tok.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    tok.parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| format!("cannot parse value '{tok}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let text = r#"
# experiment
seed = 42
label = "fig2"   # inline comment

[workload]
m = 2000
eta = 0.0005
adaptive = true
ks = [10, 20, 30, 40]
"#;
        let doc = TomlDoc::parse(text).unwrap();
        assert_eq!(doc.get("", "seed").unwrap().as_int(), Some(42));
        assert_eq!(doc.get("", "label").unwrap().as_str(), Some("fig2"));
        assert_eq!(doc.get("workload", "m").unwrap().as_int(), Some(2000));
        assert_eq!(
            doc.get("workload", "eta").unwrap().as_float(),
            Some(0.0005)
        );
        assert_eq!(doc.get("workload", "adaptive").unwrap().as_bool(), Some(true));
        let ks = doc.get("workload", "ks").unwrap().as_arr().unwrap();
        assert_eq!(ks.len(), 4);
        assert_eq!(ks[3].as_int(), Some(40));
    }

    #[test]
    fn int_coerces_to_float() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float(), Some(3.0));
    }

    #[test]
    fn errors() {
        assert!(TomlDoc::parse("x").is_err());
        assert!(TomlDoc::parse("x = ").is_err());
        assert!(TomlDoc::parse("x = \"unterminated").is_err());
        assert!(TomlDoc::parse("[sec").is_err());
        assert!(TomlDoc::parse("x = [1, 2").is_err());
    }

    #[test]
    fn comment_inside_string_preserved() {
        let doc = TomlDoc::parse("x = \"a # b\"").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_str(), Some("a # b"));
    }
}
