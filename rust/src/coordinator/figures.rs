//! Paper-figure reproduction entrypoints.
//!
//! Each function regenerates one figure's data series; the CLI prints an
//! ASCII rendering + summary table and writes a CSV under `results/`. The
//! *shape* comparisons the paper makes (who wins, by what factor, where
//! curves cross) are asserted in `rust/tests/test_figures.rs`.
//!
//! All figure sweeps execute through [`crate::sweep::SweepExecutor`]:
//! the `*_jobs` variants fan the runs out over a thread pool (`jobs = 0`
//! ⇒ all cores) and are byte-identical to the single-threaded wrappers —
//! every run's RNG streams derive from its own spec, so the worker count
//! never reaches the results.

use crate::config::{DelaySpec, ExperimentConfig, PolicySpec, WorkloadSpec};
use crate::metrics::{Recorder, Sample};
use crate::policy::PflugParams;
use crate::stats::OrderStats;
use crate::sweep::{edit, SweepExecutor, SweepGrid};
use crate::theory::{adaptive_envelope, switching_times, BoundParams, ErrorBound};
use std::sync::Arc;

/// Output of a simulation figure: labelled series.
pub struct FigureOutput {
    /// Figure id ("fig2" …).
    pub name: String,
    /// All series.
    pub runs: Vec<Recorder>,
    /// Human-readable summary lines.
    pub summary: Vec<String>,
}

/// Output of Fig. 1 (theory curves, not simulations).
pub struct Fig1Output {
    /// Fixed-k bound curves, k = 1..=n.
    pub fixed: Vec<Recorder>,
    /// The adaptive (Theorem 1) envelope.
    pub adaptive: Recorder,
    /// The switching times t_1..t_{n-1}.
    pub switch_times: Vec<f64>,
    /// Summary lines.
    pub summary: Vec<String>,
}

/// Fig. 1 / Example 1 — Lemma-1 bound for k = 1..5 plus the Theorem-1
/// adaptive envelope (n = 5, X ~ exp(5), η = 0.001, σ² = 10,
/// F(w₀)−F* = 100, L = 2, c = 1, s = 10).
pub fn fig1(points: usize) -> Fig1Output {
    fig1_jobs(points, 1)
}

/// [`fig1`] with the per-k bound curves evaluated in parallel
/// (`jobs = 0` ⇒ all cores).
pub fn fig1_jobs(points: usize, jobs: usize) -> Fig1Output {
    assert!(points >= 2, "fig1 needs at least two grid points");
    let n = 5;
    let bound = Arc::new(ErrorBound::new(
        BoundParams::example1(),
        OrderStats::exponential(n, 5.0),
    ));
    // Horizon: late enough that the k=5 floor is reached (cf. paper x-axis).
    let t_max = 14_000.0;
    let ts: Arc<Vec<f64>> = Arc::new(
        (0..points).map(|i| t_max * i as f64 / (points - 1) as f64).collect(),
    );

    // One independent theory evaluation per k, order-reassembled by the
    // executor (a pure function of k — the jobs-invariance contract).
    let fixed = {
        let bound = Arc::clone(&bound);
        let ts = Arc::clone(&ts);
        SweepExecutor::new(jobs).map(n, move |ki| {
            let k = ki + 1;
            let mut rec = Recorder::new(format!("bound k={k}"));
            for (i, &t) in ts.iter().enumerate() {
                rec.push_forced(Sample {
                    iteration: i as u64,
                    time: t,
                    k,
                    error: bound.eval(k, t),
                    ..Default::default()
                });
            }
            rec
        })
    };

    let env = adaptive_envelope(&bound, &ts);
    let mut adaptive = Recorder::new("adaptive (Theorem 1)");
    for (i, (&t, &e)) in ts.iter().zip(&env).enumerate() {
        adaptive.push_forced(Sample {
            iteration: i as u64,
            time: t,
            k: 0,
            error: e,
            ..Default::default()
        });
    }

    let switches = switching_times(&bound);
    let switch_times: Vec<f64> = switches.iter().map(|s| s.time).collect();
    let mut summary = vec![format!(
        "Theorem-1 switching times: {}",
        switch_times
            .iter()
            .enumerate()
            .map(|(i, t)| format!("t_{} = {:.1}", i + 1, t))
            .collect::<Vec<_>>()
            .join(", ")
    )];
    for k in 1..=n {
        summary.push(format!(
            "k={k}: floor = {:.4e}, mu_k = {:.4}",
            bound.floor(k),
            bound.mu(k)
        ));
    }
    Fig1Output { fixed, adaptive, switch_times, summary }
}

fn fig2_base(seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        label: String::new(),
        n: 50,
        eta: 5e-4,
        max_iterations: 200_000,
        max_time: 6500.0,
        seed,
        record_stride: 25,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 10 },
        workload: WorkloadSpec::LinReg { m: 2000, d: 100 },
        comm: Default::default(),
        coding: None,
        jobs: 0,
        intra_jobs: 1,
        trace: None,
        fastpath: false,
    }
}

/// The Fig-2/Fig-3 adaptive policy (paper: start k0, step, thresh 10,
/// burnin 0.1·m = 200, cap k_max).
fn paper_adaptive(k0: usize, step: usize, k_max: usize) -> PolicySpec {
    PolicySpec::Adaptive(PflugParams {
        k0,
        step,
        thresh: 10,
        burnin: 200,
        k_max,
    })
}

/// Fig. 2 — adaptive fastest-k (k: 10→40 by 10, Algorithm 1) vs
/// non-adaptive fixed k ∈ {10, 20, 30, 40}; n = 50, η = 5e-4, exp(1).
pub fn fig2(seed: u64, max_time: f64) -> FigureOutput {
    fig2_jobs(seed, max_time, 1)
}

/// [`fig2`] with the five runs executed in parallel (`jobs = 0` ⇒ all
/// cores; byte-identical to `jobs = 1`).
pub fn fig2_jobs(seed: u64, max_time: f64, jobs: usize) -> FigureOutput {
    let mut base = fig2_base(seed);
    base.max_time = max_time;
    let mut policies: Vec<(String, crate::sweep::CfgEdit)> = [10usize, 20, 30, 40]
        .iter()
        .map(|&k| {
            (
                format!("fixed k={k}"),
                edit(move |c: &mut ExperimentConfig| {
                    c.policy = PolicySpec::Fixed { k }
                }),
            )
        })
        .collect();
    policies.push((
        "adaptive (Algorithm 1)".into(),
        edit(|c| c.policy = paper_adaptive(10, 10, 40)),
    ));
    let specs = SweepGrid::new(base).axis("policy", policies).build();
    let outs =
        SweepExecutor::new(jobs).run(&specs).expect("fig2 sweep runs");

    let mut runs = Vec::with_capacity(outs.len());
    let mut summary = Vec::with_capacity(outs.len());
    for (spec, out) in specs.iter().zip(outs) {
        match spec.cfg.policy {
            PolicySpec::Fixed { k } => summary.push(format!(
                "fixed k={k}: min error {:.4e} at t={:.0} ({} iters)",
                out.recorder.min_error().unwrap(),
                out.total_time,
                out.steps
            )),
            _ => summary.push(format!(
                "adaptive: min error {:.4e} at t={:.0}; switches at {}",
                out.recorder.min_error().unwrap(),
                out.total_time,
                out.k_changes
                    .iter()
                    .map(|(_, t, k)| format!("t={t:.0}→k={k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            )),
        }
        runs.push(out.recorder);
    }
    FigureOutput { name: "fig2".into(), runs, summary }
}

/// Fig. 3 — adaptive fastest-k (k: 1→36 by 5, Algorithm 1) vs fully
/// asynchronous SGD; η = 2e-4.
pub fn fig3(seed: u64, max_time: f64) -> FigureOutput {
    fig3_jobs(seed, max_time, 1)
}

/// [`fig3`] with both runs executed in parallel (`jobs = 0` ⇒ all
/// cores; byte-identical to `jobs = 1`).
pub fn fig3_jobs(seed: u64, max_time: f64, jobs: usize) -> FigureOutput {
    let mut base = fig2_base(seed);
    base.eta = 2e-4;
    base.max_time = max_time;
    let specs = SweepGrid::new(base)
        .axis(
            "driver",
            vec![
                (
                    "adaptive (Algorithm 1)".to_string(),
                    edit(|c| c.policy = paper_adaptive(1, 5, 36)),
                ),
                (
                    "async SGD".to_string(),
                    edit(|c| {
                        // Async applies ~n updates per sync-iteration-
                        // equivalent; give it the same *time* budget and
                        // an ample update cap.
                        c.policy = PolicySpec::Async;
                        c.max_iterations = 2_000_000;
                    }),
                ),
            ],
        )
        .build();
    let outs =
        SweepExecutor::new(jobs).run(&specs).expect("fig3 sweep runs");

    let mut runs = Vec::with_capacity(outs.len());
    let mut summary = Vec::with_capacity(outs.len());
    for (spec, out) in specs.iter().zip(outs) {
        if spec.cfg.policy == PolicySpec::Async {
            summary.push(format!(
                "async: min error {:.4e} after {} updates",
                out.recorder.min_error().unwrap(),
                out.steps
            ));
        } else {
            summary.push(format!(
                "adaptive: min error {:.4e}; switches: {}",
                out.recorder.min_error().unwrap(),
                out.k_changes.len()
            ));
        }
        runs.push(out.recorder);
    }
    FigureOutput { name: "fig3".into(), runs, summary }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_has_five_curves_and_envelope() {
        let out = fig1(200);
        assert_eq!(out.fixed.len(), 5);
        assert_eq!(out.switch_times.len(), 4);
        assert_eq!(out.adaptive.samples().len(), 200);
        // The envelope's final error must undercut every fixed k < 5.
        let env_end = out.adaptive.last().unwrap().error;
        for k in 0..4 {
            assert!(env_end <= out.fixed[k].last().unwrap().error + 1e-12);
        }
    }

    #[test]
    fn fig1_is_jobs_invariant() {
        let seq = fig1_jobs(60, 1);
        let par = fig1_jobs(60, 4);
        assert_eq!(seq.fixed.len(), par.fixed.len());
        for (a, b) in seq.fixed.iter().zip(&par.fixed) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.samples(), b.samples());
        }
        assert_eq!(seq.switch_times, par.switch_times);
    }
}
