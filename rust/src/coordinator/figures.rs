//! Paper-figure reproduction entrypoints.
//!
//! Each function regenerates one figure's data series; the CLI prints an
//! ASCII rendering + summary table and writes a CSV under `results/`. The
//! *shape* comparisons the paper makes (who wins, by what factor, where
//! curves cross) are asserted in `rust/tests/test_figures.rs`.

use crate::config::{DelaySpec, ExperimentConfig, PolicySpec, WorkloadSpec};
use crate::coordinator::run_experiment;
use crate::metrics::{Recorder, Sample};
use crate::policy::PflugParams;
use crate::stats::OrderStats;
use crate::theory::{adaptive_envelope, switching_times, BoundParams, ErrorBound};

/// Output of a simulation figure: labelled series.
pub struct FigureOutput {
    /// Figure id ("fig2" …).
    pub name: String,
    /// All series.
    pub runs: Vec<Recorder>,
    /// Human-readable summary lines.
    pub summary: Vec<String>,
}

/// Output of Fig. 1 (theory curves, not simulations).
pub struct Fig1Output {
    /// Fixed-k bound curves, k = 1..=n.
    pub fixed: Vec<Recorder>,
    /// The adaptive (Theorem 1) envelope.
    pub adaptive: Recorder,
    /// The switching times t_1..t_{n-1}.
    pub switch_times: Vec<f64>,
    /// Summary lines.
    pub summary: Vec<String>,
}

/// Fig. 1 / Example 1 — Lemma-1 bound for k = 1..5 plus the Theorem-1
/// adaptive envelope (n = 5, X ~ exp(5), η = 0.001, σ² = 10,
/// F(w₀)−F* = 100, L = 2, c = 1, s = 10).
pub fn fig1(points: usize) -> Fig1Output {
    let n = 5;
    let bound =
        ErrorBound::new(BoundParams::example1(), OrderStats::exponential(n, 5.0));
    // Horizon: late enough that the k=5 floor is reached (cf. paper x-axis).
    let t_max = 14_000.0;
    let ts: Vec<f64> =
        (0..points).map(|i| t_max * i as f64 / (points - 1) as f64).collect();

    let mut fixed = Vec::with_capacity(n);
    for k in 1..=n {
        let mut rec = Recorder::new(format!("bound k={k}"));
        for (i, &t) in ts.iter().enumerate() {
            rec.push_forced(Sample {
                iteration: i as u64,
                time: t,
                k,
                error: bound.eval(k, t),
                ..Default::default()
            });
        }
        fixed.push(rec);
    }

    let env = adaptive_envelope(&bound, &ts);
    let mut adaptive = Recorder::new("adaptive (Theorem 1)");
    for (i, (&t, &e)) in ts.iter().zip(&env).enumerate() {
        adaptive.push_forced(Sample {
            iteration: i as u64,
            time: t,
            k: 0,
            error: e,
            ..Default::default()
        });
    }

    let switches = switching_times(&bound);
    let switch_times: Vec<f64> = switches.iter().map(|s| s.time).collect();
    let mut summary = vec![format!(
        "Theorem-1 switching times: {}",
        switch_times
            .iter()
            .enumerate()
            .map(|(i, t)| format!("t_{} = {:.1}", i + 1, t))
            .collect::<Vec<_>>()
            .join(", ")
    )];
    for k in 1..=n {
        summary.push(format!(
            "k={k}: floor = {:.4e}, mu_k = {:.4}",
            bound.floor(k),
            bound.mu(k)
        ));
    }
    Fig1Output { fixed, adaptive, switch_times, summary }
}

fn fig2_base(seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        label: String::new(),
        n: 50,
        eta: 5e-4,
        max_iterations: 200_000,
        max_time: 6500.0,
        seed,
        record_stride: 25,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 10 },
        workload: WorkloadSpec::LinReg { m: 2000, d: 100 },
        comm: Default::default(),
        coding: None,
    }
}

/// Fig. 2 — adaptive fastest-k (k: 10→40 by 10, Algorithm 1) vs
/// non-adaptive fixed k ∈ {10, 20, 30, 40}; n = 50, η = 5e-4, exp(1).
pub fn fig2(seed: u64, max_time: f64) -> FigureOutput {
    let mut runs = Vec::new();
    let mut summary = Vec::new();

    for k in [10usize, 20, 30, 40] {
        let mut cfg = fig2_base(seed);
        cfg.label = format!("fixed k={k}");
        cfg.policy = PolicySpec::Fixed { k };
        cfg.max_time = max_time;
        let out = run_experiment(&cfg).expect("fig2 fixed run");
        summary.push(format!(
            "fixed k={k}: min error {:.4e} at t={:.0} ({} iters)",
            out.recorder.min_error().unwrap(),
            out.total_time,
            out.steps
        ));
        runs.push(out.recorder);
    }

    let mut cfg = fig2_base(seed);
    cfg.label = "adaptive (Algorithm 1)".into();
    // Paper: start k=10, step 10, thresh 10, burnin 0.1*m = 200, cap 40.
    cfg.policy = PolicySpec::Adaptive(PflugParams {
        k0: 10,
        step: 10,
        thresh: 10,
        burnin: 200,
        k_max: 40,
    });
    cfg.max_time = max_time;
    let out = run_experiment(&cfg).expect("fig2 adaptive run");
    summary.push(format!(
        "adaptive: min error {:.4e} at t={:.0}; switches at {}",
        out.recorder.min_error().unwrap(),
        out.total_time,
        out.k_changes
            .iter()
            .map(|(_, t, k)| format!("t={t:.0}→k={k}"))
            .collect::<Vec<_>>()
            .join(", ")
    ));
    runs.push(out.recorder);

    FigureOutput { name: "fig2".into(), runs, summary }
}

/// Fig. 3 — adaptive fastest-k (k: 1→36 by 5, Algorithm 1) vs fully
/// asynchronous SGD; η = 2e-4.
pub fn fig3(seed: u64, max_time: f64) -> FigureOutput {
    let mut runs = Vec::new();
    let mut summary = Vec::new();

    let mut cfg = fig2_base(seed);
    cfg.label = "adaptive (Algorithm 1)".into();
    cfg.eta = 2e-4;
    cfg.max_time = max_time;
    cfg.policy = PolicySpec::Adaptive(PflugParams {
        k0: 1,
        step: 5,
        thresh: 10,
        burnin: 200,
        k_max: 36,
    });
    let out = run_experiment(&cfg).expect("fig3 adaptive run");
    summary.push(format!(
        "adaptive: min error {:.4e}; switches: {}",
        out.recorder.min_error().unwrap(),
        out.k_changes.len()
    ));
    runs.push(out.recorder);

    let mut cfg = fig2_base(seed);
    cfg.label = "async SGD".into();
    cfg.eta = 2e-4;
    cfg.max_time = max_time;
    // Async applies ~n updates per sync-iteration-equivalent; give it the
    // same *time* budget and an ample update cap.
    cfg.max_iterations = 2_000_000;
    cfg.policy = PolicySpec::Async;
    let out = run_experiment(&cfg).expect("fig3 async run");
    summary.push(format!(
        "async: min error {:.4e} after {} updates",
        out.recorder.min_error().unwrap(),
        out.steps
    ));
    runs.push(out.recorder);

    FigureOutput { name: "fig3".into(), runs, summary }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_has_five_curves_and_envelope() {
        let out = fig1(200);
        assert_eq!(out.fixed.len(), 5);
        assert_eq!(out.switch_times.len(), 4);
        assert_eq!(out.adaptive.samples().len(), 200);
        // The envelope's final error must undercut every fixed k < 5.
        let env_end = out.adaptive.last().unwrap().error;
        for k in 0..4 {
            assert!(env_end <= out.fixed[k].last().unwrap().error + 1e-12);
        }
    }
}
