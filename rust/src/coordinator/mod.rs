//! Experiment coordination: config → run → metrics, plus the paper-figure
//! generators (`fig1`/`fig2`/`fig3`) shared by the CLI and the benches.

mod figures;
mod repeat;
mod runner;

pub use figures::{fig1, fig2, fig3, Fig1Output, FigureOutput};
pub use repeat::{run_repeated, AggregatedCurve};
pub use runner::{run_experiment, ExperimentOutput};
