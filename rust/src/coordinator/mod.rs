//! Experiment coordination: config → run → metrics, plus the paper-figure
//! generators (`fig1`/`fig2`/`fig3`) shared by the CLI and the benches.
//!
//! [`run_experiment`] executes exactly one config; everything multi-run
//! (figures, repeats, bench grids) goes through [`crate::sweep`], which
//! fans independent specs out over a thread pool without changing a
//! single output byte. The `*_jobs` variants expose the worker count
//! (`0` = all cores).

mod figures;
mod repeat;
mod runner;

pub use figures::{
    fig1, fig1_jobs, fig2, fig2_jobs, fig3, fig3_jobs, Fig1Output,
    FigureOutput,
};
pub use repeat::{run_repeated, run_repeated_jobs, AggregatedCurve};
pub(crate) use runner::reject_non_native;
pub use runner::{replay_experiment, run_experiment, ExperimentOutput};
