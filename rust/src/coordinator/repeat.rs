//! Multi-seed repetition runner: run an experiment R times and aggregate
//! the error curves onto a common time grid (mean ± std), so figure
//! comparisons are not single-draw artifacts. EXPERIMENTS.md reports the
//! aggregated numbers.
//!
//! Repetitions execute through [`crate::sweep::SweepExecutor`] with
//! `base.jobs` workers (0 = all cores). Each repetition is its own
//! [`RunSpec`] whose seed is pinned *before* execution (`seed0 + r`, the
//! documented `repeat` contract), and aggregation walks the collected
//! outputs in spec order — so the thread count never changes the curve.

use crate::config::ExperimentConfig;
use crate::metrics::Recorder;
use crate::stats::RunningStats;
use crate::sweep::{RunSpec, SweepExecutor};

/// Aggregated error-vs-time curve across repetitions.
#[derive(Debug, Clone, PartialEq)]
pub struct AggregatedCurve {
    /// Label.
    pub label: String,
    /// Common time grid.
    pub times: Vec<f64>,
    /// Mean error at each grid point.
    pub mean: Vec<f64>,
    /// Sample standard deviation at each grid point.
    pub std: Vec<f64>,
    /// Repetitions aggregated.
    pub reps: usize,
}

impl AggregatedCurve {
    /// Mean error at the last grid point.
    pub fn final_mean(&self) -> f64 {
        *self.mean.last().unwrap_or(&f64::NAN)
    }

    /// First grid time at which the mean error ≤ target.
    pub fn time_to_error(&self, target: f64) -> Option<f64> {
        self.times
            .iter()
            .zip(&self.mean)
            .find(|(_, &e)| e <= target)
            .map(|(&t, _)| t)
    }
}

/// Step-interpolate a recorder onto `grid` (last sample at or before t).
fn sample_on_grid(rec: &Recorder, grid: &[f64]) -> Vec<f64> {
    grid.iter()
        .map(|&t| rec.error_at(t).unwrap_or(f64::NAN))
        .collect()
}

/// Run `base` under seeds `seed0..seed0+reps`, aggregating onto `points`
/// uniform grid points over `[0, base.max_time]`. Parallelism comes from
/// `base.jobs` ([`run_repeated_jobs`] overrides it).
pub fn run_repeated(
    base: &ExperimentConfig,
    seed0: u64,
    reps: usize,
    points: usize,
) -> Result<AggregatedCurve, String> {
    run_repeated_jobs(base, seed0, reps, points, base.jobs)
}

/// [`run_repeated`] with an explicit worker count (0 = all cores). The
/// jobs value is pure wall-clock: the aggregate is bitwise identical for
/// every `jobs`.
pub fn run_repeated_jobs(
    base: &ExperimentConfig,
    seed0: u64,
    reps: usize,
    points: usize,
    jobs: usize,
) -> Result<AggregatedCurve, String> {
    assert!(reps >= 1 && points >= 2);
    assert!(
        base.max_time > 0.0,
        "run_repeated needs a max_time so curves share a horizon"
    );
    let specs: Vec<RunSpec> = (0..reps)
        .map(|r| {
            let mut cfg = base.clone();
            cfg.seed = seed0 + r as u64;
            RunSpec::from_config(r, cfg)
        })
        .collect();
    let outs = SweepExecutor::new(jobs).run(&specs)?;

    let grid: Vec<f64> = (0..points)
        .map(|i| base.max_time * (i + 1) as f64 / points as f64)
        .collect();
    let mut acc: Vec<RunningStats> =
        (0..points).map(|_| RunningStats::new()).collect();
    // Spec order, not completion order: Welford accumulation is not
    // permutation-invariant in floating point.
    for out in &outs {
        for (stats, v) in acc.iter_mut().zip(sample_on_grid(&out.recorder, &grid))
        {
            if v.is_finite() {
                stats.push(v);
            }
        }
    }
    Ok(AggregatedCurve {
        label: base.label.clone(),
        times: grid,
        mean: acc.iter().map(|s| s.mean()).collect(),
        std: acc.iter().map(|s| s.stddev()).collect(),
        reps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DelaySpec, PolicySpec, WorkloadSpec};

    fn base() -> ExperimentConfig {
        ExperimentConfig {
            label: "rep".into(),
            n: 10,
            eta: 1e-3,
            max_iterations: 10_000,
            max_time: 60.0,
            seed: 0,
            record_stride: 10,
            delays: DelaySpec::Exponential { lambda: 1.0 },
            policy: PolicySpec::Fixed { k: 5 },
            workload: WorkloadSpec::LinReg { m: 200, d: 10 },
            comm: Default::default(),
            coding: None,
            jobs: 0,
            intra_jobs: 1,
            trace: None,
            fastpath: false,
        }
    }

    #[test]
    fn aggregates_across_seeds() {
        let agg = run_repeated(&base(), 100, 4, 12).unwrap();
        assert_eq!(agg.reps, 4);
        assert_eq!(agg.times.len(), 12);
        // Error decreases along the grid on average.
        assert!(agg.mean[11] < agg.mean[0]);
        // With multiple seeds the late-time std is positive.
        assert!(agg.std[11] >= 0.0);
        assert!(agg.final_mean().is_finite());
    }

    #[test]
    fn time_to_error_on_mean_curve() {
        let agg = run_repeated(&base(), 200, 3, 20).unwrap();
        let mid = (agg.mean[0] * agg.final_mean()).sqrt(); // geometric mid
        let t = agg.time_to_error(mid).expect("mean curve must cross");
        assert!(t > 0.0 && t <= 60.0);
    }

    #[test]
    fn aggregate_is_jobs_invariant() {
        // The sweep layer's contract at the aggregation level: the
        // worker count must never reach the curve.
        let seq = run_repeated_jobs(&base(), 100, 4, 12, 1).unwrap();
        let par = run_repeated_jobs(&base(), 100, 4, 12, 4).unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    #[should_panic(expected = "max_time")]
    fn requires_time_horizon() {
        let mut cfg = base();
        cfg.max_time = 0.0;
        let _ = run_repeated(&cfg, 0, 2, 5);
    }
}
