//! Config-driven experiment execution.

use crate::async_sgd::{run_async_comm_traced, AsyncConfig};
use crate::coding::run_coded_comm_traced;
use crate::config::{ExperimentConfig, PolicySpec, WorkloadSpec};
use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
use crate::grad::NativeBackend;
use crate::master::{run_fastest_k_comm_traced, MasterConfig};
use crate::metrics::Recorder;
use crate::model::LinRegProblem;
use crate::policy::{AdaptivePflug, FixedK, KPolicy};
use crate::straggler::DelayModel;
use crate::trace::{sanitize_label, Discipline, ReplayDelays, Trace};

/// What an experiment run produces.
pub struct ExperimentOutput {
    /// The error-vs-time record.
    pub recorder: Recorder,
    /// Iterations / updates completed.
    pub steps: u64,
    /// Final virtual wall-clock.
    pub total_time: f64,
    /// k switch log (empty for fixed/async).
    pub k_changes: Vec<(u64, f64, usize)>,
    /// Encoded bytes of all accepted gradient messages.
    pub bytes_sent: u64,
    /// Total upload time of accepted messages.
    pub comm_time: f64,
    /// Encoded bytes of all model downloads.
    pub bytes_down: u64,
    /// Total download time charged.
    pub down_time: f64,
    /// Responses discarded by the gather (stale generations plus fresh
    /// responses outside the fastest-k; 0 for async, which applies all).
    pub late_responses: u64,
    /// Mean staleness of applied updates (0 for round disciplines).
    pub mean_staleness: f64,
    /// The recorded event trace when `cfg.trace` is set (already saved
    /// to disk by [`run_experiment`]; kept here for in-process use).
    pub trace: Option<Trace>,
}

/// Reject workloads this native-backend runner cannot execute. Shared
/// with the sweep executor's fail-fast pre-scan, so a grid aborts on
/// such a cell *before* the fan-out instead of after it.
pub(crate) fn reject_non_native(
    cfg: &ExperimentConfig,
) -> Result<(), String> {
    match cfg.workload {
        WorkloadSpec::LinReg { .. } => Ok(()),
        WorkloadSpec::Transformer { .. } => Err(
            "transformer workload requires the artifact runtime; use \
             `adasgd train-transformer` or examples/transformer_e2e"
                .into(),
        ),
    }
}

/// Run one experiment end-to-end on the native backend.
///
/// When `cfg.trace` names a directory, the run records a binary event
/// trace (see [`crate::trace`]) and saves it there as
/// `<sanitized-label>.trace`; the trajectory and every other output are
/// bit-identical with tracing on or off.
///
/// (The XLA-backend path is exercised by the examples and integration
/// tests; sweeps use the native backend so they don't require artifacts
/// for every shape.)
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<ExperimentOutput, String> {
    let out = run_experiment_core(cfg, cfg.trace.is_some(), None)?;
    if let (Some(dir), Some(trace)) = (&cfg.trace, &out.trace) {
        let path = std::path::Path::new(dir)
            .join(format!("{}.trace", sanitize_label(&cfg.label)));
        trace.save(&path).map_err(|e| {
            format!("failed to write trace {}: {e}", path.display())
        })?;
    }
    Ok(out)
}

/// Re-drive an experiment from a recorded event trace: the trace's raw
/// delay draws replace live sampling ([`ReplayDelays`]), so the replay
/// reproduces the recorded run's model trajectory, virtual clock, and
/// recorder samples *bitwise* — provided `cfg` matches the recording
/// (worker count and discipline are pre-validated here; the remaining
/// fields are the caller's contract, checked bitwise by `trace replay`).
pub fn replay_experiment(
    cfg: &ExperimentConfig,
    trace: &Trace,
) -> Result<ExperimentOutput, String> {
    if trace.n_workers as usize != cfg.n {
        return Err(format!(
            "trace was recorded with {} workers but the config has n = {}; \
             replay needs the exact recorded configuration",
            trace.n_workers, cfg.n
        ));
    }
    if cfg.fastpath {
        return Err(
            "run.fastpath never materializes per-worker delay draws, so \
             a trace cannot re-drive it; drop fastpath to replay"
                .into(),
        );
    }
    let expected = if cfg.coding.is_some() {
        Discipline::Coded
    } else if matches!(cfg.policy, PolicySpec::Async) {
        Discipline::Async
    } else {
        Discipline::Sync
    };
    if trace.discipline != expected {
        return Err(format!(
            "trace was recorded under the `{}` discipline but the config \
             runs `{}`; replay needs the exact recorded configuration",
            trace.discipline, expected
        ));
    }
    let replay = ReplayDelays::from_trace(trace)?;
    run_experiment_core(cfg, false, Some(&replay))
}

/// Shared body: validate, build the problem, dispatch on discipline.
/// `override_delays` (replay) substitutes for the config's delay model;
/// `trace_on` records an event trace into the output.
fn run_experiment_core(
    cfg: &ExperimentConfig,
    trace_on: bool,
    override_delays: Option<&dyn DelayModel>,
) -> Result<ExperimentOutput, String> {
    cfg.validate()?;
    reject_non_native(cfg)?;
    let (m, d) = match cfg.workload {
        WorkloadSpec::LinReg { m, d } => (m, d),
        WorkloadSpec::Transformer { .. } => {
            unreachable!("reject_non_native() ruled this out")
        }
    };
    let ds = SyntheticDataset::generate(
        SyntheticConfig { m, d, ..Default::default() },
        cfg.seed,
    );
    let problem = LinRegProblem::new(&ds);
    let mut backend = NativeBackend::new(Shards::partition(&ds, cfg.n));
    let built;
    let delays: &dyn DelayModel = match override_delays {
        Some(d) => d,
        None => {
            built = cfg.delays.build()?;
            built.as_ref()
        }
    };
    let mut channel = cfg.comm.build(cfg.n);
    let w0 = vec![0.0f32; d];

    // Gradient coding: the k policy adapts the wait target of the
    // engine's CodedGather discipline (validate() already rejected the
    // async policy for coded runs).
    if let Some(coding) = &cfg.coding {
        let scheme = coding.build(cfg.n, cfg.seed)?;
        let mut policy: Box<dyn KPolicy> = match &cfg.policy {
            PolicySpec::Fixed { k } => Box::new(FixedK::new(*k)),
            PolicySpec::Adaptive(p) => {
                Box::new(AdaptivePflug::new(cfg.n, *p))
            }
            PolicySpec::Async => unreachable!("validate() rejects this"),
        };
        let mcfg = MasterConfig {
            eta: cfg.eta as f32,
            momentum: 0.0,
            max_iterations: cfg.max_iterations,
            max_time: cfg.max_time,
            seed: cfg.seed,
            record_stride: cfg.record_stride,
            intra_jobs: cfg.intra_jobs,
        };
        let run = run_coded_comm_traced(
            &mut backend,
            delays,
            scheme.as_ref(),
            policy.as_mut(),
            &mut channel,
            &w0,
            &mcfg,
            &mut |w| problem.error(w),
            trace_on,
        );
        let mut recorder = run.recorder;
        recorder.label = cfg.label.clone();
        return Ok(ExperimentOutput {
            recorder,
            steps: run.iterations,
            total_time: run.total_time,
            k_changes: run.k_changes,
            bytes_sent: run.bytes_sent,
            comm_time: run.comm_time,
            bytes_down: run.bytes_down,
            down_time: run.down_time,
            late_responses: run.late_responses,
            mean_staleness: run.mean_staleness,
            trace: run.trace,
        });
    }

    // Opt-in O(k) fast path: the same synchronous fastest-k discipline
    // with arrivals sampled directly from the order-statistics law.
    // validate() pinned this to sync policies over closed-form delay
    // models whose per-worker response time decomposes into a class
    // delay law plus a per-worker-constant uplink (plus the shared
    // FIFO ingress chain and a uniform download constant). Workers are
    // partitioned into homogeneous (delay class × uplink constant)
    // classes and the merged first-k arrivals are drawn in
    // O(k · classes) per round. The dispatch lives here (not in
    // `master`) because only the coordinator may couple the config
    // surface to `stats` + `engine` at once.
    if cfg.fastpath {
        use crate::config::DelaySpec;
        use crate::engine::{
            EngineConfig, EngineCore, FastpathGather, RngStreams,
            RoundEngine,
        };
        use crate::stats::{ClassOrderSampler, OrderStatSampler};
        // The delay law has at most two classes: the bimodal family's
        // persistently slow group (validate() pinned p_transient = 0,
        // so slow draws are exactly Exp(λ / slow_factor)); every other
        // closed-form family is i.i.d.
        let delay_class = |w: usize| -> u32 {
            match cfg.delays {
                DelaySpec::Bimodal { n_slow, .. } if w < n_slow => 1,
                _ => 0,
            }
        };
        let sampler_for = |class: u32, len: usize| -> OrderStatSampler {
            match cfg.delays {
                DelaySpec::Exponential { lambda } => {
                    OrderStatSampler::exponential(len, lambda)
                }
                DelaySpec::ShiftedExponential { shift, lambda } => {
                    OrderStatSampler::shifted_exponential(len, shift, lambda)
                }
                DelaySpec::Pareto { xm, alpha } => {
                    OrderStatSampler::pareto(len, xm, alpha)
                }
                DelaySpec::Weibull { lambda, k } => {
                    OrderStatSampler::weibull(len, lambda, k)
                }
                DelaySpec::Bimodal { lambda, slow_factor, .. } => {
                    let rate = if class == 1 {
                        lambda / slow_factor
                    } else {
                        lambda
                    };
                    OrderStatSampler::exponential(len, rate)
                }
                DelaySpec::Trace { .. } => {
                    unreachable!("validate() rejects trace fastpath")
                }
            }
        };
        // Partition workers by (delay class, uplink constant), keeping
        // classes in first-appearance worker order so the grouping is
        // deterministic. The uplink constant keys on exact bits: any
        // numeric difference is a different class.
        let msg = channel.message_bytes(d);
        let mut keys: Vec<(u32, u64)> = Vec::new();
        let mut members: Vec<Vec<u32>> = Vec::new();
        for w in 0..cfg.n {
            let key =
                (delay_class(w), channel.link_upload_delay(w, msg).to_bits());
            match keys.iter().position(|k| *k == key) {
                Some(c) => members[c].push(w as u32),
                None => {
                    keys.push(key);
                    members.push(vec![w as u32]);
                }
            }
        }
        let classes: Vec<(OrderStatSampler, f64)> = keys
            .iter()
            .zip(&members)
            .map(|(&(dc, up), m)| {
                (sampler_for(dc, m.len()), f64::from_bits(up))
            })
            .collect();
        let sampler = ClassOrderSampler::new(classes);
        let mut policy: Box<dyn KPolicy> = match &cfg.policy {
            PolicySpec::Fixed { k } => Box::new(FixedK::new(*k)),
            PolicySpec::Adaptive(p) => {
                Box::new(AdaptivePflug::new(cfg.n, *p))
            }
            PolicySpec::Async => unreachable!("validate() rejects this"),
        };
        let engine_cfg = EngineConfig {
            eta: cfg.eta as f32,
            momentum: 0.0,
            max_steps: cfg.max_iterations,
            max_time: cfg.max_time,
            seed: cfg.seed,
            record_stride: cfg.record_stride,
            intra_jobs: cfg.intra_jobs,
        };
        let mut eval = |w: &[f32]| problem.error(w);
        let core = EngineCore::new(
            policy.name(),
            &mut channel,
            delays,
            &mut eval,
            &w0,
            engine_cfg,
            RngStreams::sync(cfg.seed),
        );
        let mut gather = FastpathGather::new(
            &mut backend,
            policy.as_mut(),
            sampler,
            members,
            cfg.seed,
        );
        let run = RoundEngine::new(core).run(&mut gather);
        let mut recorder = run.recorder;
        recorder.label = cfg.label.clone();
        return Ok(ExperimentOutput {
            recorder,
            steps: run.steps,
            total_time: run.total_time,
            k_changes: run.k_changes,
            bytes_sent: run.bytes_sent,
            comm_time: run.comm_time,
            bytes_down: run.bytes_down,
            down_time: run.down_time,
            late_responses: run.late_responses,
            mean_staleness: run.mean_staleness,
            trace: None,
        });
    }

    match &cfg.policy {
        PolicySpec::Async => {
            let acfg = AsyncConfig {
                eta: cfg.eta as f32,
                max_updates: cfg.max_iterations,
                max_time: cfg.max_time,
                seed: cfg.seed,
                record_stride: cfg.record_stride,
                intra_jobs: cfg.intra_jobs,
                ..Default::default()
            };
            let run = run_async_comm_traced(
                &mut backend,
                delays,
                &mut channel,
                &w0,
                &acfg,
                &mut |w| problem.error(w),
                trace_on,
            );
            let mut recorder = run.recorder;
            recorder.label = cfg.label.clone();
            Ok(ExperimentOutput {
                recorder,
                steps: run.updates,
                total_time: run.total_time,
                k_changes: Vec::new(),
                bytes_sent: run.bytes_sent,
                comm_time: run.comm_time,
                bytes_down: run.bytes_down,
                down_time: run.down_time,
                late_responses: run.late_responses,
                mean_staleness: run.mean_staleness,
                trace: run.trace,
            })
        }
        policy_spec => {
            let mut policy: Box<dyn KPolicy> = match policy_spec {
                PolicySpec::Fixed { k } => Box::new(FixedK::new(*k)),
                PolicySpec::Adaptive(p) => {
                    Box::new(AdaptivePflug::new(cfg.n, *p))
                }
                PolicySpec::Async => unreachable!(),
            };
            let mcfg = MasterConfig {
                eta: cfg.eta as f32,
                momentum: 0.0,
                max_iterations: cfg.max_iterations,
                max_time: cfg.max_time,
                seed: cfg.seed,
                record_stride: cfg.record_stride,
                intra_jobs: cfg.intra_jobs,
            };
            let run = run_fastest_k_comm_traced(
                &mut backend,
                delays,
                policy.as_mut(),
                &mut channel,
                &w0,
                &mcfg,
                &mut |w| problem.error(w),
                trace_on,
            );
            let mut recorder = run.recorder;
            recorder.label = cfg.label.clone();
            Ok(ExperimentOutput {
                recorder,
                steps: run.iterations,
                total_time: run.total_time,
                k_changes: run.k_changes,
                bytes_sent: run.bytes_sent,
                comm_time: run.comm_time,
                bytes_down: run.bytes_down,
                down_time: run.down_time,
                late_responses: run.late_responses,
                mean_staleness: run.mean_staleness,
                trace: run.trace,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DelaySpec;
    use crate::policy::PflugParams;

    fn base() -> ExperimentConfig {
        ExperimentConfig {
            label: "t".into(),
            n: 10,
            eta: 1e-3,
            max_iterations: 300,
            max_time: 0.0,
            seed: 3,
            record_stride: 50,
            delays: DelaySpec::Exponential { lambda: 1.0 },
            policy: PolicySpec::Fixed { k: 5 },
            workload: WorkloadSpec::LinReg { m: 200, d: 10 },
            comm: Default::default(),
            coding: None,
            jobs: 0,
            intra_jobs: 1,
            trace: None,
            fastpath: false,
        }
    }

    #[test]
    fn replay_rejects_mismatched_config() {
        let mut cfg = base();
        let trace = Trace::new(Discipline::Sync, 10, "t");
        // Wrong worker count.
        cfg.n = 4;
        assert!(replay_experiment(&cfg, &trace)
            .unwrap_err()
            .contains("workers"));
        // Wrong discipline.
        let mut cfg = base();
        cfg.policy = PolicySpec::Async;
        assert!(replay_experiment(&cfg, &trace)
            .unwrap_err()
            .contains("discipline"));
    }

    #[test]
    fn fixed_policy_runs() {
        let out = run_experiment(&base()).unwrap();
        assert_eq!(out.steps, 300);
        assert!(out.recorder.last().unwrap().error < out.recorder.samples()[0].error);
    }

    #[test]
    fn adaptive_policy_runs_and_switches_eventually() {
        let mut cfg = base();
        cfg.policy = PolicySpec::Adaptive(PflugParams {
            k0: 1,
            step: 3,
            thresh: 5,
            burnin: 20,
            k_max: 10,
        });
        cfg.max_iterations = 3000;
        let out = run_experiment(&cfg).unwrap();
        assert!(
            !out.k_changes.is_empty(),
            "Pflug policy should detect stationarity within 3000 iters"
        );
    }

    #[test]
    fn async_policy_runs() {
        let mut cfg = base();
        cfg.policy = PolicySpec::Async;
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.steps, 300);
        assert!(out.k_changes.is_empty());
    }

    #[test]
    fn compressed_channel_runs_and_meters_bytes() {
        use crate::config::{CommSpec, CompressorSpec};
        let mut cfg = base();
        cfg.comm = CommSpec {
            scheme: CompressorSpec::TopK { frac: 0.3 },
            error_feedback: true,
            bandwidth: 1000.0,
            latency: 0.01,
            ..Default::default()
        };
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.steps, 300);
        // 3-of-10 coords: 16 + 24 = 40 bytes per accepted message, k=5.
        assert_eq!(out.bytes_sent, 300 * 5 * 40);
        assert!(out.comm_time > 0.0);
        assert!(
            out.recorder.last().unwrap().error
                < out.recorder.samples()[0].error
        );
        // The default dense config meters bytes but charges no time.
        let dense = run_experiment(&base()).unwrap();
        assert!(dense.bytes_sent > out.bytes_sent);
        assert_eq!(dense.comm_time, 0.0);
    }

    #[test]
    fn bidirectional_config_runs_and_meters_the_downlink() {
        use crate::config::{CommSpec, CompressorSpec};
        let mut cfg = base();
        cfg.comm = CommSpec {
            downlink: CompressorSpec::TopK { frac: 0.3 },
            ingress_bw: 2000.0,
            ..Default::default()
        };
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.steps, 300);
        // Delta downlink: dense bootstrap (56 B) + 299 top-3-of-10
        // deltas (40 B), each received by all 10 workers.
        assert_eq!(out.bytes_down, 10 * (56 + 299 * 40));
        assert!(
            out.recorder.last().unwrap().error
                < out.recorder.samples()[0].error
        );
        // The default config still prices the downlink at zero but
        // meters its dense traffic.
        let dense = run_experiment(&base()).unwrap();
        assert_eq!(dense.bytes_down, 300 * 10 * 56);
        assert_eq!(dense.down_time, 0.0);
        // With finite ingress the clock runs strictly slower than the
        // independent-upload model of the same config.
        let mut slow = base();
        slow.comm.ingress_bw = 100.0;
        let congested = run_experiment(&slow).unwrap();
        assert!(congested.total_time > dense.total_time);
    }

    #[test]
    fn coded_experiment_runs_and_meters_comm() {
        use crate::config::{CodingSchemeSpec, CodingSpec};
        let mut cfg = base();
        cfg.policy = PolicySpec::Fixed { k: 9 }; // the recovery threshold
        cfg.coding =
            Some(CodingSpec { scheme: CodingSchemeSpec::Frc, r: 2 });
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.steps, 300);
        assert!(
            out.recorder.last().unwrap().error
                < out.recorder.samples()[0].error
        );
        // Exact-gradient rounds still meter the contributing uploads:
        // n/r = 5 messages × 56 bytes × 300 rounds on the dense channel.
        assert_eq!(out.bytes_sent, 300 * 5 * 56);
        // Cyclic and bernoulli placements run through the same path.
        for scheme in [CodingSchemeSpec::Cyclic, CodingSchemeSpec::Bernoulli]
        {
            let mut c = base();
            c.policy = PolicySpec::Fixed { k: 8 };
            c.coding = Some(CodingSpec { scheme, r: 3 });
            let out = run_experiment(&c).unwrap();
            assert_eq!(out.steps, 300, "{scheme}");
            assert!(out.bytes_sent > 0, "{scheme}");
        }
    }

    #[test]
    fn coded_experiment_rejects_async_and_bad_r() {
        use crate::config::{CodingSchemeSpec, CodingSpec};
        let mut cfg = base();
        cfg.policy = PolicySpec::Async;
        cfg.coding =
            Some(CodingSpec { scheme: CodingSchemeSpec::Frc, r: 2 });
        assert!(run_experiment(&cfg).unwrap_err().contains("async"));
        let mut cfg = base();
        cfg.coding =
            Some(CodingSpec { scheme: CodingSchemeSpec::Frc, r: 3 });
        assert!(run_experiment(&cfg).unwrap_err().contains("divide"));
    }

    #[test]
    fn transformer_workload_is_rejected_here() {
        let mut cfg = base();
        cfg.workload = WorkloadSpec::Transformer { tag: "tiny".into() };
        assert!(run_experiment(&cfg).is_err());
    }
}
