//! Synthetic datasets and horizontal sharding.

mod sharding;
mod synthetic;

pub use sharding::Shards;
pub use synthetic::{SyntheticConfig, SyntheticDataset};
