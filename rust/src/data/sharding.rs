//! Horizontal data partitioning: worker `i` gets a contiguous block of
//! `s = m/n` rows of `A = [X|y]` with all columns (paper §I).

use crate::data::SyntheticDataset;
use crate::linalg::Matrix;

/// The n worker shards of a dataset.
#[derive(Debug, Clone)]
pub struct Shards {
    /// Per-worker feature blocks `X_i (s×d)`.
    pub x: Vec<Matrix>,
    /// Per-worker label blocks `y_i (s)`.
    pub y: Vec<Vec<f32>>,
    /// Rows per shard.
    pub s: usize,
}

impl Shards {
    /// Partition `ds` across `n` workers. Requires `n | m` (as the paper
    /// assumes); use [`Shards::partition_uneven`] otherwise.
    pub fn partition(ds: &SyntheticDataset, n: usize) -> Self {
        let m = ds.m();
        assert!(n > 0 && m % n == 0, "n={n} must divide m={m}");
        let s = m / n;
        let mut x = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            x.push(ds.x.slice_rows(i * s, (i + 1) * s));
            y.push(ds.y[i * s..(i + 1) * s].to_vec());
        }
        Self { x, y, s }
    }

    /// Partition with remainder rows spread over the first shards
    /// (extension beyond the paper's n | m assumption).
    pub fn partition_uneven(ds: &SyntheticDataset, n: usize) -> Self {
        let m = ds.m();
        assert!(n > 0 && n <= m, "need 1 <= n <= m");
        let base = m / n;
        let extra = m % n;
        let mut x = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        let mut lo = 0;
        for i in 0..n {
            let hi = lo + base + usize::from(i < extra);
            x.push(ds.x.slice_rows(lo, hi));
            y.push(ds.y[lo..hi].to_vec());
            lo = hi;
        }
        Self { x, y, s: base }
    }

    /// Number of workers n.
    pub fn n(&self) -> usize {
        self.x.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticConfig;

    fn tiny() -> SyntheticDataset {
        SyntheticDataset::generate(
            SyntheticConfig { m: 12, d: 2, ..Default::default() },
            9,
        )
    }

    #[test]
    fn even_partition_covers_everything() {
        let ds = tiny();
        let sh = Shards::partition(&ds, 4);
        assert_eq!(sh.n(), 4);
        assert_eq!(sh.s, 3);
        // Row 5 of the dataset is row 2 of shard 1.
        assert_eq!(sh.x[1].row(2), ds.x.row(5));
        assert_eq!(sh.y[1][2], ds.y[5]);
        let total: usize = sh.x.iter().map(|m| m.rows()).sum();
        assert_eq!(total, 12);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn even_partition_requires_divisibility() {
        Shards::partition(&tiny(), 5);
    }

    #[test]
    fn uneven_partition_spreads_remainder() {
        let ds = tiny();
        let sh = Shards::partition_uneven(&ds, 5);
        let sizes: Vec<usize> = sh.x.iter().map(|m| m.rows()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2, 2]);
        let total: usize = sizes.iter().sum();
        assert_eq!(total, 12);
        // Last row of last shard is the dataset's last row.
        assert_eq!(sh.x[4].row(1), ds.x.row(11));
    }
}
