//! Synthetic linear-regression data, generated exactly per paper §V.A:
//!
//! 1. each row `x_ℓ` drawn uniformly from `{1, …, 10}^d`,
//! 2. a hidden model `w̄` with integer entries uniform in `{1, …, 100}`,
//! 3. labels `y_ℓ ~ N(⟨x_ℓ, w̄⟩, 1)`.

use crate::linalg::Matrix;
use crate::rng::{Normal, Pcg64, Rng};

/// Generation parameters (defaults = the paper's Fig. 2 setup).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyntheticConfig {
    /// Number of data rows m.
    pub m: usize,
    /// Feature dimension d.
    pub d: usize,
    /// Feature entries are uniform integers in `1..=feat_hi`.
    pub feat_hi: u64,
    /// Hidden-model entries are uniform integers in `1..=w_hi`.
    pub w_hi: u64,
    /// Label noise standard deviation.
    pub noise_sigma: f64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        Self { m: 2000, d: 100, feat_hi: 10, w_hi: 100, noise_sigma: 1.0 }
    }
}

/// A generated dataset: `X (m×d)`, `y (m)`, and the hidden `w̄`.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    /// Feature matrix, row-major.
    pub x: Matrix,
    /// Labels.
    pub y: Vec<f32>,
    /// The hidden ground-truth model.
    pub w_bar: Vec<f32>,
    /// Config it was generated from.
    pub config: SyntheticConfig,
}

impl SyntheticDataset {
    /// Deterministic generation from `seed` per §V.A.
    pub fn generate(config: SyntheticConfig, seed: u64) -> Self {
        let mut rng = Pcg64::seed_stream(seed, 0xDA7A);
        let SyntheticConfig { m, d, feat_hi, w_hi, noise_sigma } = config;

        let mut x = Matrix::zeros(m, d);
        for v in x.as_mut_slice().iter_mut() {
            *v = rng.gen_range_u64(1, feat_hi) as f32;
        }
        let w_bar: Vec<f32> =
            (0..d).map(|_| rng.gen_range_u64(1, w_hi) as f32).collect();

        let mut y = Vec::with_capacity(m);
        for i in 0..m {
            let dot: f64 = x
                .row(i)
                .iter()
                .zip(&w_bar)
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            y.push(
                Normal::new(dot, noise_sigma).sample_one(&mut rng) as f32,
            );
        }
        Self { x, y, w_bar, config }
    }

    /// Number of rows m.
    pub fn m(&self) -> usize {
        self.x.rows()
    }

    /// Feature dimension d.
    pub fn d(&self) -> usize {
        self.x.cols()
    }
}

// Small extension so Normal can be used without importing the trait at
// call sites that only need one draw.
trait SampleOne {
    fn sample_one<R: Rng>(&self, rng: &mut R) -> f64;
}

impl SampleOne for Normal {
    fn sample_one<R: Rng>(&self, rng: &mut R) -> f64 {
        use crate::rng::Distribution;
        self.sample(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let ds = SyntheticDataset::generate(SyntheticConfig::default(), 1);
        assert_eq!(ds.m(), 2000);
        assert_eq!(ds.d(), 100);
        for &v in ds.x.as_slice() {
            assert!((1.0..=10.0).contains(&v));
            assert_eq!(v.fract(), 0.0); // integer features
        }
        for &w in &ds.w_bar {
            assert!((1.0..=100.0).contains(&w));
            assert_eq!(w.fract(), 0.0);
        }
    }

    #[test]
    fn labels_track_hidden_model() {
        let ds = SyntheticDataset::generate(SyntheticConfig::default(), 2);
        // y − <x, w̄> should look like N(0, 1): small mean, unit-ish var.
        let mut resid = Vec::with_capacity(ds.m());
        for i in 0..ds.m() {
            let dot: f64 = ds
                .x
                .row(i)
                .iter()
                .zip(&ds.w_bar)
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            resid.push(ds.y[i] as f64 - dot);
        }
        let mean = resid.iter().sum::<f64>() / resid.len() as f64;
        let var = resid.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>()
            / resid.len() as f64;
        assert!(mean.abs() < 0.15, "mean={mean}");
        assert!((var - 1.0).abs() < 0.25, "var={var}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SyntheticDataset::generate(SyntheticConfig::default(), 7);
        let b = SyntheticDataset::generate(SyntheticConfig::default(), 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = SyntheticDataset::generate(SyntheticConfig::default(), 8);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn small_config() {
        let cfg = SyntheticConfig { m: 10, d: 3, ..Default::default() };
        let ds = SyntheticDataset::generate(cfg, 3);
        assert_eq!(ds.m(), 10);
        assert_eq!(ds.d(), 3);
    }
}
