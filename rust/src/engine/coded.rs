//! The coded-gradients gather discipline.
//!
//! [`CodedGather`] runs a [`CodingScheme`] placement through the round
//! engine: each round it prices the model broadcast, samples every
//! worker's response time with the compute term scaled by the
//! replication factor `r`, waits for the policy's target count, and —
//! when that set is not yet decodable — extends along the arrival order
//! to the **first decodable responder set**. The decoded cover names
//! which responders contribute which shards (each shard exactly once),
//! so the applied update is the *exact* full gradient; each contributing
//! worker ships one message (the sum of its covered shards' gradients)
//! through the channel, inheriting uplink compression, error feedback,
//! byte metering, and the shared-ingress round clock for free.
//!
//! Degenerate identities (asserted bit-for-bit by
//! `rust/tests/test_coded_equivalence.rs`):
//!
//! * With a fixed wait target at the recovery threshold, the round is
//!   the classic coded-GD loop — decode always succeeds at the target,
//!   the clock is `r · X_(n−r+1)` on the free channel.
//! * With `r = 1` every worker holds exactly its own shard, the only
//!   decodable set is all n, and the discipline is
//!   [`FastestKGather`](super::FastestKGather) at `k = n` — including
//!   on comm-priced channels.

use super::core::EngineCore;
use super::gather::GatherPolicy;
use crate::coding::CodingScheme;
use crate::exec::scratch;
use crate::engine::EngineRun;
use crate::grad::GradBackend;
use crate::master::fastest_k_select;
use crate::policy::KPolicy;

/// The coded gather: wait for the policy's target, extend to the first
/// decodable responder set, combine the covered shards' gradients into
/// the exact full gradient.
pub struct CodedGather<'a> {
    backend: &'a mut dyn GradBackend,
    scheme: &'a dyn CodingScheme,
    policy: &'a mut dyn KPolicy,
    /// The wait target (the k the policy adapts).
    k: usize,
    delay_buf: Vec<f64>,
    idx_buf: Vec<usize>,
    /// Shard-coverage bitmap of the accepted responders (the cheap
    /// necessary condition for decodability, maintained incrementally
    /// during extension so the decoder runs once per round).
    covered: Vec<bool>,
    /// Accepted-arrival scratch for the shared-ingress round clock.
    arrival_buf: Vec<f64>,
    /// Per-shard gradient scratch.
    partial: Vec<f32>,
    /// A contributing worker's wire message: the sum of its covered
    /// shards' gradients.
    message: Vec<f32>,
    /// The cover flattened to shard order (the fixed work list the
    /// intra-parallel path fans out over).
    flat: Vec<usize>,
    /// Per-shard gradient arena for the intra-parallel path (grown on
    /// demand through [`scratch`]; empty on the serial path).
    arena: Vec<f32>,
    k_changes: Vec<(u64, f64, usize)>,
}

impl<'a> CodedGather<'a> {
    /// Gather `scheme`-coded gradients over `backend`'s shards, with
    /// `policy` adapting the wait target.
    pub fn new(
        backend: &'a mut dyn GradBackend,
        scheme: &'a dyn CodingScheme,
        policy: &'a mut dyn KPolicy,
    ) -> Self {
        let n = backend.n_shards();
        assert_eq!(
            scheme.n(),
            n,
            "coding scheme built for {} workers, backend has {n}",
            scheme.n()
        );
        let d = backend.dim();
        Self {
            backend,
            scheme,
            policy,
            k: 1,
            delay_buf: vec![0.0f64; n],
            idx_buf: Vec::with_capacity(n),
            covered: vec![false; n],
            arrival_buf: Vec::with_capacity(n),
            partial: vec![0.0f32; d],
            message: vec![0.0f32; d],
            flat: Vec::with_capacity(n),
            arena: Vec::new(),
            k_changes: Vec::new(),
        }
    }
}

impl Drop for CodedGather<'_> {
    fn drop(&mut self) {
        scratch::give_f32(std::mem::take(&mut self.arena));
    }
}

impl GatherPolicy for CodedGather<'_> {
    fn initial_k(&self) -> usize {
        self.k
    }

    fn start(&mut self, _core: &mut EngineCore) {
        let n = self.scheme.n();
        self.k = self.policy.initial_k().min(n).max(1);
    }

    fn step(&mut self, core: &mut EngineCore) -> bool {
        let n = self.scheme.n();
        let j = core.steps;
        if j >= core.cfg.max_steps
            || (core.cfg.max_time > 0.0 && core.t >= core.cfg.max_time)
        {
            return false;
        }
        self.backend.on_iteration(j);
        // (1) downlink: broadcast w_j; every worker is charged its
        // download before compute starts.
        let down_bytes = core.broadcast_round();
        // (2) response times: a coded worker computes r shard gradients,
        // so its compute delay scales by r before the (unscaled) upload
        // and download terms.
        let scale = self.scheme.r() as f64;
        for (i, slot) in self.delay_buf.iter_mut().enumerate() {
            *slot = core.response_delay_scaled(j, i, down_bytes, scale);
        }
        // (3) wait for the target's k fastest, then extend one arrival
        // at a time to the first decodable responder set. Any decodable
        // cover draws only from the responders' own assignments, so
        // full union coverage is a *necessary* condition — the bitmap
        // tracks it incrementally (O(r) per added responder) and the
        // decoder itself runs only once it holds (for the greedy cover
        // decode it is also sufficient, so decode runs once per round).
        let scheme = self.scheme;
        let (x_k, _) =
            fastest_k_select(&self.delay_buf, self.k, &mut self.idx_buf);
        let mut accepted = self.k;
        let mut last_arrival = x_k;
        for slot in self.covered.iter_mut() {
            *slot = false;
        }
        let mut remaining = n;
        for &w in &self.idx_buf[..accepted] {
            for &s in scheme.assignment(w) {
                if !self.covered[s] {
                    self.covered[s] = true;
                    remaining -= 1;
                }
            }
        }
        let mut sorted_rest = false;
        let mut cover = None;
        loop {
            if remaining == 0 {
                cover = scheme.decode(&self.idx_buf[..accepted]);
                if cover.is_some() {
                    break;
                }
            }
            if accepted >= n {
                break;
            }
            if !sorted_rest {
                // Lazily order the remainder by arrival once extension
                // is actually needed.
                let delays = &self.delay_buf;
                self.idx_buf[accepted..].sort_unstable_by(|&a, &b| {
                    delays[a].total_cmp(&delays[b])
                });
                sorted_rest = true;
            }
            let w = self.idx_buf[accepted];
            accepted += 1;
            last_arrival = self.delay_buf[w];
            for &s in scheme.assignment(w) {
                if !self.covered[s] {
                    self.covered[s] = true;
                    remaining -= 1;
                }
            }
        }
        let cover = cover.expect(
            "coding-scheme invariant violated: the full responder set \
             must always decode (every shard held by >= 1 worker)",
        );
        // (3b) shared-ingress congestion over every accepted upload —
        // redundant responders hit the master's NIC too, even when their
        // message adds no new shard.
        let round_time = if core.ingress_unlimited() {
            last_arrival
        } else {
            self.arrival_buf.clear();
            self.arrival_buf.extend(
                self.idx_buf[..accepted].iter().map(|&i| self.delay_buf[i]),
            );
            core.round_completion(&mut self.arrival_buf)
        };
        core.t += round_time;

        // (4) decode: each contributing worker ships one message — the
        // sum of its covered shards' gradients — through the channel
        // (compression + error feedback + byte accounting).
        core.zero_g();
        let d = self.message.len();
        if core.par.is_serial() || d == 0 {
            for part in &cover {
                let (&first, rest) = part
                    .shards
                    .split_first()
                    .expect("decode never emits an empty part");
                self.backend.partial_grad(
                    first,
                    &core.w_view,
                    &mut self.message,
                );
                for &shard in rest {
                    self.backend.partial_grad(
                        shard,
                        &core.w_view,
                        &mut self.partial,
                    );
                    for (mv, pv) in
                        self.message.iter_mut().zip(&self.partial)
                    {
                        *mv += *pv;
                    }
                }
                core.accept_into_g(part.worker, &self.message);
            }
        } else {
            // Intra-parallel path: flatten the cover into its fixed
            // shard order, compute every covered shard's gradient into
            // the arena concurrently, then rebuild each part's message
            // serially in the same first-then-rest addition order and
            // accept in the same part order — bitwise the serial loop
            // (partial_grad draws no RNG; transmit stays serial).
            self.flat.clear();
            for part in &cover {
                self.flat.extend_from_slice(&part.shards);
            }
            let total = self.flat.len() * d;
            if self.arena.len() < total {
                scratch::give_f32(std::mem::replace(
                    &mut self.arena,
                    scratch::take_f32(total),
                ));
            }
            let arena = &mut self.arena[..total];
            self.backend.partial_grads(
                &self.flat,
                &core.w_view,
                arena,
                core.par,
            );
            let mut off = 0;
            for part in &cover {
                let slots = &arena[off..off + part.shards.len() * d];
                off += slots.len();
                let (first, rest) = slots.split_at(d);
                self.message.copy_from_slice(first);
                for slot in rest.chunks_exact(d) {
                    for (mv, pv) in self.message.iter_mut().zip(slot) {
                        *mv += *pv;
                    }
                }
                core.accept_into_g(part.worker, &self.message);
            }
        }
        // (5) the shared round tail. Every shard is covered exactly once,
        // so the mean divides by n (the exact full gradient) while the
        // policy adapts the wait target k.
        self.k = core.finish_round_scaled(
            j,
            n,
            self.k,
            n,
            &mut *self.policy,
            &mut self.k_changes,
        );
        true
    }

    fn finish(&mut self, core: &mut EngineCore) {
        core.record_final(core.steps, self.k);
    }

    fn annotate(&mut self, run: &mut EngineRun) {
        run.k_changes = std::mem::take(&mut self.k_changes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{BernoulliScheme, CyclicRepetition, FrcScheme};
    use crate::comm::CommChannel;
    use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
    use crate::engine::{EngineConfig, EngineCore, RngStreams, RoundEngine};
    use crate::grad::NativeBackend;
    use crate::model::{full_gradient, LinRegProblem};
    use crate::policy::{AdaptivePflug, FixedK, PflugParams};
    use crate::straggler::ExponentialDelays;

    fn run_coded(
        scheme: &dyn CodingScheme,
        target: usize,
        max_steps: u64,
        eta: f32,
        seed: u64,
    ) -> crate::engine::EngineRun {
        let n = scheme.n();
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 20 * n, d: 8, ..Default::default() },
            seed,
        );
        let problem = LinRegProblem::new(&ds);
        let mut backend = NativeBackend::new(Shards::partition(&ds, n));
        let delays = ExponentialDelays::new(1.0);
        let mut policy = FixedK::new(target);
        let mut channel = CommChannel::dense(n);
        let mut eval = |w: &[f32]| problem.error(w);
        let cfg = EngineConfig {
            eta,
            momentum: 0.0,
            max_steps,
            max_time: 0.0,
            seed,
            record_stride: 50,
            intra_jobs: 1,
        };
        let core = EngineCore::new(
            scheme.name(),
            &mut channel,
            &delays,
            &mut eval,
            &vec![0.0f32; 8],
            cfg,
            RngStreams::coded(seed),
        );
        let mut gather = CodedGather::new(&mut backend, scheme, &mut policy);
        RoundEngine::new(core).run(&mut gather)
    }

    #[test]
    fn coded_gather_applies_the_exact_full_gradient_below_threshold() {
        // Target 1 forces the decode-extension path; the update must
        // still be the exact full gradient.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 6, ..Default::default() },
            7,
        );
        let problem = LinRegProblem::new(&ds);
        let mut backend = NativeBackend::new(Shards::partition(&ds, 6));
        let scheme = FrcScheme::new(6, 2).unwrap();
        let delays = ExponentialDelays::new(1.0);
        let mut policy = FixedK::new(1);
        let mut channel = CommChannel::dense(6);
        let mut eval = |w: &[f32]| problem.error(w);
        let cfg = EngineConfig {
            eta: 1e-3,
            momentum: 0.0,
            max_steps: 1,
            max_time: 0.0,
            seed: 1,
            record_stride: 1,
            intra_jobs: 1,
        };
        let core = EngineCore::new(
            "coded",
            &mut channel,
            &delays,
            &mut eval,
            &vec![0.0f32; 6],
            cfg,
            RngStreams::coded(1),
        );
        let mut gather =
            CodedGather::new(&mut backend, &scheme, &mut policy);
        let run = RoundEngine::new(core).run(&mut gather);
        let mut gfull = vec![0.0f32; 6];
        full_gradient(&ds.x, &ds.y, &[0.0f32; 6], &mut gfull);
        for j in 0..6 {
            let want = -1e-3 * gfull[j];
            let rel = (run.w[j] - want).abs() / want.abs().max(1e-6);
            assert!(rel < 1e-3, "j={j}: {} vs {}", run.w[j], want);
        }
    }

    #[test]
    fn first_decodable_wait_never_exceeds_the_threshold_wait() {
        // Same seed → same delay draws; per round the first decodable
        // prefix arrives no later than the guaranteed threshold count,
        // and the applied gradient is exact either way.
        let scheme = FrcScheme::new(12, 3).unwrap();
        let thr = scheme.recovery_threshold();
        let eager = run_coded(&scheme, 1, 200, 1e-3, 5);
        let classic = run_coded(&scheme, thr, 200, 1e-3, 5);
        assert_eq!(eager.steps, classic.steps);
        assert!(
            eager.total_time <= classic.total_time + 1e-9,
            "decodability-driven wait must not be slower: {} vs {}",
            eager.total_time,
            classic.total_time
        );
        let e_last = eager.recorder.last().unwrap().error;
        let c_last = classic.recorder.last().unwrap().error;
        // Both are exact GD — identical math up to fp reassociation
        // (different part groupings), so the errors track closely.
        let rel = (e_last - c_last).abs() / c_last.abs().max(1e-12);
        assert!(rel < 5e-2, "{e_last} vs {c_last}");
    }

    #[test]
    fn cyclic_and_bernoulli_schemes_converge_through_the_engine() {
        let cyclic = CyclicRepetition::new(10, 3).unwrap();
        let run_c = run_coded(&cyclic, 4, 400, 2e-3, 2);
        assert_eq!(run_c.steps, 400);
        let first = run_c.recorder.samples()[0].error;
        let last = run_c.recorder.last().unwrap().error;
        assert!(last < first * 1e-2, "cyclic: {first} -> {last}");

        let bern = BernoulliScheme::new(10, 3, 11).unwrap();
        let run_b = run_coded(&bern, 4, 400, 2e-3, 2);
        let first = run_b.recorder.samples()[0].error;
        let last = run_b.recorder.last().unwrap().error;
        assert!(last < first * 1e-2, "bernoulli: {first} -> {last}");
    }

    #[test]
    fn adaptive_wait_target_runs_and_is_clamped() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 200, d: 10, ..Default::default() },
            3,
        );
        let problem = LinRegProblem::new(&ds);
        let mut backend = NativeBackend::new(Shards::partition(&ds, 10));
        let scheme = CyclicRepetition::new(10, 2).unwrap();
        let delays = ExponentialDelays::new(1.0);
        let mut policy = AdaptivePflug::new(
            10,
            PflugParams { k0: 2, step: 3, thresh: 5, burnin: 10, k_max: 10 },
        );
        let mut channel = CommChannel::dense(10);
        let mut eval = |w: &[f32]| problem.error(w);
        let cfg = EngineConfig {
            eta: 2e-3,
            momentum: 0.0,
            max_steps: 300,
            max_time: 0.0,
            seed: 4,
            record_stride: 50,
            intra_jobs: 1,
        };
        let core = EngineCore::new(
            "coded-adaptive",
            &mut channel,
            &delays,
            &mut eval,
            &vec![0.0f32; 10],
            cfg,
            RngStreams::coded(4),
        );
        let mut gather =
            CodedGather::new(&mut backend, &scheme, &mut policy);
        let run = RoundEngine::new(core).run(&mut gather);
        assert_eq!(run.steps, 300);
        for &(_, _, k) in &run.k_changes {
            assert!((1..=10).contains(&k));
        }
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 1e-2, "{first} -> {last}");
    }
}
