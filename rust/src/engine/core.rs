//! Shared state and mechanics of the round engine.
//!
//! [`EngineCore`] is the single home of every per-round mechanism the
//! training drivers used to duplicate: model-broadcast pricing
//! ([`EngineCore::broadcast_round`] / [`EngineCore::push_model_to`]),
//! worker response-delay composition ([`EngineCore::response_delay`] /
//! [`EngineCore::cycle_delay`]), uplink transmit + aggregation
//! ([`EngineCore::accept_into_g`] / [`EngineCore::transmit`]),
//! shared-ingress clocks ([`EngineCore::round_completion`] /
//! [`EngineCore::serve_ingress`]), the SGD apply
//! ([`EngineCore::apply_g_sgd`] / [`EngineCore::apply_decoded`]), and
//! metric recording ([`EngineCore::maybe_record`] and friends). A
//! [`GatherPolicy`](super::GatherPolicy) composes these into a
//! discipline; it never touches the channel, the rng streams, or the
//! recorder directly, so a new discipline cannot re-implement pricing
//! differently by accident.
//!
//! Reproducibility contract: every method performs the exact operations
//! (same floating-point order, same rng stream constants, same draw
//! order) of the pre-engine drivers, so the compatibility shims in
//! [`master`](crate::master), [`async_sgd`](crate::async_sgd), and
//! [`exec`](crate::exec) reproduce their historical trajectories bit for
//! bit on the default channel (asserted by
//! `rust/tests/test_engine_equivalence.rs`).

use crate::comm::{CommChannel, DownlinkMode, IngressDiscipline, IngressModel};
use crate::exec::{for_each_block_mut, zip_block_mut, Parallelism};
use crate::linalg::dot;
use crate::metrics::{Recorder, Sample};
use crate::policy::{IterationObs, KPolicy};
use crate::rng::Pcg64;
use crate::straggler::DelayModel;
use crate::trace::{Discipline, Event, Trace};

/// Engine loop bounds and step parameters, the superset of the three
/// drivers' configs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Step size η.
    pub eta: f32,
    /// Heavy-ball momentum β (0 = plain SGD; only the sync gather uses
    /// it).
    pub momentum: f32,
    /// Hard step cap: iterations for round disciplines, updates for the
    /// async discipline.
    pub max_steps: u64,
    /// Stop once the virtual clock passes this (0 = no time budget).
    pub max_time: f64,
    /// Seed the rng streams derive from.
    pub seed: u64,
    /// Evaluate + record the error every this many steps.
    pub record_stride: u64,
    /// Intra-round worker budget (1 = strictly serial, 0 = the machine;
    /// see [`Parallelism::new`]). Wall-clock only — results are bitwise
    /// identical for every value, so like `jobs` it is never part of an
    /// experiment's identity.
    pub intra_jobs: usize,
}

/// The uplink-compression rng: one shared stream for the single-threaded
/// simulators, one stream per worker for the threaded cluster (responses
/// arrive in nondeterministic order there, so a shared stream would hand
/// different draws to different workers across runs of the same seed).
pub enum CommStream {
    /// One stream, drawn in acceptance order (the simulators' model).
    Shared(Pcg64),
    /// One stream per worker, independent of arrival order.
    PerWorker(Vec<Pcg64>),
}

impl CommStream {
    fn for_worker(&mut self, worker: usize) -> &mut Pcg64 {
        match self {
            CommStream::Shared(rng) => rng,
            CommStream::PerWorker(rngs) => &mut rngs[worker],
        }
    }
}

/// The three rng streams an engine run draws from, with the historical
/// per-driver stream constants (changing any would change trajectories).
pub struct RngStreams {
    /// Compute-delay draws.
    pub delay: Pcg64,
    /// Downlink (broadcast) encoder draws.
    pub bcast: Pcg64,
    /// Uplink compression draws.
    pub comm: CommStream,
}

impl RngStreams {
    /// The synchronous simulator's streams.
    pub fn sync(seed: u64) -> Self {
        Self {
            delay: Pcg64::seed_stream(seed, 0xFA57),
            bcast: Pcg64::seed_stream(seed, 0xB04D),
            comm: CommStream::Shared(Pcg64::seed_stream(seed, 0xC044)),
        }
    }

    /// The asynchronous simulator's streams.
    pub fn asynchronous(seed: u64) -> Self {
        Self {
            delay: Pcg64::seed_stream(seed, 0xA57C),
            bcast: Pcg64::seed_stream(seed, 0xB04E),
            comm: CommStream::Shared(Pcg64::seed_stream(seed, 0xC045)),
        }
    }

    /// The threaded cluster's streams (delay stream shared with the sync
    /// simulator so both replay the same straggler pattern; per-worker
    /// compression streams).
    pub fn threaded(seed: u64, n: usize) -> Self {
        Self {
            delay: Pcg64::seed_stream(seed, 0xFA57),
            bcast: Pcg64::seed_stream(seed, 0xB04F),
            comm: CommStream::PerWorker(
                (0..n)
                    .map(|i| {
                        Pcg64::seed_stream(seed, 0xC046_0000 + i as u64)
                    })
                    .collect(),
            ),
        }
    }

    /// The coded-gather driver's streams. The delay constant is the
    /// historical `run_coded_gd` stream, so coded trajectories keep
    /// their pre-engine straggler pattern and stay paired across
    /// schemes/replication factors at a fixed seed.
    pub fn coded(seed: u64) -> Self {
        Self {
            delay: Pcg64::seed_stream(seed, 0xC0DE),
            bcast: Pcg64::seed_stream(seed, 0xB050),
            comm: CommStream::Shared(Pcg64::seed_stream(seed, 0xC047)),
        }
    }
}

/// What every engine run produces; discipline-specific fields default to
/// zero/empty and are filled by the gather's
/// [`annotate`](super::GatherPolicy::annotate).
pub struct EngineRun {
    /// Error-vs-time record.
    pub recorder: Recorder,
    /// Final model.
    pub w: Vec<f32>,
    /// Steps completed (iterations or updates).
    pub steps: u64,
    /// Final virtual clock.
    pub total_time: f64,
    /// Encoded bytes of all accepted gradient messages.
    pub bytes_sent: u64,
    /// Total upload time of accepted messages.
    pub comm_time: f64,
    /// Encoded bytes of all model downloads.
    pub bytes_down: u64,
    /// Total download time charged.
    pub down_time: f64,
    /// (iteration, time, new_k) log — fastest-k disciplines.
    pub k_changes: Vec<(u64, f64, usize)>,
    /// Mean staleness — the async discipline.
    pub mean_staleness: f64,
    /// True if the run blew up (non-finite model) and stopped early.
    pub diverged: bool,
    /// Late (discarded) responses — the threaded discipline.
    pub late_responses: u64,
    /// The binary event trace, when [`EngineCore::enable_trace`] was
    /// called before the run (`None` otherwise — tracing is opt-in).
    pub trace: Option<Trace>,
}

/// Shared engine state: model, buffers, rng streams, channel plumbing,
/// clock, and recorder. See the module docs for the method inventory.
pub struct EngineCore<'a> {
    /// Loop bounds and step parameters.
    pub cfg: EngineConfig,
    /// Resolved intra-round worker budget (from `cfg.intra_jobs`).
    /// Gathers thread it into [`GradBackend::partial_grads`]
    /// (crate::grad::GradBackend::partial_grads) and the core's own
    /// d-dimensional merge/apply loops split on it. Never observable in
    /// results — see [`crate::exec::par`] for the determinism argument.
    pub par: Parallelism,
    channel: &'a mut CommChannel,
    delays: &'a dyn DelayModel,
    eval: &'a mut dyn FnMut(&[f32]) -> f64,
    delay_rng: Pcg64,
    bcast_rng: Pcg64,
    comm_rng: CommStream,
    /// The master's model `w_j`.
    pub w: Vec<f32>,
    /// The workers' model view — what the downlink broadcast reconstructs
    /// (bitwise `w` on the default dense downlink).
    pub w_view: Vec<f32>,
    /// Aggregated (or, for async, scratch) gradient `ĝ_j`.
    pub g: Vec<f32>,
    g_prev: Vec<f32>,
    decoded: Vec<f32>,
    velocity: Option<Vec<f32>>,
    msg_bytes: u64,
    ingress: IngressModel,
    ingress_free: f64,
    bytes0: u64,
    comm_t0: f64,
    down0: u64,
    down_t0: f64,
    recorder: Recorder,
    tracer: Option<Trace>,
    /// Virtual clock.
    pub t: f64,
    /// Steps completed (iterations or updates — the discipline's unit).
    pub steps: u64,
}

impl<'a> EngineCore<'a> {
    /// Build a core over the caller's channel/delay-model/evaluator, with
    /// the model initialised to `w0` and the recorder labelled `label`.
    pub fn new(
        label: impl Into<String>,
        channel: &'a mut CommChannel,
        delays: &'a dyn DelayModel,
        eval: &'a mut dyn FnMut(&[f32]) -> f64,
        w0: &[f32],
        cfg: EngineConfig,
        streams: RngStreams,
    ) -> Self {
        let d = w0.len();
        // Per-message upload pricing is data-independent, so the whole
        // run's message size is known up front; on a zero-cost link every
        // priced delay is exactly 0.0 and `x + 0.0` is bitwise identity
        // for the positive compute delays — no branch needed to preserve
        // compute-only trajectories.
        let msg_bytes = channel.message_bytes(d);
        let ingress = *channel.ingress();
        let recorder = Recorder::with_stride(label, cfg.record_stride);
        let par = Parallelism::new(cfg.intra_jobs);
        Self {
            par,
            bytes0: channel.stats.bytes_sent,
            comm_t0: channel.stats.comm_time,
            down0: channel.stats.bytes_down,
            down_t0: channel.stats.down_time,
            channel,
            delays,
            eval,
            delay_rng: streams.delay,
            bcast_rng: streams.bcast,
            comm_rng: streams.comm,
            w: w0.to_vec(),
            w_view: w0.to_vec(),
            g: vec![0.0f32; d],
            g_prev: vec![0.0f32; d],
            decoded: vec![0.0f32; d],
            velocity: None,
            msg_bytes,
            ingress,
            ingress_free: f64::NEG_INFINITY,
            recorder,
            tracer: None,
            t: 0.0,
            steps: 0,
            cfg,
        }
    }

    /// Turn on binary event tracing for this run (see [`crate::trace`]).
    ///
    /// Observationally free: no RNG draw, clock update, or recorder
    /// push is added or reordered, so a traced run's trajectory is
    /// bit-identical to the untraced one. The finished trace rides out
    /// on [`EngineRun::trace`].
    pub fn enable_trace(&mut self, discipline: Discipline) {
        self.tracer = Some(Trace::new(
            discipline,
            self.channel.n() as u32,
            self.recorder.label.clone(),
        ));
    }

    /// True when tracing is enabled (gathers guard event construction
    /// on it).
    #[inline]
    pub fn trace_on(&self) -> bool {
        self.tracer.is_some()
    }

    /// Append an event to the trace; no-op when tracing is off. Public
    /// so gather disciplines can log what only they can see (applies
    /// with staleness, for the async disciplines).
    #[inline]
    pub fn trace_event(&mut self, ev: Event) {
        if let Some(t) = self.tracer.as_mut() {
            t.push(ev);
        }
    }

    /// Mirror a recorder sample into the trace, so a replay can be
    /// diffed against the trace file alone.
    fn trace_sample(&mut self, s: &Sample) {
        if let Some(t) = self.tracer.as_mut() {
            t.push(Event::Sample {
                iteration: s.iteration,
                time: s.time,
                k: s.k as u32,
                error: s.error,
                bytes: s.bytes,
                comm_time: s.comm_time,
                bytes_down: s.bytes_down,
                down_time: s.down_time,
            });
        }
    }

    /// Workers the channel is sized for.
    pub fn n(&self) -> usize {
        self.channel.n()
    }

    /// Model dimension.
    pub fn dim(&self) -> usize {
        self.w.len()
    }

    /// Encoded uplink message size (data-independent).
    pub fn msg_bytes(&self) -> u64 {
        self.msg_bytes
    }

    /// `worker`'s constant uplink delay for this round's message size
    /// (latency + bytes/bandwidth — data-independent, so the fastpath
    /// can fold it into per-class arrival shifts).
    pub fn upload_const(&self, worker: usize) -> f64 {
        self.channel.link_upload_delay(worker, self.msg_bytes)
    }

    /// `worker`'s constant download delay for a `bytes`-sized model
    /// message. Uniform downlinks make this one number per round — the
    /// fastpath shifts every merged arrival by it.
    pub fn download_const(&self, worker: usize, bytes: u64) -> f64 {
        self.channel.download_delay(worker, bytes)
    }

    // ------------------------------------------------------------------
    // Downlink: model broadcast pricing (the one place it happens).
    // ------------------------------------------------------------------

    /// Broadcast `w` to all workers (round disciplines): encodes once
    /// through the downlink into `w_view`, accounts bytes × n downloads
    /// plus every worker's download delay, and returns the encoded size
    /// for per-worker response pricing.
    pub fn broadcast_round(&mut self) -> u64 {
        let bytes = self.channel.broadcast_model(
            &self.w,
            &mut self.w_view,
            &mut self.bcast_rng,
        );
        if self.tracer.is_some() {
            let (step, time) = (self.steps, self.t);
            self.trace_event(Event::Broadcast { step, time, bytes });
        }
        bytes
    }

    /// Unicast `w` to one restarting worker (the async discipline),
    /// writing the decoded view into `out` (the worker's snapshot) and
    /// charging `replay` downlink messages; returns `(bytes, download
    /// delay)`.
    pub fn push_model_to(
        &mut self,
        worker: usize,
        out: &mut [f32],
        replay: u64,
    ) -> (u64, f64) {
        let (bytes, delay) = self.channel.push_model(
            worker,
            &self.w,
            out,
            replay,
            &mut self.bcast_rng,
        );
        if self.tracer.is_some() {
            let step = self.steps;
            self.trace_event(Event::Push {
                step,
                worker: worker as u32,
                bytes,
                delay,
            });
        }
        (bytes, delay)
    }

    /// The downlink encoding mode (disciplines branch replay accounting
    /// on it).
    pub fn downlink_mode(&self) -> DownlinkMode {
        self.channel.downlink_mode()
    }

    // ------------------------------------------------------------------
    // Response-delay composition (the one place delays are sampled).
    // ------------------------------------------------------------------

    /// A round worker's full response time: compute delay (drawn from the
    /// delay stream) + priced upload + priced download of a
    /// `down_bytes`-sized model message. Free links contribute exactly
    /// 0.0, preserving compute-only sums bitwise.
    pub fn response_delay(
        &mut self,
        iteration: u64,
        worker: usize,
        down_bytes: u64,
    ) -> f64 {
        self.response_delay_scaled(iteration, worker, down_bytes, 1.0)
    }

    /// A round worker's response time with the compute term scaled: a
    /// coded worker computes `r` shard gradients per round, so its
    /// sampled delay is multiplied by `compute_scale = r` before the
    /// (unscaled) upload and download terms. `compute_scale = 1.0` is
    /// bitwise inert, so the uncoded disciplines are unchanged.
    pub fn response_delay_scaled(
        &mut self,
        iteration: u64,
        worker: usize,
        down_bytes: u64,
        compute_scale: f64,
    ) -> f64 {
        // Bound as locals in sampling order; the sum below keeps the
        // historical left-to-right float association bit for bit.
        let raw = self.delays.sample(iteration, worker, &mut self.delay_rng);
        let upload = self.channel.link_upload_delay(worker, self.msg_bytes);
        let download = self.channel.download_delay(worker, down_bytes);
        if self.tracer.is_some() {
            self.trace_event(Event::Compute {
                iteration,
                worker: worker as u32,
                raw,
                compute: raw * compute_scale,
                upload,
                download,
            });
        }
        raw * compute_scale + upload + download
    }

    /// An async worker's next cycle: compute delay + priced upload +
    /// the already-priced download delay of its restart (0.0 for the
    /// initial dispatch — workers are assumed to know `w0`).
    pub fn cycle_delay(
        &mut self,
        step: u64,
        worker: usize,
        down_delay: f64,
    ) -> f64 {
        let raw = self.delays.sample(step, worker, &mut self.delay_rng);
        let upload = self.channel.link_upload_delay(worker, self.msg_bytes);
        if self.tracer.is_some() {
            self.trace_event(Event::Compute {
                iteration: step,
                worker: worker as u32,
                raw,
                compute: raw,
                upload,
                download: down_delay,
            });
        }
        raw + upload + down_delay
    }

    // ------------------------------------------------------------------
    // Shared-ingress clocks (the one place contention is priced).
    // ------------------------------------------------------------------

    /// True iff uploads never contend (the independent-upload model).
    pub fn ingress_unlimited(&self) -> bool {
        self.ingress.is_unlimited()
    }

    /// The ingress queueing discipline.
    pub fn ingress_discipline(&self) -> IngressDiscipline {
        self.ingress.discipline()
    }

    /// Ingress service time of one uplink message.
    pub fn ingress_service_time(&self) -> f64 {
        self.ingress.service_time(self.msg_bytes)
    }

    /// Round clock under contention: completion of the last accepted
    /// upload, FIFO or PS per the channel's discipline (sorts `arrivals`
    /// in place).
    pub fn round_completion(&self, arrivals: &mut [f64]) -> f64 {
        self.ingress.round_completion(arrivals, self.msg_bytes)
    }

    /// Serve `worker`'s arriving upload through the FIFO ingress chain
    /// (the async discipline's running state lives here): completion is
    /// `max(arrival, free) + service`, bitwise the arrival when
    /// unlimited.
    pub fn serve_ingress(&mut self, worker: usize, arrival: f64) -> f64 {
        let t =
            self.ingress.serve_at(arrival, self.ingress_free, self.msg_bytes);
        self.ingress_free = t;
        if self.tracer.is_some() {
            self.trace_event(Event::IngressServe {
                worker: worker as u32,
                arrival,
                served: t,
            });
        }
        t
    }

    // ------------------------------------------------------------------
    // Uplink transmit + aggregation (the one place gradients ship).
    // ------------------------------------------------------------------

    /// Ship worker `i`'s raw gradient through the channel (error feedback
    /// + compression + byte accounting) and add the master's
    /// reconstruction into `g`.
    pub fn accept_into_g(&mut self, worker: usize, raw: &[f32]) {
        self.transmit(worker, raw);
        // Elementwise merge, split into fixed column blocks: bitwise
        // equal to the serial loop for any intra budget. `transmit`
        // itself stays strictly serial — it draws from the comm rng.
        zip_block_mut(self.par, &mut self.g, &self.decoded, |_, gc, pc| {
            for (gv, pv) in gc.iter_mut().zip(pc) {
                *gv += *pv;
            }
        });
    }

    /// Ship worker `i`'s raw gradient through the channel, leaving the
    /// reconstruction in the decoded buffer (applied by
    /// [`EngineCore::apply_decoded`] — the async discipline).
    pub fn transmit(&mut self, worker: usize, raw: &[f32]) {
        let rng = self.comm_rng.for_worker(worker);
        self.channel.transmit(worker, raw, &mut self.decoded, rng);
        if self.tracer.is_some() {
            let (step, bytes) = (self.steps, self.msg_bytes);
            self.trace_event(Event::Transmit {
                step,
                worker: worker as u32,
                bytes,
            });
        }
    }

    /// Zero the aggregation buffer for a new round.
    pub fn zero_g(&mut self) {
        self.g.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Scale the aggregate by `1/k` (the fastest-k mean).
    pub fn scale_g(&mut self, k: usize) {
        let inv_k = 1.0 / k as f32;
        for_each_block_mut(self.par, &mut self.g, |_, gc| {
            for gv in gc.iter_mut() {
                *gv *= inv_k;
            }
        });
    }

    // ------------------------------------------------------------------
    // The gradient apply (the one place the model moves).
    // ------------------------------------------------------------------

    /// SGD step from the aggregated `g`: heavy-ball when momentum > 0
    /// (velocity allocated lazily), plain descent otherwise.
    pub fn apply_g_sgd(&mut self) {
        if self.cfg.momentum > 0.0 {
            // Heavy-ball stays serial: it mutates two vectors in
            // lockstep, and only the sync gather (small d in practice)
            // uses it — not worth a second SendPtr protocol.
            let v = self
                .velocity
                .get_or_insert_with(|| vec![0.0f32; self.w.len()]);
            for ((vv, wv), gv) in
                v.iter_mut().zip(self.w.iter_mut()).zip(&self.g)
            {
                *vv = self.cfg.momentum * *vv + *gv;
                *wv -= self.cfg.eta * *vv;
            }
        } else {
            let eta = self.cfg.eta;
            zip_block_mut(self.par, &mut self.w, &self.g, |_, wc, gc| {
                for (wv, gv) in wc.iter_mut().zip(gc) {
                    *wv -= eta * *gv;
                }
            });
        }
    }

    /// Apply the decoded single-worker gradient with an explicit step
    /// size (the async discipline's staleness-damped update).
    pub fn apply_decoded(&mut self, step: f32) {
        zip_block_mut(self.par, &mut self.w, &self.decoded, |_, wc, gc| {
            for (wv, gv) in wc.iter_mut().zip(gc) {
                *wv -= step * *gv;
            }
        });
    }

    /// The shared tail of every fastest-k round, after the clock has
    /// advanced and the k accepted gradients are summed in `g`:
    /// mean-scale, apply the SGD step, feed the `policy` its
    /// [`IterationObs`] (logging any k switch into `k_changes`), rotate
    /// the gradient history, advance the step counter, and record on
    /// stride. Returns the k for the next round. Both the simulated and
    /// the threaded fastest-k disciplines call this, so the round
    /// composition cannot fork again.
    pub fn finish_fastest_k_round(
        &mut self,
        j: u64,
        n: usize,
        k: usize,
        policy: &mut dyn KPolicy,
        k_changes: &mut Vec<(u64, f64, usize)>,
    ) -> usize {
        self.finish_round_scaled(j, n, k, k, policy, k_changes)
    }

    /// [`EngineCore::finish_fastest_k_round`] with the aggregate's mean
    /// divisor decoupled from the policy variable: the fastest-k mean
    /// divides by the k accepted gradients (`scale_count = k`), while the
    /// coded gather's exact full gradient divides by n (every shard
    /// covered exactly once) even as the policy adapts the wait target
    /// `k`. The two coincide at `scale_count = k`, which
    /// `finish_fastest_k_round` delegates with.
    pub fn finish_round_scaled(
        &mut self,
        j: u64,
        n: usize,
        k: usize,
        scale_count: usize,
        policy: &mut dyn KPolicy,
        k_changes: &mut Vec<(u64, f64, usize)>,
    ) -> usize {
        self.scale_g(scale_count);
        self.apply_g_sgd();
        if self.tracer.is_some() {
            let (time, k32) = (self.t, k as u32);
            self.trace_event(Event::Apply {
                step: j,
                time,
                k: k32,
                staleness: 0,
            });
        }
        let inner =
            if j == 0 { None } else { Some(self.grad_inner_prev()) };
        let obs = IterationObs {
            iteration: j,
            time: self.t,
            k_used: k,
            grad_inner_prev: inner,
            grad_norm_sq: self.grad_norm_sq(),
        };
        let k_next = policy.next_k(&obs).clamp(1, n);
        let k_new = if k_next != k {
            k_changes.push((j, self.t, k_next));
            if self.tracer.is_some() {
                let time = self.t;
                self.trace_event(Event::KChange {
                    step: j,
                    time,
                    k: k_next as u32,
                });
            }
            k_next
        } else {
            k
        };
        self.swap_g();
        self.steps = j + 1;
        self.maybe_record(self.steps, k_new);
        k_new
    }

    /// True while the model is finite (divergence guard, first
    /// coordinate — the historical async check).
    pub fn model_is_finite(&self) -> bool {
        self.w[0].is_finite()
    }

    // ------------------------------------------------------------------
    // Policy observables.
    // ------------------------------------------------------------------

    /// `⟨ĝ_j, ĝ_{j−1}⟩` for the k policies.
    pub fn grad_inner_prev(&self) -> f64 {
        dot(&self.g, &self.g_prev)
    }

    /// `‖ĝ_j‖²`.
    pub fn grad_norm_sq(&self) -> f64 {
        dot(&self.g, &self.g)
    }

    /// Rotate `g` into `g_prev` for the next round's inner product.
    pub fn swap_g(&mut self) {
        std::mem::swap(&mut self.g, &mut self.g_prev);
    }

    // ------------------------------------------------------------------
    // Metric recording (the one place samples are built).
    // ------------------------------------------------------------------

    /// A sample at the current clock with the given error value (the one
    /// place the stats-delta fields are assembled).
    fn sample_with_error(&self, step: u64, k: usize, error: f64) -> Sample {
        Sample {
            iteration: step,
            time: self.t,
            k,
            error,
            bytes: self.channel.stats.bytes_sent - self.bytes0,
            comm_time: self.channel.stats.comm_time - self.comm_t0,
            bytes_down: self.channel.stats.bytes_down - self.down0,
            down_time: self.channel.stats.down_time - self.down_t0,
        }
    }

    fn stats_sample(&mut self, step: u64, k: usize) -> Sample {
        let error = (self.eval)(&self.w);
        self.sample_with_error(step, k, error)
    }

    /// Record the initial point (iteration 0, time 0, zero traffic).
    pub fn record_initial(&mut self, k: usize) {
        let error = (self.eval)(&self.w);
        let s = Sample {
            iteration: 0,
            time: 0.0,
            k,
            error,
            ..Default::default()
        };
        self.trace_sample(&s);
        self.recorder.push_forced(s);
    }

    /// Record a full sample if `step` lands on the record stride.
    pub fn maybe_record(&mut self, step: u64, k: usize) {
        if step % self.cfg.record_stride == 0 {
            let s = self.stats_sample(step, k);
            self.trace_sample(&s);
            self.recorder.push_forced(s);
        }
    }

    /// Record the end state unless the stride already captured it.
    pub fn record_final(&mut self, step: u64, k: usize) {
        if step % self.cfg.record_stride != 0 {
            let s = self.stats_sample(step, k);
            self.trace_sample(&s);
            self.recorder.push_forced(s);
        }
    }

    /// Record a divergence marker (error = ∞, no model evaluation).
    pub fn record_diverged(&mut self, step: u64, k: usize) {
        let s = self.sample_with_error(step, k, f64::INFINITY);
        self.trace_sample(&s);
        self.recorder.push_forced(s);
    }

    /// Consume the core into the run result (discipline extras default).
    pub fn into_run(self) -> EngineRun {
        EngineRun {
            bytes_sent: self.channel.stats.bytes_sent - self.bytes0,
            comm_time: self.channel.stats.comm_time - self.comm_t0,
            bytes_down: self.channel.stats.bytes_down - self.down0,
            down_time: self.channel.stats.down_time - self.down_t0,
            recorder: self.recorder,
            w: self.w,
            steps: self.steps,
            total_time: self.t,
            k_changes: Vec::new(),
            mean_staleness: 0.0,
            diverged: false,
            late_responses: 0,
            trace: self.tracer,
        }
    }
}
