//! The O(k) order-statistics fast path for synchronous fastest-k rounds.
//!
//! [`FastestKGather`](super::FastestKGather) prices all n worker
//! responses every round and quickselects the k fastest — O(n) rng draws
//! and O(n) comparisons per step, which caps experiments at n in the
//! thousands. For i.i.d. delay models the round outcome depends on the
//! delays only through (a) the k-th arrival time `X_(k)` and (b) *which*
//! k workers respond — and both can be sampled directly:
//!
//! * the ascending arrival prefix `X_(1..k)` comes from
//!   [`OrderStatSampler`] in O(k) (Rényi spacings for the exponential
//!   family, conditional-uniform inverse CDF otherwise);
//! * by exchangeability the identities of the k fastest are a uniform
//!   k-subset of `0..n`, drawn with k partial Fisher–Yates swaps over a
//!   persistent permutation (the permutation never needs resetting: a
//!   uniform subset of a permuted range is still uniform).
//!
//! The result is an O(k + k·d) round — independent of n except for the
//! one-time O(n) identity array — making the ROADMAP's n = 10⁶ sync
//! round a few microseconds of sampling instead of 10⁶ draws.
//!
//! **Contract: distributional, not bitwise.** The fast path consumes a
//! different number of rng draws (2k, on its own dedicated stream) than
//! the exhaustive gather (n per round on the sync delay stream), so
//! trajectories differ draw-by-draw while every round-time and
//! worker-subset *distribution* is exactly the law of the exhaustive
//! path. That is why it is opt-in (`[run] fastpath` / `--fastpath`,
//! off by default — all existing trajectories stay bit-identical) and
//! why `coordinator` only enables it for free-communication,
//! untraced, i.i.d.-delay configs where "delay model draw" and "full
//! response time" coincide (see `ExperimentConfig::validate`). The
//! statistical contract is pinned in
//! `rust/tests/test_fastpath_stats.rs`: moment/quantile agreement with
//! the exhaustive path on small n, and exact agreement of the expected
//! round time with `theory`'s closed-form `E[X_(k)]`.

use super::core::{EngineCore, EngineRun};
use super::gather::GatherPolicy;
use crate::grad::GradBackend;
use crate::policy::KPolicy;
use crate::rng::{Pcg64, Rng};
use crate::stats::OrderStatSampler;

/// Dedicated rng stream tag for the fastpath gather (arrivals +
/// identity swaps), disjoint from every stream in
/// [`RngStreams`](super::RngStreams).
const FASTPATH_STREAM: u64 = 0xFA5B;

/// The synchronous fastest-k discipline with O(k) rounds via direct
/// order-statistics sampling.
pub struct FastpathGather<'a> {
    backend: &'a mut dyn GradBackend,
    policy: &'a mut dyn KPolicy,
    sampler: &'a OrderStatSampler,
    k: usize,
    /// Fastpath draws live on their own stream so the opt-in cannot
    /// perturb any default-path sequence.
    rng: Pcg64,
    /// Ascending first-k arrival scratch, reused across rounds.
    arrivals: Vec<f64>,
    /// Persistent worker-identity permutation; the k leading slots are
    /// re-randomized each round with partial Fisher–Yates swaps.
    perm: Vec<u32>,
    partial: Vec<f32>,
    k_changes: Vec<(u64, f64, usize)>,
}

impl<'a> FastpathGather<'a> {
    /// Gather the `policy`-chosen k fastest of `backend`'s shards,
    /// sampling arrivals from `sampler` on stream `seed`.
    pub fn new(
        backend: &'a mut dyn GradBackend,
        policy: &'a mut dyn KPolicy,
        sampler: &'a OrderStatSampler,
        seed: u64,
    ) -> Self {
        let n = backend.n_shards();
        let d = backend.dim();
        assert_eq!(
            sampler.n(),
            n,
            "sampler sized for {} workers, backend has {n}",
            sampler.n()
        );
        assert!(n <= u32::MAX as usize, "fastpath identity array is u32");
        Self {
            backend,
            policy,
            sampler,
            k: 1,
            rng: Pcg64::seed_stream(seed, FASTPATH_STREAM),
            arrivals: Vec::new(),
            perm: (0..n as u32).collect(),
            partial: vec![0.0f32; d],
            k_changes: Vec::new(),
        }
    }
}

impl GatherPolicy for FastpathGather<'_> {
    fn initial_k(&self) -> usize {
        self.k
    }

    fn start(&mut self, _core: &mut EngineCore) {
        let n = self.backend.n_shards();
        self.k = self.policy.initial_k().min(n).max(1);
    }

    fn step(&mut self, core: &mut EngineCore) -> bool {
        let n = self.backend.n_shards();
        let j = core.steps;
        if j >= core.cfg.max_steps
            || (core.cfg.max_time > 0.0 && core.t >= core.cfg.max_time)
        {
            return false;
        }
        self.backend.on_iteration(j);
        // (1) broadcast w_j. The fastpath contract (enforced by config
        // validation) pins the channel to the free default, so this only
        // meters bytes; the arrival times below ARE the response times.
        let _down_bytes = core.broadcast_round();
        // (2) O(k): the k-th order statistic of n i.i.d. delays, sampled
        // directly instead of drawing and selecting over all n.
        self.sampler.sample_first_k(self.k, &mut self.arrivals, &mut self.rng);
        let round_time = self.arrivals[self.k - 1];
        core.t += round_time;
        // (2b) responder identities: a uniform k-subset via k partial
        // Fisher–Yates swaps on the persistent permutation.
        for i in 0..self.k {
            let swap =
                i + self.rng.next_below((n - i) as u64) as usize;
            self.perm.swap(i, swap);
        }
        // (3) aggregate the k sampled responders, shard by shard (the
        // huge-n regime this gather exists for is exactly where an
        // O(n·d) batched buffer is unaffordable).
        core.zero_g();
        for i in 0..self.k {
            let worker = self.perm[i] as usize;
            self.backend.partial_grad(
                worker,
                &core.w_view,
                &mut self.partial,
            );
            core.accept_into_g(worker, &self.partial);
        }
        // (4, 5) shared round tail: mean-scale + SGD + policy feedback +
        // recording, identical to the exhaustive gather.
        self.k = core.finish_fastest_k_round(
            j,
            n,
            self.k,
            &mut *self.policy,
            &mut self.k_changes,
        );
        true
    }

    fn finish(&mut self, core: &mut EngineCore) {
        core.record_final(core.steps, self.k);
    }

    fn annotate(&mut self, run: &mut EngineRun) {
        run.k_changes = std::mem::take(&mut self.k_changes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommChannel;
    use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
    use crate::engine::{EngineConfig, RngStreams, RoundEngine};
    use crate::grad::NativeBackend;
    use crate::model::LinRegProblem;
    use crate::policy::FixedK;

    #[test]
    fn fastpath_discipline_trains_the_model() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 200, d: 10, ..Default::default() },
            3,
        );
        let problem = LinRegProblem::new(&ds);
        let mut backend = NativeBackend::new(Shards::partition(&ds, 10));
        let sampler = OrderStatSampler::exponential(10, 1.0);
        let mut policy = FixedK::new(5);
        let mut channel = CommChannel::dense(10);
        let mut eval = |w: &[f32]| problem.error(w);
        let cfg = EngineConfig {
            eta: 0.002,
            momentum: 0.0,
            max_steps: 400,
            max_time: 0.0,
            seed: 1,
            record_stride: 50,
            intra_jobs: 1,
        };
        let delays = sampler_delays();
        let core = EngineCore::new(
            "fastpath",
            &mut channel,
            &delays,
            &mut eval,
            &vec![0.0f32; 10],
            cfg,
            RngStreams::sync(1),
        );
        let mut gather =
            FastpathGather::new(&mut backend, &mut policy, &sampler, 1);
        let run = RoundEngine::new(core).run(&mut gather);
        assert_eq!(run.steps, 400);
        assert!(run.total_time > 0.0);
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 1e-2, "{first} -> {last}");
        assert!(!run.diverged);
    }

    /// The core still wants a delay model reference (for its unused sync
    /// stream); the fastpath never samples it.
    fn sampler_delays() -> crate::straggler::ExponentialDelays {
        crate::straggler::ExponentialDelays::new(1.0)
    }

    #[test]
    fn identity_swaps_cover_all_workers_uniformly() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 160, d: 4, ..Default::default() },
            7,
        );
        let problem = LinRegProblem::new(&ds);
        let mut backend = NativeBackend::new(Shards::partition(&ds, 8));
        let sampler = OrderStatSampler::exponential(8, 1.0);
        let mut policy = FixedK::new(3);
        let mut channel = CommChannel::dense(8);
        let mut eval = |w: &[f32]| problem.error(w);
        let cfg = EngineConfig {
            eta: 0.001,
            momentum: 0.0,
            max_steps: 500,
            max_time: 0.0,
            seed: 9,
            record_stride: 100,
            intra_jobs: 1,
        };
        let delays = sampler_delays();
        let core = EngineCore::new(
            "fastpath",
            &mut channel,
            &delays,
            &mut eval,
            &vec![0.0f32; 4],
            cfg,
            RngStreams::sync(9),
        );
        let mut gather =
            FastpathGather::new(&mut backend, &mut policy, &sampler, 9);
        let run = RoundEngine::new(core).run(&mut gather);
        assert_eq!(run.steps, 500);
        // Over 500 rounds of k = 3 every worker must respond sometimes;
        // the permutation keeps all 8 identities alive.
        let mut seen: Vec<u32> = gather.perm.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..8).collect::<Vec<u32>>());
    }
}
