//! The O(k) order-statistics fast path for synchronous fastest-k rounds.
//!
//! [`FastestKGather`](super::FastestKGather) prices all n worker
//! responses every round and quickselects the k fastest — O(n) rng draws
//! and O(n) comparisons per step, which caps experiments at n in the
//! thousands. For class-heterogeneous delay models (i.i.d. *within* each
//! class) the round outcome depends on the delays only through (a) the
//! ascending first-k response times and (b) *which* k workers respond —
//! and both can be sampled directly:
//!
//! * the merged ascending arrival prefix comes from
//!   [`ClassOrderSampler`] in O(k · classes): each class's own order
//!   statistics are drawn lazily (Rényi spacings for the exponential
//!   family, conditional-uniform inverse CDF otherwise), shifted by the
//!   class's **constant uplink delay** (latency + bytes/bandwidth of the
//!   round's fixed-size message — constant within a uniform-per-class
//!   link, so it shifts the class's order statistics exactly), and
//!   k-way-merged;
//! * within the winning class the responder identities are exchangeable,
//!   so each merged arrival draws its worker with one partial
//!   Fisher–Yates swap over the class's persistent member permutation (a
//!   uniform subset of a permuted range is still uniform);
//! * a uniform broadcast download constant shifts all arrivals equally,
//!   and the shared O(k) [`IngressModel::round_completion`] FIFO chain
//!   over the merged prefix prices master-ingress contention identically
//!   to the exhaustive path.
//!
//! The result is an O(k · classes + k·d) round — independent of n except
//! for the one-time O(n) identity arrays — making the ROADMAP's n = 10⁶
//! sync round a few microseconds of sampling instead of 10⁶ draws, now
//! including priced uplinks, slow worker classes, and finite FIFO
//! ingress.
//!
//! **Contract: distributional, not bitwise.** The fast path consumes a
//! different number of rng draws (≈2k, on its own dedicated stream) than
//! the exhaustive gather (n per round on the sync delay stream), so
//! trajectories differ draw-by-draw while every round-time and
//! worker-subset *distribution* is exactly the law of the exhaustive
//! path. That is why it is opt-in (`[run] fastpath` / `--fastpath`,
//! off by default — all existing trajectories stay bit-identical) and
//! why `coordinator` only enables it for configs whose response times
//! decompose into class order statistics plus per-class constants (see
//! `ExperimentConfig::validate` for the per-feature gates: PS ingress,
//! per-worker heterogeneous downlinks, error feedback, transient
//! bimodal straggling, traces remain exhaustive-only). The statistical
//! contract is pinned in `rust/tests/test_fastpath_stats.rs`:
//! moment/quantile agreement with the exhaustive priced-comm path on
//! small n, and exact agreement of the expected round time with
//! `theory`'s closed-form `E[X_(k)]`.
//!
//! [`IngressModel::round_completion`]: crate::comm::IngressModel::round_completion

use super::core::{EngineCore, EngineRun};
use super::gather::GatherPolicy;
use crate::grad::GradBackend;
use crate::policy::KPolicy;
use crate::rng::{Pcg64, Rng};
use crate::stats::{ClassOrderSampler, OrderStatSampler};

/// Dedicated rng stream tag for the fastpath gather (arrivals +
/// identity swaps), disjoint from every stream in
/// [`RngStreams`](super::RngStreams).
const FASTPATH_STREAM: u64 = 0xFA5B;

/// The synchronous fastest-k discipline with O(k · classes) rounds via
/// direct order-statistics sampling over homogeneous worker classes.
pub struct FastpathGather<'a> {
    backend: &'a mut dyn GradBackend,
    policy: &'a mut dyn KPolicy,
    /// Merged per-class arrival sampler (owns per-class stream scratch).
    sampler: ClassOrderSampler,
    /// Per-class persistent worker-identity permutations; each round the
    /// leading slots of the winning classes are re-randomized with
    /// partial Fisher–Yates swaps. The class → worker-id mapping lives
    /// here, so the sampler stays pure statistics.
    members: Vec<Vec<u32>>,
    /// Per-class count of identities drawn this round.
    taken: Vec<usize>,
    k: usize,
    /// Fastpath draws live on their own stream so the opt-in cannot
    /// perturb any default-path sequence.
    rng: Pcg64,
    /// Merged ascending first-k arrival scratch, reused across rounds.
    arrivals: Vec<f64>,
    /// Per-arrival winning class, aligned with `arrivals`.
    class_ids: Vec<u32>,
    partial: Vec<f32>,
    k_changes: Vec<(u64, f64, usize)>,
}

impl<'a> FastpathGather<'a> {
    /// Gather the `policy`-chosen k fastest of `backend`'s shards:
    /// arrivals merged from `sampler`'s classes, identities drawn from
    /// `members` (one worker-id list per class, same order and sizes as
    /// the sampler's classes, disjoint and covering `0..n`), rng on
    /// stream `seed`.
    pub fn new(
        backend: &'a mut dyn GradBackend,
        policy: &'a mut dyn KPolicy,
        sampler: ClassOrderSampler,
        members: Vec<Vec<u32>>,
        seed: u64,
    ) -> Self {
        let n = backend.n_shards();
        let d = backend.dim();
        assert_eq!(
            sampler.n(),
            n,
            "sampler sized for {} workers, backend has {n}",
            sampler.n()
        );
        assert!(n <= u32::MAX as usize, "fastpath identity array is u32");
        assert_eq!(
            members.len(),
            sampler.classes(),
            "need one member list per class"
        );
        for (c, m) in members.iter().enumerate() {
            assert_eq!(
                m.len(),
                sampler.class_size(c),
                "class {c} has {} members but the sampler says {}",
                m.len(),
                sampler.class_size(c)
            );
        }
        let taken = vec![0usize; members.len()];
        Self {
            backend,
            policy,
            sampler,
            members,
            taken,
            k: 1,
            rng: Pcg64::seed_stream(seed, FASTPATH_STREAM),
            arrivals: Vec::new(),
            class_ids: Vec::new(),
            partial: vec![0.0f32; d],
            k_changes: Vec::new(),
        }
    }

    /// The homogeneous case: one free-link class covering all shards —
    /// PR 8's i.i.d. fastpath, which this constructor reproduces
    /// draw-for-draw (k arrival draws then k swap draws per round).
    pub fn iid(
        backend: &'a mut dyn GradBackend,
        policy: &'a mut dyn KPolicy,
        sampler: OrderStatSampler,
        seed: u64,
    ) -> Self {
        let n = sampler.n();
        assert!(n <= u32::MAX as usize, "fastpath identity array is u32");
        let members = vec![(0..n as u32).collect()];
        Self::new(
            backend,
            policy,
            ClassOrderSampler::single(sampler),
            members,
            seed,
        )
    }
}

impl GatherPolicy for FastpathGather<'_> {
    fn initial_k(&self) -> usize {
        self.k
    }

    fn start(&mut self, _core: &mut EngineCore) {
        let n = self.backend.n_shards();
        self.k = self.policy.initial_k().min(n).max(1);
    }

    fn step(&mut self, core: &mut EngineCore) -> bool {
        let n = self.backend.n_shards();
        let j = core.steps;
        if j >= core.cfg.max_steps
            || (core.cfg.max_time > 0.0 && core.t >= core.cfg.max_time)
        {
            return false;
        }
        self.backend.on_iteration(j);
        // (1) broadcast w_j: meters downlink bytes. Config validation
        // pins the downlink to a uniform link, so the per-worker download
        // constant is one number that shifts every arrival equally
        // (order-preserving — the merge stays ascending).
        let down_bytes = core.broadcast_round();
        let down = core.download_const(0, down_bytes);
        // (2) O(k · classes): the merged ascending first-k response
        // times, each class's order statistics pre-shifted by its
        // constant uplink delay inside the sampler.
        self.sampler.sample_first_k(
            self.k,
            &mut self.arrivals,
            &mut self.class_ids,
            &mut self.rng,
        );
        if down != 0.0 {
            for a in self.arrivals.iter_mut() {
                *a += down;
            }
        }
        // (2b) master-ingress contention over the merged prefix — the
        // exact O(k) FIFO chain the exhaustive path runs (PS ingress is
        // gated off by config validation).
        let round_time = if core.ingress_unlimited() {
            self.arrivals[self.k - 1]
        } else {
            core.round_completion(&mut self.arrivals)
        };
        core.t += round_time;
        // (3) responder identities + aggregation, in merged arrival
        // order so per-worker comm accounting matches the exhaustive
        // acceptance order. Each arrival draws a uniform not-yet-taken
        // member of its winning class via one partial Fisher–Yates swap
        // on the class's persistent permutation (never reset: a uniform
        // subset of a permuted range is still uniform). Shard-by-shard —
        // the huge-n regime this gather exists for is exactly where an
        // O(n·d) batched buffer is unaffordable.
        for t in self.taken.iter_mut() {
            *t = 0;
        }
        core.zero_g();
        for i in 0..self.k {
            let c = self.class_ids[i] as usize;
            let m = &mut self.members[c];
            let t = self.taken[c];
            let swap =
                t + self.rng.next_below((m.len() - t) as u64) as usize;
            m.swap(t, swap);
            let worker = m[t] as usize;
            self.taken[c] = t + 1;
            self.backend.partial_grad(
                worker,
                &core.w_view,
                &mut self.partial,
            );
            core.accept_into_g(worker, &self.partial);
        }
        // (4, 5) shared round tail: mean-scale + SGD + policy feedback +
        // recording, identical to the exhaustive gather.
        self.k = core.finish_fastest_k_round(
            j,
            n,
            self.k,
            &mut *self.policy,
            &mut self.k_changes,
        );
        true
    }

    fn finish(&mut self, core: &mut EngineCore) {
        core.record_final(core.steps, self.k);
    }

    fn annotate(&mut self, run: &mut EngineRun) {
        run.k_changes = std::mem::take(&mut self.k_changes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{
        Broadcast, CommChannel, Dense, DownlinkMode, IngressModel,
        LinkModel, TopK,
    };
    use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
    use crate::engine::{EngineConfig, RngStreams, RoundEngine};
    use crate::grad::NativeBackend;
    use crate::model::LinRegProblem;
    use crate::policy::FixedK;

    #[test]
    fn fastpath_discipline_trains_the_model() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 200, d: 10, ..Default::default() },
            3,
        );
        let problem = LinRegProblem::new(&ds);
        let mut backend = NativeBackend::new(Shards::partition(&ds, 10));
        let sampler = OrderStatSampler::exponential(10, 1.0);
        let mut policy = FixedK::new(5);
        let mut channel = CommChannel::dense(10);
        let mut eval = |w: &[f32]| problem.error(w);
        let cfg = EngineConfig {
            eta: 0.002,
            momentum: 0.0,
            max_steps: 400,
            max_time: 0.0,
            seed: 1,
            record_stride: 50,
            intra_jobs: 1,
        };
        let delays = sampler_delays();
        let core = EngineCore::new(
            "fastpath",
            &mut channel,
            &delays,
            &mut eval,
            &vec![0.0f32; 10],
            cfg,
            RngStreams::sync(1),
        );
        let mut gather =
            FastpathGather::iid(&mut backend, &mut policy, sampler, 1);
        let run = RoundEngine::new(core).run(&mut gather);
        assert_eq!(run.steps, 400);
        assert!(run.total_time > 0.0);
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 1e-2, "{first} -> {last}");
        assert!(!run.diverged);
    }

    /// The core still wants a delay model reference (for its unused sync
    /// stream); the fastpath never samples it.
    fn sampler_delays() -> crate::straggler::ExponentialDelays {
        crate::straggler::ExponentialDelays::new(1.0)
    }

    #[test]
    fn identity_swaps_cover_all_workers_uniformly() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 160, d: 4, ..Default::default() },
            7,
        );
        let problem = LinRegProblem::new(&ds);
        let mut backend = NativeBackend::new(Shards::partition(&ds, 8));
        let sampler = OrderStatSampler::exponential(8, 1.0);
        let mut policy = FixedK::new(3);
        let mut channel = CommChannel::dense(8);
        let mut eval = |w: &[f32]| problem.error(w);
        let cfg = EngineConfig {
            eta: 0.001,
            momentum: 0.0,
            max_steps: 500,
            max_time: 0.0,
            seed: 9,
            record_stride: 100,
            intra_jobs: 1,
        };
        let delays = sampler_delays();
        let core = EngineCore::new(
            "fastpath",
            &mut channel,
            &delays,
            &mut eval,
            &vec![0.0f32; 4],
            cfg,
            RngStreams::sync(9),
        );
        let mut gather =
            FastpathGather::iid(&mut backend, &mut policy, sampler, 9);
        let run = RoundEngine::new(core).run(&mut gather);
        assert_eq!(run.steps, 500);
        // Over 500 rounds of k = 3 every worker must respond sometimes;
        // the member permutations keep all 8 identities alive.
        let mut seen: Vec<u32> =
            gather.members.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..8).collect::<Vec<u32>>());
    }

    #[test]
    fn heterogeneous_priced_round_trains_and_prices_comm() {
        // Two classes (6 fast + 2 slow-uplink workers), TopK uplink
        // without error feedback, priced uniform downlink, finite FIFO
        // ingress: the full generalized-fastpath surface in one round
        // loop. The clock must strictly exceed the free-comm arrival
        // time every round, and the byte meters must price exactly k
        // uploads + n downloads per round.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 160, d: 6, ..Default::default() },
            5,
        );
        let problem = LinRegProblem::new(&ds);
        let mut backend = NativeBackend::new(Shards::partition(&ds, 8));
        let mut policy = FixedK::new(4);
        let link = LinkModel::uniform_with_slow(8, 64.0, 0.05, 2, 8.0);
        let mut channel =
            CommChannel::new(Box::new(TopK::new(0.5)), link, false)
                .with_broadcast(Broadcast::new(
                    Box::new(Dense::new()),
                    LinkModel::uniform(8, 256.0, 0.0),
                    DownlinkMode::Full,
                ))
                .with_ingress(IngressModel::new(512.0));
        let msg = channel.message_bytes(6);
        let up_fast = channel.link_upload_delay(0, msg);
        let up_slow = channel.link_upload_delay(7, msg);
        assert!(up_slow > up_fast);
        let sampler = ClassOrderSampler::new(vec![
            (OrderStatSampler::exponential(6, 1.0), up_fast),
            (OrderStatSampler::exponential(2, 1.0), up_slow),
        ]);
        let members = vec![vec![0, 1, 2, 3, 4, 5], vec![6, 7]];
        let mut eval = |w: &[f32]| problem.error(w);
        let steps = 300u64;
        let cfg = EngineConfig {
            eta: 0.002,
            momentum: 0.0,
            max_steps: steps,
            max_time: 0.0,
            seed: 13,
            record_stride: 50,
            intra_jobs: 1,
        };
        let delays = sampler_delays();
        let core = EngineCore::new(
            "fastpath-hetero",
            &mut channel,
            &delays,
            &mut eval,
            &vec![0.0f32; 6],
            cfg,
            RngStreams::sync(13),
        );
        let mut gather = FastpathGather::new(
            &mut backend,
            &mut policy,
            sampler,
            members,
            13,
        );
        let run = RoundEngine::new(core).run(&mut gather);
        assert_eq!(run.steps, steps);
        // Every arrival carries at least the fast uplink constant plus
        // the downlink constant, and the finite ingress adds k service
        // times on top — per-round time is bounded below accordingly.
        let down = channel.download_delay(0, msg);
        assert!(run.total_time > steps as f64 * (up_fast + down));
        // Uplink meter: exactly k messages per round.
        assert_eq!(channel.stats.messages, steps * 4);
        assert_eq!(channel.stats.bytes_sent, steps * 4 * msg);
        // Training still converges under the priced stack.
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 0.1, "{first} -> {last}");
    }
}
