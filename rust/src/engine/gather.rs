//! Gather disciplines: how a round's worker responses become a model
//! update.
//!
//! A [`GatherPolicy`] drives the engine loop one step at a time through
//! the [`EngineCore`] primitives — it decides *which* responses count and
//! *when* the clock advances, while every mechanism (pricing, transmit,
//! apply, recording) stays in the core. The two simulator disciplines
//! live here:
//!
//! * [`FastestKGather`] — the paper's synchronous fastest-k round: price
//!   all n responses, select the k fastest, aggregate their gradients,
//!   one SGD step, feed the [`KPolicy`].
//! * [`StalenessGather`] — Dutta et al.'s fully-asynchronous comparator:
//!   an event per worker completion, each (possibly stale) gradient
//!   applied immediately with optional staleness damping.
//!
//! The threaded cluster's discipline (real threads as the delay source)
//! implements the same trait privately in
//! [`exec::cluster`](crate::exec). A new discipline is one more impl —
//! roughly 100 lines against the core's primitives — instead of a fourth
//! driver fork.

use super::core::{EngineCore, EngineRun};
use crate::comm::{DownlinkMode, IngressDiscipline, PsServer};
use crate::exec::scratch;
use crate::grad::GradBackend;
use crate::master::fastest_k_select;
use crate::policy::KPolicy;
use crate::sim::EventQueue;

/// A pluggable gather discipline driven by
/// [`RoundEngine::run`](super::RoundEngine::run).
pub trait GatherPolicy {
    /// The k column of the initial sample (called after
    /// [`GatherPolicy::start`]).
    fn initial_k(&self) -> usize;

    /// One-time setup: schedule initial work, snapshot state.
    fn start(&mut self, _core: &mut EngineCore) {}

    /// Advance one step (a round, or one event); `false` ends the run.
    fn step(&mut self, core: &mut EngineCore) -> bool;

    /// Post-loop bookkeeping (e.g. force the final sample).
    fn finish(&mut self, _core: &mut EngineCore) {}

    /// Move discipline-specific results (k switches, staleness, lateness)
    /// into the run.
    fn annotate(&mut self, _run: &mut EngineRun) {}
}

/// The synchronous fastest-k discipline over a simulated
/// [`GradBackend`].
pub struct FastestKGather<'a> {
    backend: &'a mut dyn GradBackend,
    policy: &'a mut dyn KPolicy,
    k: usize,
    delay_buf: Vec<f64>,
    idx_buf: Vec<usize>,
    /// Accepted-arrival scratch for the shared-ingress round clock.
    arrival_buf: Vec<f64>,
    partial: Vec<f32>,
    /// Batched-backend scratch (allocated lazily, and only on the batched
    /// aggregation path — shard-by-shard runs never pay the O(n·d)
    /// memory).
    all_buf: Option<Vec<f32>>,
    /// Per-responder gradient arena for the intra-parallel path (k·d,
    /// grown on demand through [`scratch`] so capacity persists across
    /// sweep specs; empty on the serial path, which streams through
    /// `partial` instead).
    arena: Vec<f32>,
    k_changes: Vec<(u64, f64, usize)>,
}

impl<'a> FastestKGather<'a> {
    /// Gather the `policy`-chosen k fastest of `backend`'s shards.
    pub fn new(
        backend: &'a mut dyn GradBackend,
        policy: &'a mut dyn KPolicy,
    ) -> Self {
        let n = backend.n_shards();
        let d = backend.dim();
        Self {
            backend,
            policy,
            k: 1,
            delay_buf: vec![0.0f64; n],
            idx_buf: Vec::with_capacity(n),
            arrival_buf: Vec::with_capacity(n),
            partial: vec![0.0f32; d],
            all_buf: None,
            arena: Vec::new(),
            k_changes: Vec::new(),
        }
    }
}

impl Drop for FastestKGather<'_> {
    fn drop(&mut self) {
        // Hand the arena back to the thread's scratch pool so the next
        // spec on this sweep worker reuses it (no-op when empty).
        scratch::give_f32(std::mem::take(&mut self.arena));
    }
}

impl GatherPolicy for FastestKGather<'_> {
    fn initial_k(&self) -> usize {
        self.k
    }

    fn start(&mut self, _core: &mut EngineCore) {
        let n = self.backend.n_shards();
        self.k = self.policy.initial_k().min(n).max(1);
    }

    fn step(&mut self, core: &mut EngineCore) -> bool {
        let n = self.backend.n_shards();
        let d = self.backend.dim();
        let j = core.steps;
        if j >= core.cfg.max_steps
            || (core.cfg.max_time > 0.0 && core.t >= core.cfg.max_time)
        {
            return false;
        }
        self.backend.on_iteration(j);
        // (1) downlink: broadcast w_j; every worker computes against the
        // decoded view and is charged its download before compute starts.
        let down_bytes = core.broadcast_round();
        // (2) response times (download + compute + upload) + fastest-k
        // selection.
        for (i, slot) in self.delay_buf.iter_mut().enumerate() {
            *slot = core.response_delay(j, i, down_bytes);
        }
        let (x_k, _) =
            fastest_k_select(&self.delay_buf, self.k, &mut self.idx_buf);
        // (2b) shared-ingress congestion: with finite master ingress the
        // k accepted uploads contend, so the round ends at the last
        // accepted message's ingress finish, not the k-th arrival. The
        // unlimited default skips the sort and keeps x_k bitwise.
        let round_time = if core.ingress_unlimited() {
            x_k
        } else {
            self.arrival_buf.clear();
            self.arrival_buf
                .extend(self.idx_buf[..self.k].iter().map(|&i| self.delay_buf[i]));
            core.round_completion(&mut self.arrival_buf)
        };
        core.t += round_time;

        // (3) aggregate the k fastest partial gradients — through the
        // batched path when the backend has one and k is past the
        // dispatch-cost crossover (~n/4, see GradBackend::all_grads),
        // else shard by shard. Each accepted gradient passes through the
        // channel (error feedback + compression + byte accounting).
        core.zero_g();
        let use_batched =
            self.backend.supports_all_grads() && 4 * self.k >= n;
        let mut batched = false;
        if use_batched {
            let buf =
                self.all_buf.get_or_insert_with(|| vec![0.0f32; n * d]);
            batched = self.backend.all_grads(&core.w_view, buf);
        }
        if batched {
            let buf = self
                .all_buf
                .as_ref()
                .expect("batched scratch allocated above");
            for &worker in &self.idx_buf[..self.k] {
                core.accept_into_g(worker, &buf[worker * d..(worker + 1) * d]);
            }
        } else if core.par.is_serial() || d == 0 {
            for &worker in &self.idx_buf[..self.k] {
                self.backend.partial_grad(
                    worker,
                    &core.w_view,
                    &mut self.partial,
                );
                core.accept_into_g(worker, &self.partial);
            }
        } else {
            // Intra-parallel two-phase round: every responder's partial
            // gradient lands in its own arena slice concurrently, then
            // the reduction walks the slices serially in the fixed
            // fastest-k responder order — the exact per-element sums and
            // comm-rng draw order of the serial loop above, so the two
            // paths are bitwise interchangeable.
            let kd = self.k * d;
            if self.arena.len() < kd {
                scratch::give_f32(std::mem::replace(
                    &mut self.arena,
                    scratch::take_f32(kd),
                ));
            }
            let arena = &mut self.arena[..kd];
            self.backend.partial_grads(
                &self.idx_buf[..self.k],
                &core.w_view,
                arena,
                core.par,
            );
            for (slot, &worker) in
                arena.chunks_exact(d).zip(&self.idx_buf[..self.k])
            {
                core.accept_into_g(worker, slot);
            }
        }
        // (4, 5) the shared round tail: mean-scale + SGD update + policy
        // feedback + recording, in exactly one place (engine/core.rs).
        self.k = core.finish_fastest_k_round(
            j,
            n,
            self.k,
            &mut *self.policy,
            &mut self.k_changes,
        );
        true
    }

    fn finish(&mut self, core: &mut EngineCore) {
        // Always record the end state.
        core.record_final(core.steps, self.k);
    }

    fn annotate(&mut self, run: &mut EngineRun) {
        run.k_changes = std::mem::take(&mut self.k_changes);
    }
}

/// Event payload of the asynchronous discipline: a worker's upload
/// arriving at the master, or (processor-sharing ingress only) a
/// tentative drain completion tagged with the epoch it was computed in.
#[derive(Debug, Clone, Copy, PartialEq)]
enum AsyncEv {
    /// Worker `i`'s upload reaches the master ingress.
    Arrive(usize),
    /// The oldest in-flight message finishes draining (stale if the
    /// active set changed since this epoch).
    Complete(u64),
}

/// The fully-asynchronous discipline: every worker computes against its
/// stale snapshot; each completion is applied immediately.
///
/// Ingress handling: the FIFO discipline keeps the historical running
/// `free`-chain (bitwise the pre-engine driver); the processor-sharing
/// discipline is simulated exactly by driving the shared
/// [`PsServer`] fluid drain with tentative completion events — each
/// arrival reshares the drain and invalidates the scheduled completion
/// by epoch, so per-update apply times reflect true PS. With unlimited
/// ingress both collapse to "apply at arrival".
pub struct StalenessGather<'a> {
    backend: &'a mut dyn GradBackend,
    damping: bool,
    queue: EventQueue<AsyncEv>,
    snapshots: Vec<Vec<f32>>,
    read_version: Vec<u64>,
    version: u64,
    staleness_sum: f64,
    g_raw: Vec<f32>,
    diverged: bool,
    /// True when the finite-ingress PS event machinery is active.
    use_ps: bool,
    /// The shared PS drain (tags are worker ids).
    ps: PsServer,
    ps_epoch: u64,
    ps_service: f64,
}

impl<'a> StalenessGather<'a> {
    /// Asynchronous SGD over `backend` with optional staleness damping
    /// (`η/(1 + staleness)` per update).
    pub fn new(backend: &'a mut dyn GradBackend, damping: bool) -> Self {
        let d = backend.dim();
        Self {
            backend,
            damping,
            queue: EventQueue::new(),
            snapshots: Vec::new(),
            read_version: Vec::new(),
            version: 0,
            staleness_sum: 0.0,
            g_raw: vec![0.0f32; d],
            diverged: false,
            use_ps: false,
            ps: PsServer::new(),
            ps_epoch: 0,
            ps_service: 0.0,
        }
    }

    /// Schedule the tentative completion of the oldest in-flight message
    /// under the current active set (equal sizes → oldest always
    /// completes first). Any later arrival bumps the epoch and
    /// supersedes it.
    fn ps_schedule_front(&mut self) {
        if let Some(t_complete) = self.ps.next_completion() {
            self.queue
                .schedule_at(t_complete, AsyncEv::Complete(self.ps_epoch));
        }
    }

    /// Apply worker `i`'s update at `t_apply`: decode, staleness-damped
    /// step, divergence guard, restart the worker through the priced
    /// downlink. Returns `false` when the run must stop.
    fn apply_update(
        &mut self,
        core: &mut EngineCore,
        i: usize,
        t_apply: f64,
    ) -> bool {
        core.t = t_apply;
        if core.cfg.max_time > 0.0 && t_apply > core.cfg.max_time {
            return false;
        }
        // Gradient at the worker's stale snapshot, shipped through the
        // channel (compression + error feedback + byte accounting). The
        // single-responder `partial_grads` lets a backend split the
        // back-projection by column panel under `--intra-jobs`; serial
        // it is exactly `partial_grad`.
        self.backend.partial_grads(
            &[i],
            &self.snapshots[i],
            &mut self.g_raw,
            core.par,
        );
        core.transmit(i, &self.g_raw);
        let staleness = self.version - self.read_version[i];
        let step = if self.damping {
            core.cfg.eta / (1.0 + staleness as f32)
        } else {
            core.cfg.eta
        };
        core.apply_decoded(step);
        self.version += 1;
        self.staleness_sum += staleness as f64;
        core.steps += 1;
        if core.trace_on() {
            core.trace_event(crate::trace::Event::Apply {
                step: core.steps,
                time: core.t,
                k: 1,
                staleness,
            });
        }
        if !core.model_is_finite() {
            self.diverged = true;
            core.record_diverged(core.steps, 1);
            return false;
        }

        // Worker restarts immediately: it downloads the fresh model
        // through the priced downlink (its snapshot becomes the decoded
        // view), then its next cycle covers download + compute + upload.
        // Delta mode streams one delta per update, so the worker replays
        // every delta appended since its last restart: staleness + 1
        // messages, one download each.
        let replay = match core.downlink_mode() {
            DownlinkMode::Full => 1,
            DownlinkMode::Delta => staleness + 1,
        };
        let (_, down_delay) =
            core.push_model_to(i, &mut self.snapshots[i], replay);
        self.read_version[i] = self.version;
        let dt = core.cycle_delay(core.steps, i, down_delay);
        self.queue.schedule_at(t_apply + dt, AsyncEv::Arrive(i));

        core.maybe_record(core.steps, 1);
        true
    }
}

impl GatherPolicy for StalenessGather<'_> {
    fn initial_k(&self) -> usize {
        1
    }

    fn start(&mut self, core: &mut EngineCore) {
        let n = self.backend.n_shards();
        self.snapshots = vec![core.w.clone(); n];
        self.read_version = vec![0u64; n];
        self.use_ps = !core.ingress_unlimited()
            && core.ingress_discipline() == IngressDiscipline::Ps;
        self.ps_service = core.ingress_service_time();
        for i in 0..n {
            // Workers know w0, so the initial dispatch carries no
            // download (the 0.0 download term is bitwise inert).
            let dt = core.cycle_delay(0, i, 0.0);
            self.queue.schedule_in(dt, AsyncEv::Arrive(i));
        }
    }

    fn step(&mut self, core: &mut EngineCore) -> bool {
        if core.steps >= core.cfg.max_steps {
            return false;
        }
        let ev = match self.queue.pop() {
            Some(e) => e,
            None => return false,
        };
        match ev.payload {
            AsyncEv::Arrive(i) if !self.use_ps => {
                // Congested FIFO ingress: the upload that *arrived* at
                // ev.time is applied once the master's NIC has served it.
                let t_apply = core.serve_ingress(i, ev.time);
                self.apply_update(core, i, t_apply)
            }
            AsyncEv::Arrive(i) => {
                // PS ingress: join the drain; the pending tentative
                // completion is now stale (one more message sharing).
                self.ps.advance(ev.time);
                self.ps.admit(i, self.ps_service);
                self.ps_epoch += 1;
                self.ps_schedule_front();
                true
            }
            AsyncEv::Complete(epoch) => {
                if epoch != self.ps_epoch {
                    return true; // superseded by a later arrival
                }
                self.ps.advance(ev.time);
                let i = self
                    .ps
                    .complete_front()
                    .expect("valid completion with empty PS server");
                self.ps_epoch += 1;
                self.ps_schedule_front();
                self.apply_update(core, i, ev.time)
            }
        }
    }

    fn finish(&mut self, core: &mut EngineCore) {
        if !self.diverged {
            core.record_final(core.steps, 1);
        }
    }

    fn annotate(&mut self, run: &mut EngineRun) {
        run.diverged = self.diverged;
        run.mean_staleness = if run.steps > 0 {
            self.staleness_sum / run.steps as f64
        } else {
            0.0
        };
    }
}
