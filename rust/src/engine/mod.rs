//! The event-driven round engine every training driver runs on.
//!
//! The paper's adaptive fastest-k policy, the async error-runtime
//! comparator (Dutta et al., arXiv 1803.01113), and the
//! communication-efficient adaptive follow-up (arXiv 2208.03134) are the
//! *same* simulation with different gather rules. This module is that
//! simulation, once:
//!
//! * [`EngineCore`] owns the per-round mechanics — model-broadcast
//!   pricing (downlink), worker compute-delay sampling, uplink
//!   compression + link pricing, shared-ingress clocks, the gradient
//!   apply, and metric recording — each in exactly one place.
//! * [`GatherPolicy`] is the pluggable discipline: [`FastestKGather`]
//!   (the paper's sync round), [`FastpathGather`] (the same round with
//!   O(k · classes) direct order-statistics sampling — per-class
//!   ascending streams shifted by priced uplink constants, k-way
//!   merged, then priced through the O(k) FIFO ingress chain — opt-in,
//!   distributionally but not bitwise equivalent; see
//!   `engine/fastpath.rs`), [`StalenessGather`] (fully async,
//!   staleness-aware, with exact processor-sharing ingress via
//!   completion events on the [`sim::EventQueue`](crate::sim)),
//!   [`CodedGather`] (redundant shard placement via a
//!   [`coding::CodingScheme`](crate::coding::CodingScheme); waits for
//!   the first decodable responder set and applies the exact full
//!   gradient), and the threaded cluster's private impls in
//!   [`exec`](crate::exec) (real threads reduced to a delay/gradient
//!   source, round-based and fully asynchronous).
//! * [`RoundEngine`] drives a core through a discipline and returns the
//!   uniform [`EngineRun`].
//!
//! The historical drivers — [`master::run_fastest_k_comm`],
//! [`async_sgd::run_async_comm`], and
//! [`exec::ThreadedCluster::run_with_comm`] — are thin adapters that
//! build a core + gather and delegate here; their default-channel
//! trajectories are preserved bit for bit (see
//! `rust/tests/test_engine_equivalence.rs`, which replays the
//! pre-engine loops as executable specifications; the coded path has
//! the same contract in `rust/tests/test_coded_equivalence.rs`). A new
//! gather discipline — another ingress model, heterogeneous links, a
//! new code — is one ~100-line [`GatherPolicy`] impl instead of a
//! driver fork: [`CodedGather`] retired the standalone coded driver
//! exactly this way.
//!
//! [`master::run_fastest_k_comm`]: crate::master::run_fastest_k_comm
//! [`async_sgd::run_async_comm`]: crate::async_sgd::run_async_comm
//! [`exec::ThreadedCluster::run_with_comm`]:
//!     crate::exec::ThreadedCluster::run_with_comm

mod coded;
mod core;
mod fastpath;
mod gather;

pub use self::coded::CodedGather;
pub use self::core::{
    CommStream, EngineConfig, EngineCore, EngineRun, RngStreams,
};
pub use self::fastpath::FastpathGather;
pub use self::gather::{FastestKGather, GatherPolicy, StalenessGather};

/// Drives an [`EngineCore`] through a [`GatherPolicy`] to completion.
pub struct RoundEngine<'a> {
    core: EngineCore<'a>,
}

impl<'a> RoundEngine<'a> {
    /// Wrap a configured core.
    pub fn new(core: EngineCore<'a>) -> Self {
        Self { core }
    }

    /// Run the discipline to completion: start → initial sample → steps
    /// until the gather stops → final sample → annotated result.
    pub fn run(mut self, gather: &mut dyn GatherPolicy) -> EngineRun {
        gather.start(&mut self.core);
        self.core.record_initial(gather.initial_k());
        while gather.step(&mut self.core) {}
        gather.finish(&mut self.core);
        let mut run = self.core.into_run();
        gather.annotate(&mut run);
        run
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommChannel;
    use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
    use crate::grad::NativeBackend;
    use crate::model::LinRegProblem;
    use crate::policy::{FixedK, KPolicy};
    use crate::straggler::ExponentialDelays;

    fn setup() -> (NativeBackend, LinRegProblem) {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 200, d: 10, ..Default::default() },
            3,
        );
        let problem = LinRegProblem::new(&ds);
        (NativeBackend::new(Shards::partition(&ds, 10)), problem)
    }

    #[test]
    fn engine_runs_the_fastest_k_discipline_directly() {
        let (mut backend, problem) = setup();
        let delays = ExponentialDelays::new(1.0);
        let mut policy = FixedK::new(5);
        let mut channel = CommChannel::dense(10);
        let mut eval = |w: &[f32]| problem.error(w);
        let cfg = EngineConfig {
            eta: 0.002,
            momentum: 0.0,
            max_steps: 400,
            max_time: 0.0,
            seed: 1,
            record_stride: 50,
            intra_jobs: 1,
        };
        let core = EngineCore::new(
            policy.name(),
            &mut channel,
            &delays,
            &mut eval,
            &vec![0.0f32; 10],
            cfg,
            RngStreams::sync(1),
        );
        let mut gather = FastestKGather::new(&mut backend, &mut policy);
        let run = RoundEngine::new(core).run(&mut gather);
        assert_eq!(run.steps, 400);
        assert!(run.total_time > 0.0);
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 1e-2, "{first} -> {last}");
        assert!(run.k_changes.is_empty());
        assert!(!run.diverged);
    }

    #[test]
    fn engine_runs_the_staleness_discipline_directly() {
        let (mut backend, problem) = setup();
        let delays = ExponentialDelays::new(1.0);
        let mut channel = CommChannel::dense(10);
        let mut eval = |w: &[f32]| problem.error(w);
        let cfg = EngineConfig {
            eta: 0.0005,
            momentum: 0.0,
            max_steps: 2000,
            max_time: 0.0,
            seed: 2,
            record_stride: 200,
            intra_jobs: 1,
        };
        let core = EngineCore::new(
            "async",
            &mut channel,
            &delays,
            &mut eval,
            &vec![0.0f32; 10],
            cfg,
            RngStreams::asynchronous(2),
        );
        let mut gather = StalenessGather::new(&mut backend, true);
        let run = RoundEngine::new(core).run(&mut gather);
        assert_eq!(run.steps, 2000);
        // With 10 concurrent workers, mean staleness ≈ 9.
        assert!(run.mean_staleness > 5.0, "{}", run.mean_staleness);
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 0.05, "{first} -> {last}");
    }

    #[test]
    fn ps_ingress_delays_async_applies_but_conserves_work() {
        use crate::comm::{IngressDiscipline, IngressModel};
        // 56-byte dense messages at 56 B/t: 1.0 service each. Under PS a
        // bunch of overlapping uploads all land near the bunch makespan,
        // so per-update apply times shift later than FIFO early-decodes;
        // the total update *rate* (work conservation) stays comparable.
        let delays = ExponentialDelays::new(1.0);
        let run_with = |disc: IngressDiscipline| {
            let (mut backend, problem) = setup();
            let mut channel = CommChannel::dense(10)
                .with_ingress(IngressModel::with_discipline(56.0, disc));
            let mut eval = |w: &[f32]| problem.error(w);
            let cfg = EngineConfig {
                eta: 0.0001,
                momentum: 0.0,
                max_steps: 1500,
                max_time: 0.0,
                seed: 5,
                record_stride: 500,
                intra_jobs: 1,
            };
            let core = EngineCore::new(
                "async",
                &mut channel,
                &delays,
                &mut eval,
                &vec![0.0f32; 10],
                cfg,
                RngStreams::asynchronous(5),
            );
            let mut gather = StalenessGather::new(&mut backend, true);
            RoundEngine::new(core).run(&mut gather)
        };
        let fifo = run_with(IngressDiscipline::Fifo);
        let ps = run_with(IngressDiscipline::Ps);
        assert_eq!(fifo.steps, ps.steps);
        // The saturated ingress bounds both rates near 1 update per time
        // unit; work conservation keeps the totals within a few services.
        let rel = (fifo.total_time - ps.total_time).abs()
            / fifo.total_time.max(1.0);
        assert!(
            rel < 0.05,
            "work conservation violated: fifo {} vs ps {}",
            fifo.total_time,
            ps.total_time
        );
        // But the trajectories genuinely differ: PS reshuffles apply
        // times, so the recorded series diverge.
        assert_ne!(
            fifo.recorder.samples(),
            ps.recorder.samples(),
            "PS must be observable in per-update apply times"
        );
        assert!(!fifo.diverged && !ps.diverged);
    }
}
