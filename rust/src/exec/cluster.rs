//! Threaded master/worker cluster with fastest-k gather.
//!
//! Communication-aware like the simulator: the master prices each
//! worker's download + upload from the channel's size models and folds
//! both into the injected virtual delay (the worker sleeps download +
//! compute + upload), broadcasts the *downlink view* of the model, and
//! decodes accepted gradients through the channel on receipt. With a
//! finite master-ingress capacity the round's virtual time is the
//! ingress completion of the accepted responses, not their max.
//!
//! The run loop is the round engine's: the cluster implements a private
//! [`GatherPolicy`](crate::engine::GatherPolicy) whose job is only to
//! dispatch jobs to the worker threads and gather fresh responses — all
//! pricing (broadcast, response delays, ingress clock), the SGD apply,
//! and recording go through the shared
//! [`EngineCore`](crate::engine::EngineCore), so the real threads are
//! reduced to a delay-and-gradient source feeding the same engine as
//! the simulators.

use crate::comm::CommChannel;
use crate::data::Shards;
use crate::engine::{
    EngineConfig, EngineCore, EngineRun, GatherPolicy, RngStreams,
    RoundEngine,
};
use crate::linalg::{gemv, gemv_t, Matrix};
use crate::metrics::Recorder;
use crate::policy::KPolicy;
use crate::straggler::DelayModel;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Threaded-run configuration.
#[derive(Debug, Clone)]
pub struct ThreadedConfig {
    /// Step size η.
    pub eta: f32,
    /// Iterations to run.
    pub max_iterations: u64,
    /// Seconds of real sleep per virtual delay unit (keep small: the
    /// threaded mode is a semantics demonstration, not a throughput test).
    pub time_scale: f64,
    /// Seed for the delay draws (same stream family as the simulator).
    pub seed: u64,
    /// Record stride.
    pub record_stride: u64,
}

impl Default for ThreadedConfig {
    fn default() -> Self {
        Self {
            eta: 5e-4,
            max_iterations: 200,
            time_scale: 1e-3,
            seed: 0,
            record_stride: 10,
        }
    }
}

/// Statistics from a threaded run.
pub struct ThreadedRunStats {
    /// Error-vs-(virtual)-time record.
    pub recorder: Recorder,
    /// Final model.
    pub w: Vec<f32>,
    /// Total virtual time (sum of per-iteration k-th response delays).
    pub virtual_time: f64,
    /// Total real wall-clock seconds.
    pub real_time: f64,
    /// Late (discarded) responses observed — wasted straggler work.
    pub late_responses: u64,
    /// Encoded bytes of all accepted gradient messages.
    pub bytes_sent: u64,
    /// Total upload time of accepted messages (virtual units).
    pub comm_time: f64,
    /// Encoded bytes of all model downloads (once per worker per round).
    pub bytes_down: u64,
    /// Total download time charged (virtual units).
    pub down_time: f64,
}

struct Job {
    generation: u64,
    w: Arc<Vec<f32>>,
    /// Injected virtual delay for this worker at this iteration.
    delay: f64,
}

struct Response {
    generation: u64,
    worker: usize,
    grad: Vec<f32>,
    /// Virtual delay echoed back.
    delay: f64,
}

/// A running cluster of worker threads pinned to their shards.
pub struct ThreadedCluster {
    job_txs: Vec<mpsc::Sender<Job>>,
    resp_rx: mpsc::Receiver<Response>,
    handles: Vec<std::thread::JoinHandle<()>>,
    n: usize,
    d: usize,
}

impl ThreadedCluster {
    /// Spawn one thread per shard. Each worker owns its `(X_i, y_i)` and
    /// computes real partial gradients with the native kernels.
    pub fn spawn(shards: &Shards, time_scale: f64) -> Self {
        let n = shards.n();
        let d = shards.x[0].cols();
        let (resp_tx, resp_rx) = mpsc::channel::<Response>();
        let mut job_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<Job>();
            job_txs.push(tx);
            let resp_tx = resp_tx.clone();
            let x: Matrix = shards.x[i].clone();
            let y: Vec<f32> = shards.y[i].clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(i, x, y, rx, resp_tx, time_scale);
            }));
        }
        Self { job_txs, resp_rx, handles, n, d }
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Run fastest-k SGD on the live cluster (exp(1) delays, free link).
    pub fn run_fastest_k(
        &mut self,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        assert_eq!(w0.len(), self.d);
        let delay_model = crate::straggler::ExponentialDelays::new(1.0);
        let mut channel = CommChannel::dense(self.n);
        self.run_inner(policy, w0, cfg, eval_error, &delay_model, &mut channel)
    }

    /// Run with an explicit delay model (free link).
    pub fn run_with_delays(
        &mut self,
        delays: &dyn DelayModel,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        let mut channel = CommChannel::dense(self.n);
        self.run_inner(policy, w0, cfg, eval_error, delays, &mut channel)
    }

    /// Run with an explicit delay model *and* comm channel: worker sleeps
    /// cover compute + upload, and accepted gradients are decoded through
    /// the channel (compression + error feedback) before aggregation.
    pub fn run_with_comm(
        &mut self,
        delays: &dyn DelayModel,
        channel: &mut CommChannel,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        self.run_inner(policy, w0, cfg, eval_error, delays, channel)
    }

    /// Build an engine core (threaded rng streams: delay stream shared
    /// with the simulator, per-worker compression streams) and run the
    /// cluster's gather discipline on it.
    fn run_inner(
        &mut self,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
        delays: &dyn DelayModel,
        channel: &mut CommChannel,
    ) -> ThreadedRunStats {
        let n = self.n;
        assert_eq!(
            channel.n(),
            n,
            "comm channel sized for {} workers, cluster has {n}",
            channel.n()
        );
        let start = Instant::now();
        let engine_cfg = EngineConfig {
            eta: cfg.eta,
            momentum: 0.0,
            max_steps: cfg.max_iterations,
            max_time: 0.0,
            seed: cfg.seed,
            record_stride: cfg.record_stride,
        };
        let core = EngineCore::new(
            format!("threaded/{}", policy.name()),
            channel,
            delays,
            eval_error,
            w0,
            engine_cfg,
            RngStreams::threaded(cfg.seed, n),
        );
        let mut gather = ThreadedGather {
            job_txs: &self.job_txs,
            resp_rx: &self.resp_rx,
            policy,
            n,
            k: 1,
            accepted_delays: Vec::with_capacity(n),
            late: 0,
            k_changes: Vec::new(),
        };
        let run = RoundEngine::new(core).run(&mut gather);
        ThreadedRunStats {
            recorder: run.recorder,
            w: run.w,
            virtual_time: run.total_time,
            real_time: start.elapsed().as_secs_f64(),
            late_responses: run.late_responses,
            bytes_sent: run.bytes_sent,
            comm_time: run.comm_time,
            bytes_down: run.bytes_down,
            down_time: run.down_time,
        }
    }
}

/// The cluster's gather discipline: real worker threads as the delay and
/// gradient source. Dispatch sends every worker its priced virtual delay
/// (the worker sleeps download + compute + upload, scaled); gathering
/// accepts the first k *fresh* responses and discards stragglers from
/// earlier generations. Everything priced or recorded goes through the
/// [`EngineCore`].
struct ThreadedGather<'a> {
    job_txs: &'a [mpsc::Sender<Job>],
    resp_rx: &'a mpsc::Receiver<Response>,
    policy: &'a mut dyn KPolicy,
    n: usize,
    k: usize,
    /// Accepted responses' virtual delays, for the congested clock.
    accepted_delays: Vec<f64>,
    late: u64,
    k_changes: Vec<(u64, f64, usize)>,
}

impl GatherPolicy for ThreadedGather<'_> {
    fn initial_k(&self) -> usize {
        self.k
    }

    fn start(&mut self, _core: &mut EngineCore) {
        self.k = self.policy.initial_k().clamp(1, self.n);
    }

    fn step(&mut self, core: &mut EngineCore) -> bool {
        let j = core.steps;
        if j >= core.cfg.max_steps {
            return false;
        }
        // Broadcast w_j through the priced downlink: workers compute at
        // the decoded view, and each injected delay covers the download,
        // the compute, and the priced upload of the coming response.
        let down_bytes = core.broadcast_round();
        let w_shared = Arc::new(core.w_view.clone());
        for (i, tx) in self.job_txs.iter().enumerate() {
            let delay = core.response_delay(j, i, down_bytes);
            tx.send(Job {
                generation: j,
                w: Arc::clone(&w_shared),
                delay,
            })
            .expect("worker died");
        }

        // Gather the fastest k fresh responses, decoding each through
        // the channel.
        core.zero_g();
        let mut got = 0usize;
        let mut iter_vt = 0.0f64;
        self.accepted_delays.clear();
        while got < self.k {
            let resp = self.resp_rx.recv().expect("cluster closed");
            if resp.generation != j {
                self.late += 1; // straggler from an earlier round: discard
                continue;
            }
            got += 1;
            iter_vt = iter_vt.max(resp.delay);
            self.accepted_delays.push(resp.delay);
            core.accept_into_g(resp.worker, &resp.grad);
        }
        // Congested clock: with finite ingress the round's virtual time
        // is the ingress completion of the accepted uploads (real
        // arrival order is thread-nondeterministic, so the virtual
        // order is by virtual delay — sorted inside).
        if !core.ingress_unlimited() {
            iter_vt = core.round_completion(&mut self.accepted_delays);
        }
        core.t += iter_vt;

        // The shared round tail: mean-scale + SGD update + policy
        // feedback + recording, in exactly one place (engine/core.rs).
        self.k = core.finish_fastest_k_round(
            j,
            self.n,
            self.k,
            &mut *self.policy,
            &mut self.k_changes,
        );
        true
    }

    fn annotate(&mut self, run: &mut EngineRun) {
        run.late_responses = self.late;
        run.k_changes = std::mem::take(&mut self.k_changes);
    }
}

impl Drop for ThreadedCluster {
    fn drop(&mut self) {
        self.job_txs.clear(); // close job channels; workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    _id: usize,
    x: Matrix,
    y: Vec<f32>,
    rx: mpsc::Receiver<Job>,
    tx: mpsc::Sender<Response>,
    time_scale: f64,
) {
    let s = x.rows();
    let d = x.cols();
    let mut resid = vec![0.0f32; s];
    let id = _id;
    while let Ok(job) = rx.recv() {
        // Real compute: partial gradient of this worker's shard.
        let mut grad = vec![0.0f32; d];
        gemv(1.0, &x, &job.w, 0.0, &mut resid);
        for (r, yv) in resid.iter_mut().zip(&y) {
            *r -= *yv;
        }
        gemv_t(1.0 / s as f32, &x, &resid, 0.0, &mut grad);
        // Injected straggling.
        if job.delay > 0.0 && time_scale > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(job.delay * time_scale));
        }
        if tx
            .send(Response {
                generation: job.generation,
                worker: id,
                grad,
                delay: job.delay,
            })
            .is_err()
        {
            break; // master gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticConfig, SyntheticDataset};
    use crate::model::LinRegProblem;
    use crate::policy::FixedK;

    #[test]
    fn threaded_training_descends() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 8, ..Default::default() },
            21,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 6);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(3);
        let cfg = ThreadedConfig {
            eta: 0.002,
            max_iterations: 150,
            time_scale: 1e-5,
            seed: 5,
            record_stride: 25,
        };
        let run = cluster.run_fastest_k(
            &mut policy,
            &vec![0.0; 8],
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 0.05, "{first} -> {last}");
        assert!(run.virtual_time > 0.0);
        assert!(run.real_time > 0.0);
    }

    #[test]
    fn late_responses_are_discarded_not_applied() {
        // k=1 of 4: three responses per round arrive late and must be
        // counted as waste.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 40, d: 4, ..Default::default() },
            22,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 4);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(1);
        let cfg = ThreadedConfig {
            eta: 0.001,
            max_iterations: 30,
            time_scale: 1e-5,
            seed: 6,
            record_stride: 10,
        };
        let run = cluster.run_fastest_k(
            &mut policy,
            &vec![0.0; 4],
            &cfg,
            &mut |w| problem.error(w),
        );
        assert!(
            run.late_responses > 0,
            "with k=1 of 4, late responses are inevitable"
        );
    }

    #[test]
    fn bidirectional_channel_slows_the_virtual_clock_on_the_live_cluster() {
        use crate::comm::{
            Broadcast, CommChannel, Dense, DownlinkMode, IngressModel,
            LinkModel,
        };
        use crate::straggler::ExponentialDelays;
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 40, d: 4, ..Default::default() },
            24,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 4);
        let delays = ExponentialDelays::new(1.0);
        let cfg = ThreadedConfig {
            eta: 0.001,
            max_iterations: 40,
            time_scale: 1e-5,
            seed: 8,
            record_stride: 10,
        };
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(2);
        // d=4 -> 32-byte messages both ways; downlink 32 B/t (+1.0 per
        // round per worker) and ingress 32 B/t (+1.0 serialization per
        // accepted upload).
        let mut channel = CommChannel::dense(4)
            .with_broadcast(Broadcast::new(
                Box::new(Dense::new()),
                LinkModel::uniform(4, 32.0, 0.0),
                DownlinkMode::Full,
            ))
            .with_ingress(IngressModel::new(32.0));
        let run = cluster.run_with_comm(
            &delays,
            &mut channel,
            &mut policy,
            &vec![0.0; 4],
            &cfg,
            &mut |w| problem.error(w),
        );
        // Deterministic accounting regardless of thread scheduling:
        // every round all 4 workers download one 32-byte model at 1.0
        // each, and every round's clock is at least download (1.0) +
        // two serialized ingress services (2.0).
        assert_eq!(run.bytes_down, 40 * 4 * 32);
        assert!((run.down_time - 40.0 * 4.0).abs() < 1e-9);
        assert!(
            run.virtual_time >= 40.0 * 3.0 - 1e-9,
            "congested clock too small: {}",
            run.virtual_time
        );
    }

    #[test]
    fn comm_channel_meters_bytes_and_decodes_on_the_live_cluster() {
        use crate::comm::{CommChannel, LinkModel, TopK};
        use crate::straggler::ExponentialDelays;
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 8, ..Default::default() },
            23,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 6);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(3);
        let cfg = ThreadedConfig {
            eta: 0.002,
            max_iterations: 200,
            time_scale: 1e-5,
            seed: 7,
            record_stride: 50,
        };
        let delays = ExponentialDelays::new(1.0);
        let mut channel = CommChannel::new(
            Box::new(TopK::new(0.5)),
            LinkModel::uniform(6, 1000.0, 0.0),
            true,
        );
        let run = cluster.run_with_comm(
            &delays,
            &mut channel,
            &mut policy,
            &vec![0.0; 8],
            &cfg,
            &mut |w| problem.error(w),
        );
        // top-4-of-8 as (index,value) pairs: 16 + 32 = 48 bytes/message.
        assert_eq!(run.bytes_sent, 200 * 3 * 48);
        assert!(run.comm_time > 0.0);
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        // Compression slows convergence vs the dense cluster test; the
        // point here is that feedback keeps it descending.
        assert!(last < first * 0.2, "{first} -> {last}");
    }
}
