//! Threaded master/worker cluster with fastest-k gather.
//!
//! Communication-aware like the simulator: the master prices each
//! worker's download + upload from the channel's size models and folds
//! both into the injected virtual delay (the worker sleeps download +
//! compute + upload), broadcasts the *downlink view* of the model, and
//! decodes accepted gradients through the channel on receipt. With a
//! finite master-ingress capacity the round's virtual time is the FIFO
//! ingress completion of the accepted responses, not their max.

use crate::comm::CommChannel;
use crate::data::Shards;
use crate::linalg::{dot, gemv, gemv_t, Matrix};
use crate::metrics::{Recorder, Sample};
use crate::policy::{IterationObs, KPolicy};
use crate::rng::Pcg64;
use crate::straggler::DelayModel;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Threaded-run configuration.
#[derive(Debug, Clone)]
pub struct ThreadedConfig {
    /// Step size η.
    pub eta: f32,
    /// Iterations to run.
    pub max_iterations: u64,
    /// Seconds of real sleep per virtual delay unit (keep small: the
    /// threaded mode is a semantics demonstration, not a throughput test).
    pub time_scale: f64,
    /// Seed for the delay draws (same stream family as the simulator).
    pub seed: u64,
    /// Record stride.
    pub record_stride: u64,
}

impl Default for ThreadedConfig {
    fn default() -> Self {
        Self {
            eta: 5e-4,
            max_iterations: 200,
            time_scale: 1e-3,
            seed: 0,
            record_stride: 10,
        }
    }
}

/// Statistics from a threaded run.
pub struct ThreadedRunStats {
    /// Error-vs-(virtual)-time record.
    pub recorder: Recorder,
    /// Final model.
    pub w: Vec<f32>,
    /// Total virtual time (sum of per-iteration k-th response delays).
    pub virtual_time: f64,
    /// Total real wall-clock seconds.
    pub real_time: f64,
    /// Late (discarded) responses observed — wasted straggler work.
    pub late_responses: u64,
    /// Encoded bytes of all accepted gradient messages.
    pub bytes_sent: u64,
    /// Total upload time of accepted messages (virtual units).
    pub comm_time: f64,
    /// Encoded bytes of all model downloads (once per worker per round).
    pub bytes_down: u64,
    /// Total download time charged (virtual units).
    pub down_time: f64,
}

struct Job {
    generation: u64,
    w: Arc<Vec<f32>>,
    /// Injected virtual delay for this worker at this iteration.
    delay: f64,
}

struct Response {
    generation: u64,
    worker: usize,
    grad: Vec<f32>,
    /// Virtual delay echoed back.
    delay: f64,
}

/// A running cluster of worker threads pinned to their shards.
pub struct ThreadedCluster {
    job_txs: Vec<mpsc::Sender<Job>>,
    resp_rx: mpsc::Receiver<Response>,
    handles: Vec<std::thread::JoinHandle<()>>,
    n: usize,
    d: usize,
}

impl ThreadedCluster {
    /// Spawn one thread per shard. Each worker owns its `(X_i, y_i)` and
    /// computes real partial gradients with the native kernels.
    pub fn spawn(shards: &Shards, time_scale: f64) -> Self {
        let n = shards.n();
        let d = shards.x[0].cols();
        let (resp_tx, resp_rx) = mpsc::channel::<Response>();
        let mut job_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<Job>();
            job_txs.push(tx);
            let resp_tx = resp_tx.clone();
            let x: Matrix = shards.x[i].clone();
            let y: Vec<f32> = shards.y[i].clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(i, x, y, rx, resp_tx, time_scale);
            }));
        }
        Self { job_txs, resp_rx, handles, n, d }
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Run fastest-k SGD on the live cluster (exp(1) delays, free link).
    pub fn run_fastest_k(
        &mut self,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        assert_eq!(w0.len(), self.d);
        let start = Instant::now();
        let mut rng = Pcg64::seed_stream(cfg.seed, 0xFA57); // same as sim
        let delay_model = crate::straggler::ExponentialDelays::new(1.0);
        let mut channel = CommChannel::dense(self.n);
        self.run_inner(
            policy,
            w0,
            cfg,
            eval_error,
            &delay_model,
            &mut channel,
            &mut rng,
            start,
        )
    }

    /// Run with an explicit delay model (free link).
    pub fn run_with_delays(
        &mut self,
        delays: &dyn DelayModel,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        let start = Instant::now();
        let mut rng = Pcg64::seed_stream(cfg.seed, 0xFA57);
        let mut channel = CommChannel::dense(self.n);
        self.run_inner(
            policy, w0, cfg, eval_error, delays, &mut channel, &mut rng, start,
        )
    }

    /// Run with an explicit delay model *and* comm channel: worker sleeps
    /// cover compute + upload, and accepted gradients are decoded through
    /// the channel (compression + error feedback) before aggregation.
    pub fn run_with_comm(
        &mut self,
        delays: &dyn DelayModel,
        channel: &mut CommChannel,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        let start = Instant::now();
        let mut rng = Pcg64::seed_stream(cfg.seed, 0xFA57);
        self.run_inner(
            policy, w0, cfg, eval_error, delays, channel, &mut rng, start,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn run_inner(
        &mut self,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
        delays: &dyn DelayModel,
        channel: &mut CommChannel,
        rng: &mut Pcg64,
        start: Instant,
    ) -> ThreadedRunStats {
        let n = self.n;
        let d = self.d;
        assert_eq!(
            channel.n(),
            n,
            "comm channel sized for {} workers, cluster has {n}",
            channel.n()
        );
        // One compression stream per worker: responses are gathered in
        // nondeterministic arrival order, so a single shared stream would
        // hand different draws to different workers across runs of the
        // same seed. Per-worker streams keep stochastic compressors
        // (QSGD/RandK) reproducible regardless of thread scheduling.
        let mut comm_rngs: Vec<Pcg64> = (0..n)
            .map(|i| Pcg64::seed_stream(cfg.seed, 0xC046_0000 + i as u64))
            .collect();
        // Downlink encoder stream (the broadcast is master-side and
        // single-threaded, so one stream suffices and stays reproducible).
        let mut bcast_rng = Pcg64::seed_stream(cfg.seed, 0xB04F);
        let bytes0 = channel.stats.bytes_sent;
        let comm_t0 = channel.stats.comm_time;
        let down0 = channel.stats.bytes_down;
        let down_t0 = channel.stats.down_time;
        let mut w = w0.to_vec();
        // Workers' model view: what the downlink broadcast reconstructs
        // (bitwise `w` on the default dense downlink).
        let mut w_view = w0.to_vec();
        let mut g = vec![0.0f32; d];
        let mut g_prev = vec![0.0f32; d];
        let mut decoded = vec![0.0f32; d];
        let mut k = policy.initial_k().clamp(1, n);
        let mut vt = 0.0f64;
        let mut late = 0u64;
        // Zero-cost links price messages at exactly 0.0 — no branch needed.
        let msg_bytes = channel.message_bytes(d);
        let ingress = *channel.ingress();
        // Accepted responses' virtual delays, for the congested clock.
        let mut accepted_delays: Vec<f64> = Vec::with_capacity(n);
        let mut recorder = Recorder::with_stride(
            format!("threaded/{}", policy.name()),
            cfg.record_stride,
        );
        recorder.push_forced(Sample {
            iteration: 0,
            time: 0.0,
            k,
            error: eval_error(&w),
            ..Default::default()
        });

        for j in 0..cfg.max_iterations {
            // Broadcast w_j through the priced downlink: workers compute
            // at the decoded view, and each injected delay covers the
            // download, the compute, and the priced upload of the coming
            // response.
            let down_bytes =
                channel.broadcast_model(&w, &mut w_view, &mut bcast_rng);
            let w_shared = Arc::new(w_view.clone());
            for (i, tx) in self.job_txs.iter().enumerate() {
                let delay = delays.sample(j, i, rng)
                    + channel.link_upload_delay(i, msg_bytes)
                    + channel.download_delay(i, down_bytes);
                tx.send(Job {
                    generation: j,
                    w: Arc::clone(&w_shared),
                    delay,
                })
                .expect("worker died");
            }

            // Gather the fastest k fresh responses, decoding each through
            // the channel.
            g.iter_mut().for_each(|v| *v = 0.0);
            let mut got = 0usize;
            let mut iter_vt = 0.0f64;
            accepted_delays.clear();
            while got < k {
                let resp = self.resp_rx.recv().expect("cluster closed");
                if resp.generation != j {
                    late += 1; // straggler from an earlier round: discard
                    continue;
                }
                got += 1;
                iter_vt = iter_vt.max(resp.delay);
                accepted_delays.push(resp.delay);
                channel.transmit(
                    resp.worker,
                    &resp.grad,
                    &mut decoded,
                    &mut comm_rngs[resp.worker],
                );
                for (gv, pv) in g.iter_mut().zip(&decoded) {
                    *gv += *pv;
                }
            }
            // Congested clock: with finite ingress the round's virtual
            // time is the FIFO completion of the accepted uploads (real
            // arrival order is thread-nondeterministic, so the virtual
            // FIFO order is by virtual delay — sorted inside).
            if !ingress.is_unlimited() {
                iter_vt =
                    ingress.round_completion(&mut accepted_delays, msg_bytes);
            }
            let inv_k = 1.0 / k as f32;
            g.iter_mut().for_each(|v| *v *= inv_k);
            vt += iter_vt;

            for (wv, gv) in w.iter_mut().zip(&g) {
                *wv -= cfg.eta * *gv;
            }

            let inner = if j == 0 { None } else { Some(dot(&g, &g_prev)) };
            let obs = IterationObs {
                iteration: j,
                time: vt,
                k_used: k,
                grad_inner_prev: inner,
                grad_norm_sq: dot(&g, &g),
            };
            k = policy.next_k(&obs).clamp(1, n);
            std::mem::swap(&mut g, &mut g_prev);

            if (j + 1) % cfg.record_stride == 0 {
                recorder.push_forced(Sample {
                    iteration: j + 1,
                    time: vt,
                    k,
                    error: eval_error(&w),
                    bytes: channel.stats.bytes_sent - bytes0,
                    comm_time: channel.stats.comm_time - comm_t0,
                    bytes_down: channel.stats.bytes_down - down0,
                    down_time: channel.stats.down_time - down_t0,
                });
            }
        }

        ThreadedRunStats {
            recorder,
            w,
            virtual_time: vt,
            real_time: start.elapsed().as_secs_f64(),
            late_responses: late,
            bytes_sent: channel.stats.bytes_sent - bytes0,
            comm_time: channel.stats.comm_time - comm_t0,
            bytes_down: channel.stats.bytes_down - down0,
            down_time: channel.stats.down_time - down_t0,
        }
    }
}

impl Drop for ThreadedCluster {
    fn drop(&mut self) {
        self.job_txs.clear(); // close job channels; workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    _id: usize,
    x: Matrix,
    y: Vec<f32>,
    rx: mpsc::Receiver<Job>,
    tx: mpsc::Sender<Response>,
    time_scale: f64,
) {
    let s = x.rows();
    let d = x.cols();
    let mut resid = vec![0.0f32; s];
    let id = _id;
    while let Ok(job) = rx.recv() {
        // Real compute: partial gradient of this worker's shard.
        let mut grad = vec![0.0f32; d];
        gemv(1.0, &x, &job.w, 0.0, &mut resid);
        for (r, yv) in resid.iter_mut().zip(&y) {
            *r -= *yv;
        }
        gemv_t(1.0 / s as f32, &x, &resid, 0.0, &mut grad);
        // Injected straggling.
        if job.delay > 0.0 && time_scale > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(job.delay * time_scale));
        }
        if tx
            .send(Response {
                generation: job.generation,
                worker: id,
                grad,
                delay: job.delay,
            })
            .is_err()
        {
            break; // master gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticConfig, SyntheticDataset};
    use crate::model::LinRegProblem;
    use crate::policy::FixedK;

    #[test]
    fn threaded_training_descends() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 8, ..Default::default() },
            21,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 6);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(3);
        let cfg = ThreadedConfig {
            eta: 0.002,
            max_iterations: 150,
            time_scale: 1e-5,
            seed: 5,
            record_stride: 25,
        };
        let run = cluster.run_fastest_k(
            &mut policy,
            &vec![0.0; 8],
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 0.05, "{first} -> {last}");
        assert!(run.virtual_time > 0.0);
        assert!(run.real_time > 0.0);
    }

    #[test]
    fn late_responses_are_discarded_not_applied() {
        // k=1 of 4: three responses per round arrive late and must be
        // counted as waste.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 40, d: 4, ..Default::default() },
            22,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 4);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(1);
        let cfg = ThreadedConfig {
            eta: 0.001,
            max_iterations: 30,
            time_scale: 1e-5,
            seed: 6,
            record_stride: 10,
        };
        let run = cluster.run_fastest_k(
            &mut policy,
            &vec![0.0; 4],
            &cfg,
            &mut |w| problem.error(w),
        );
        assert!(
            run.late_responses > 0,
            "with k=1 of 4, late responses are inevitable"
        );
    }

    #[test]
    fn bidirectional_channel_slows_the_virtual_clock_on_the_live_cluster() {
        use crate::comm::{
            Broadcast, CommChannel, Dense, DownlinkMode, IngressModel,
            LinkModel,
        };
        use crate::straggler::ExponentialDelays;
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 40, d: 4, ..Default::default() },
            24,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 4);
        let delays = ExponentialDelays::new(1.0);
        let cfg = ThreadedConfig {
            eta: 0.001,
            max_iterations: 40,
            time_scale: 1e-5,
            seed: 8,
            record_stride: 10,
        };
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(2);
        // d=4 -> 32-byte messages both ways; downlink 32 B/t (+1.0 per
        // round per worker) and ingress 32 B/t (+1.0 serialization per
        // accepted upload).
        let mut channel = CommChannel::dense(4)
            .with_broadcast(Broadcast::new(
                Box::new(Dense::new()),
                LinkModel::uniform(4, 32.0, 0.0),
                DownlinkMode::Full,
            ))
            .with_ingress(IngressModel::new(32.0));
        let run = cluster.run_with_comm(
            &delays,
            &mut channel,
            &mut policy,
            &vec![0.0; 4],
            &cfg,
            &mut |w| problem.error(w),
        );
        // Deterministic accounting regardless of thread scheduling:
        // every round all 4 workers download one 32-byte model at 1.0
        // each, and every round's clock is at least download (1.0) +
        // two serialized ingress services (2.0).
        assert_eq!(run.bytes_down, 40 * 4 * 32);
        assert!((run.down_time - 40.0 * 4.0).abs() < 1e-9);
        assert!(
            run.virtual_time >= 40.0 * 3.0 - 1e-9,
            "congested clock too small: {}",
            run.virtual_time
        );
    }

    #[test]
    fn comm_channel_meters_bytes_and_decodes_on_the_live_cluster() {
        use crate::comm::{CommChannel, LinkModel, TopK};
        use crate::straggler::ExponentialDelays;
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 8, ..Default::default() },
            23,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 6);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(3);
        let cfg = ThreadedConfig {
            eta: 0.002,
            max_iterations: 200,
            time_scale: 1e-5,
            seed: 7,
            record_stride: 50,
        };
        let delays = ExponentialDelays::new(1.0);
        let mut channel = CommChannel::new(
            Box::new(TopK::new(0.5)),
            LinkModel::uniform(6, 1000.0, 0.0),
            true,
        );
        let run = cluster.run_with_comm(
            &delays,
            &mut channel,
            &mut policy,
            &vec![0.0; 8],
            &cfg,
            &mut |w| problem.error(w),
        );
        // top-4-of-8 as (index,value) pairs: 16 + 32 = 48 bytes/message.
        assert_eq!(run.bytes_sent, 200 * 3 * 48);
        assert!(run.comm_time > 0.0);
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        // Compression slows convergence vs the dense cluster test; the
        // point here is that feedback keeps it descending.
        assert!(last < first * 0.2, "{first} -> {last}");
    }
}
