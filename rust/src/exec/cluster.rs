//! Threaded master/worker cluster: fastest-k rounds and a fully
//! asynchronous mode, both deterministic.
//!
//! Communication-aware like the simulator: the master prices each
//! worker's download + upload from the channel's size models and folds
//! both into the injected virtual delay (the worker sleeps download +
//! compute + upload), broadcasts the *downlink view* of the model, and
//! decodes accepted gradients through the channel on receipt. With a
//! finite master-ingress capacity the round's virtual time is the
//! ingress completion of the accepted responses, not their max.
//!
//! The run loops are the round engine's: the cluster implements private
//! [`GatherPolicy`](crate::engine::GatherPolicy) impls whose job is only
//! to dispatch jobs to the worker threads and collect responses — all
//! pricing (broadcast, response delays, ingress clock), the SGD apply,
//! and recording go through the shared
//! [`EngineCore`](crate::engine::EngineCore), so the real threads are
//! reduced to a delay-and-gradient source feeding the same engine as
//! the simulators.
//!
//! **Determinism.** The master decides by *virtual* time, never by real
//! arrival order: the fastest-k round selects the k smallest injected
//! delays (it computed every delay before dispatch) and waits for
//! exactly those workers' responses, and the async mode applies
//! responses in virtual completion order (buffering early real
//! arrivals). Thread scheduling therefore cannot change a trajectory —
//! an adaptive [`KPolicy`] sees the simulator's observable sequence bit
//! for bit, asserted by `rust/tests/test_engine_equivalence.rs`.

use crate::async_sgd::AsyncConfig;
use crate::comm::{CommChannel, DownlinkMode, IngressDiscipline};
use crate::data::Shards;
use crate::engine::{
    EngineConfig, EngineCore, EngineRun, GatherPolicy, RngStreams,
    RoundEngine,
};
use crate::linalg::{gemv, gemv_t, Matrix};
use crate::master::fastest_k_select;
use crate::metrics::Recorder;
use crate::policy::KPolicy;
use crate::sim::EventQueue;
use crate::straggler::DelayModel;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Threaded-run configuration.
#[derive(Debug, Clone)]
pub struct ThreadedConfig {
    /// Step size η.
    pub eta: f32,
    /// Iterations to run.
    pub max_iterations: u64,
    /// Seconds of real sleep per virtual delay unit (keep small: the
    /// threaded mode is a semantics demonstration, not a throughput test).
    pub time_scale: f64,
    /// Seed for the delay draws (same stream family as the simulator).
    pub seed: u64,
    /// Record stride.
    pub record_stride: u64,
    /// Intra-round worker budget for the master's merge/apply loops
    /// (1 = serial, 0 = the machine). Pure wall-clock — trajectories
    /// are bitwise identical for every value.
    pub intra_jobs: usize,
}

impl Default for ThreadedConfig {
    fn default() -> Self {
        Self {
            eta: 5e-4,
            max_iterations: 200,
            time_scale: 1e-3,
            seed: 0,
            record_stride: 10,
            intra_jobs: 1,
        }
    }
}

/// Statistics from a threaded run.
pub struct ThreadedRunStats {
    /// Error-vs-(virtual)-time record.
    pub recorder: Recorder,
    /// Final model.
    pub w: Vec<f32>,
    /// Total virtual time (sum of per-iteration k-th response delays).
    pub virtual_time: f64,
    /// Total real wall-clock seconds.
    pub real_time: f64,
    /// Discarded responses — wasted straggler work: stale generations
    /// plus fresh responses outside the virtual fastest-k (0 for the
    /// async mode, which applies everything).
    pub late_responses: u64,
    /// (iteration, time, new_k) for every k change the policy made
    /// (empty for the async mode).
    pub k_changes: Vec<(u64, f64, usize)>,
    /// Mean staleness of applied updates — the async mode (0 for
    /// rounds).
    pub mean_staleness: f64,
    /// True if the run blew up (non-finite model) and stopped early —
    /// the async mode's divergence guard.
    pub diverged: bool,
    /// Encoded bytes of all accepted gradient messages.
    pub bytes_sent: u64,
    /// Total upload time of accepted messages (virtual units).
    pub comm_time: f64,
    /// Encoded bytes of all model downloads (once per worker per round).
    pub bytes_down: u64,
    /// Total download time charged (virtual units).
    pub down_time: f64,
    /// The recorded event trace when the run was started through a
    /// `_traced` entry point with tracing on (see [`crate::trace`]).
    pub trace: Option<crate::trace::Trace>,
}

struct Job {
    /// Which run_* invocation dispatched this job (stale responses from
    /// an earlier run on a reused cluster are filtered by epoch).
    epoch: u64,
    generation: u64,
    w: Arc<Vec<f32>>,
    /// Injected virtual delay for this worker at this iteration.
    delay: f64,
}

struct Response {
    epoch: u64,
    generation: u64,
    worker: usize,
    grad: Vec<f32>,
}

/// A running cluster of worker threads pinned to their shards.
pub struct ThreadedCluster {
    job_txs: Vec<mpsc::Sender<Job>>,
    resp_rx: mpsc::Receiver<Response>,
    handles: Vec<std::thread::JoinHandle<()>>,
    n: usize,
    d: usize,
    /// Bumped per run_* call; in-flight responses from an earlier run
    /// on this cluster can never be mistaken for the current run's.
    epoch: u64,
}

impl ThreadedCluster {
    /// Spawn one thread per shard. Each worker owns its `(X_i, y_i)` and
    /// computes real partial gradients with the native kernels.
    pub fn spawn(shards: &Shards, time_scale: f64) -> Self {
        let n = shards.n();
        let d = shards.x[0].cols();
        let (resp_tx, resp_rx) = mpsc::channel::<Response>();
        let mut job_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<Job>();
            job_txs.push(tx);
            let resp_tx = resp_tx.clone();
            let x: Matrix = shards.x[i].clone();
            let y: Vec<f32> = shards.y[i].clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(i, x, y, rx, resp_tx, time_scale);
            }));
        }
        Self { job_txs, resp_rx, handles, n, d, epoch: 0 }
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Run fastest-k SGD on the live cluster (exp(1) delays, free link).
    pub fn run_fastest_k(
        &mut self,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        assert_eq!(w0.len(), self.d);
        let delay_model = crate::straggler::ExponentialDelays::new(1.0);
        let mut channel = CommChannel::dense(self.n);
        self.run_inner(
            policy,
            w0,
            cfg,
            eval_error,
            &delay_model,
            &mut channel,
            false,
        )
    }

    /// Run with an explicit delay model (free link).
    pub fn run_with_delays(
        &mut self,
        delays: &dyn DelayModel,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        let mut channel = CommChannel::dense(self.n);
        self.run_inner(
            policy,
            w0,
            cfg,
            eval_error,
            delays,
            &mut channel,
            false,
        )
    }

    /// Run with an explicit delay model *and* comm channel: worker sleeps
    /// cover compute + upload, and accepted gradients are decoded through
    /// the channel (compression + error feedback) before aggregation.
    pub fn run_with_comm(
        &mut self,
        delays: &dyn DelayModel,
        channel: &mut CommChannel,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        self.run_inner(policy, w0, cfg, eval_error, delays, channel, false)
    }

    /// [`Self::run_with_comm`] with opt-in binary event tracing (see
    /// [`crate::trace`]); the trajectory is bit-identical either way.
    #[allow(clippy::too_many_arguments)]
    pub fn run_with_comm_traced(
        &mut self,
        delays: &dyn DelayModel,
        channel: &mut CommChannel,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
        trace: bool,
    ) -> ThreadedRunStats {
        self.run_inner(policy, w0, cfg, eval_error, delays, channel, trace)
    }

    /// Build an engine core (threaded rng streams: delay stream shared
    /// with the simulator, per-worker compression streams) and run the
    /// cluster's gather discipline on it.
    #[allow(clippy::too_many_arguments)]
    fn run_inner(
        &mut self,
        policy: &mut dyn KPolicy,
        w0: &[f32],
        cfg: &ThreadedConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
        delays: &dyn DelayModel,
        channel: &mut CommChannel,
        trace: bool,
    ) -> ThreadedRunStats {
        let n = self.n;
        assert_eq!(
            channel.n(),
            n,
            "comm channel sized for {} workers, cluster has {n}",
            channel.n()
        );
        self.epoch += 1;
        // wall clock feeds only the reported real_time stat; results
        // are driven by virtual delays. detlint: allow(D003)
        let start = Instant::now();
        let engine_cfg = EngineConfig {
            eta: cfg.eta,
            momentum: 0.0,
            max_steps: cfg.max_iterations,
            max_time: 0.0,
            seed: cfg.seed,
            record_stride: cfg.record_stride,
            intra_jobs: cfg.intra_jobs,
        };
        let mut core = EngineCore::new(
            format!("threaded/{}", policy.name()),
            channel,
            delays,
            eval_error,
            w0,
            engine_cfg,
            RngStreams::threaded(cfg.seed, n),
        );
        if trace {
            core.enable_trace(crate::trace::Discipline::Threaded);
        }
        let mut gather = ThreadedGather {
            job_txs: &self.job_txs,
            resp_rx: &self.resp_rx,
            epoch: self.epoch,
            policy,
            n,
            k: 1,
            delay_buf: vec![0.0f64; n],
            idx_buf: Vec::with_capacity(n),
            grad_buf: vec![None; n],
            accepted_delays: Vec::with_capacity(n),
            w_cache: None,
            late: 0,
            k_changes: Vec::new(),
        };
        let run = RoundEngine::new(core).run(&mut gather);
        Self::stats_from(run, start)
    }

    /// Run the fully-asynchronous discipline on the live cluster with
    /// the zero-cost dense channel.
    pub fn run_async(
        &mut self,
        delays: &dyn DelayModel,
        w0: &[f32],
        cfg: &AsyncConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        let mut channel = CommChannel::dense(self.n);
        self.run_async_comm(delays, &mut channel, w0, cfg, eval_error)
    }

    /// Threaded asynchronous SGD: every worker computes continuously
    /// against the model view it was last handed; the master applies
    /// each (possibly stale) gradient immediately, with optional
    /// staleness damping, and restarts the worker through the priced
    /// downlink.
    ///
    /// Deterministic by construction: the master computed every injected
    /// delay before dispatch, so it applies responses in *virtual*
    /// completion order (FIFO among ties, the simulator's event-queue
    /// rule), buffering real arrivals that come in early. With the same
    /// seed, channel, and config this reproduces the simulated
    /// [`run_async_comm`](crate::async_sgd::run_async_comm) bit for bit
    /// — same rng streams, and the worker threads run the same gemv
    /// kernels as [`NativeBackend`](crate::grad::NativeBackend)
    /// (asserted by `rust/tests/test_engine_equivalence.rs`).
    ///
    /// Processor-sharing ingress needs the simulator's tentative-event
    /// machinery and is rejected here; use unlimited or FIFO ingress.
    pub fn run_async_comm(
        &mut self,
        delays: &dyn DelayModel,
        channel: &mut CommChannel,
        w0: &[f32],
        cfg: &AsyncConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
    ) -> ThreadedRunStats {
        self.run_async_comm_traced(delays, channel, w0, cfg, eval_error, false)
    }

    /// [`Self::run_async_comm`] with opt-in binary event tracing (see
    /// [`crate::trace`]); the trajectory is bit-identical either way.
    pub fn run_async_comm_traced(
        &mut self,
        delays: &dyn DelayModel,
        channel: &mut CommChannel,
        w0: &[f32],
        cfg: &AsyncConfig,
        eval_error: &mut dyn FnMut(&[f32]) -> f64,
        trace: bool,
    ) -> ThreadedRunStats {
        let n = self.n;
        assert_eq!(w0.len(), self.d, "w0 dimension mismatch");
        assert_eq!(
            channel.n(),
            n,
            "comm channel sized for {} workers, cluster has {n}",
            channel.n()
        );
        assert!(
            channel.ingress().is_unlimited()
                || channel.ingress().discipline() == IngressDiscipline::Fifo,
            "threaded async supports unlimited or FIFO ingress; processor \
             sharing needs the simulated path (async_sgd::run_async_comm)"
        );
        self.epoch += 1;
        // wall clock feeds only the reported real_time stat; results
        // are driven by virtual delays. detlint: allow(D003)
        let start = Instant::now();
        let engine_cfg = EngineConfig {
            eta: cfg.eta,
            momentum: 0.0,
            max_steps: cfg.max_updates,
            max_time: cfg.max_time,
            seed: cfg.seed,
            record_stride: cfg.record_stride,
            intra_jobs: cfg.intra_jobs,
        };
        let mut core = EngineCore::new(
            "threaded-async",
            channel,
            delays,
            eval_error,
            w0,
            engine_cfg,
            RngStreams::asynchronous(cfg.seed),
        );
        if trace {
            core.enable_trace(crate::trace::Discipline::ThreadedAsync);
        }
        let mut gather = ThreadedAsyncGather {
            job_txs: &self.job_txs,
            resp_rx: &self.resp_rx,
            epoch: self.epoch,
            damping: cfg.staleness_damping,
            queue: EventQueue::new(),
            grad_buf: vec![None; n],
            view_buf: vec![0.0f32; self.d],
            w_cache: vec![None; n],
            read_version: vec![0u64; n],
            version: 0,
            staleness_sum: 0.0,
            diverged: false,
        };
        let run = RoundEngine::new(core).run(&mut gather);
        Self::stats_from(run, start)
    }

    fn stats_from(run: EngineRun, start: Instant) -> ThreadedRunStats {
        ThreadedRunStats {
            recorder: run.recorder,
            w: run.w,
            virtual_time: run.total_time,
            real_time: start.elapsed().as_secs_f64(),
            late_responses: run.late_responses,
            k_changes: run.k_changes,
            mean_staleness: run.mean_staleness,
            diverged: run.diverged,
            bytes_sent: run.bytes_sent,
            comm_time: run.comm_time,
            bytes_down: run.bytes_down,
            down_time: run.down_time,
            trace: run.trace,
        }
    }
}

/// The cluster's fastest-k gather: real worker threads as the delay and
/// gradient source. Dispatch sends every worker its priced virtual delay
/// (the worker sleeps download + compute + upload, scaled); the round
/// accepts the k smallest *virtual* delays — the master computed every
/// delay before dispatch, so the accepted set, the aggregation order,
/// and hence the whole trajectory are independent of thread scheduling
/// and match the simulated [`FastestKGather`](crate::engine)'s. Fresh
/// responses outside the selection and stragglers from earlier
/// generations are discarded (counted as `late`). Everything priced or
/// recorded goes through the [`EngineCore`].
struct ThreadedGather<'a> {
    job_txs: &'a [mpsc::Sender<Job>],
    resp_rx: &'a mpsc::Receiver<Response>,
    epoch: u64,
    policy: &'a mut dyn KPolicy,
    n: usize,
    k: usize,
    /// Injected virtual delays of the current round.
    delay_buf: Vec<f64>,
    /// Selection scratch (quickselect permutation).
    idx_buf: Vec<usize>,
    /// Selected workers' gradients, buffered until the set is complete.
    grad_buf: Vec<Option<Vec<f32>>>,
    /// Accepted responses' virtual delays, for the congested clock.
    accepted_delays: Vec<f64>,
    /// Last round's broadcast buffer, reused (no fresh allocation) when
    /// every worker has dropped its handle — memory-only, bitwise inert.
    w_cache: Option<Arc<Vec<f32>>>,
    late: u64,
    k_changes: Vec<(u64, f64, usize)>,
}

/// Reuse `cache`'s buffer for a broadcast of `w` when nobody else still
/// holds it (strong count 1), else allocate a fresh shared copy. The
/// bytes shipped are identical either way — this only recycles memory.
fn shared_model(
    cache: Option<Arc<Vec<f32>>>,
    w: &[f32],
) -> Arc<Vec<f32>> {
    if let Some(mut arc) = cache {
        if let Some(buf) = Arc::get_mut(&mut arc) {
            if buf.len() == w.len() {
                buf.copy_from_slice(w);
            } else {
                *buf = w.to_vec();
            }
            return arc;
        }
    }
    Arc::new(w.to_vec())
}

impl GatherPolicy for ThreadedGather<'_> {
    fn initial_k(&self) -> usize {
        self.k
    }

    fn start(&mut self, _core: &mut EngineCore) {
        self.k = self.policy.initial_k().clamp(1, self.n);
    }

    fn step(&mut self, core: &mut EngineCore) -> bool {
        let j = core.steps;
        if j >= core.cfg.max_steps {
            return false;
        }
        // Broadcast w_j through the priced downlink: workers compute at
        // the decoded view, and each injected delay covers the download,
        // the compute, and the priced upload of the coming response.
        let down_bytes = core.broadcast_round();
        let w_shared = shared_model(self.w_cache.take(), &core.w_view);
        self.w_cache = Some(Arc::clone(&w_shared));
        for (i, tx) in self.job_txs.iter().enumerate() {
            let delay = core.response_delay(j, i, down_bytes);
            self.delay_buf[i] = delay;
            tx.send(Job {
                epoch: self.epoch,
                generation: j,
                w: Arc::clone(&w_shared),
                delay,
            })
            .expect("worker died");
        }

        // Deterministic selection + clock, exactly the simulator's: the
        // k fastest by virtual delay, ingress completion when the
        // master's NIC is finite.
        let (x_k, _) =
            fastest_k_select(&self.delay_buf, self.k, &mut self.idx_buf);
        let round_time = if core.ingress_unlimited() {
            x_k
        } else {
            self.accepted_delays.clear();
            self.accepted_delays.extend(
                self.idx_buf[..self.k].iter().map(|&i| self.delay_buf[i]),
            );
            core.round_completion(&mut self.accepted_delays)
        };
        core.t += round_time;

        // Wait for exactly the selected workers' fresh responses; real
        // arrival order only affects buffering, never the result.
        for slot in self.grad_buf.iter_mut() {
            *slot = None;
        }
        let mut got = 0usize;
        while got < self.k {
            let resp = self.resp_rx.recv().expect("cluster closed");
            if resp.epoch != self.epoch || resp.generation != j {
                self.late += 1; // straggler from an earlier round: discard
                continue;
            }
            if !self.idx_buf[..self.k].contains(&resp.worker) {
                self.late += 1; // fresh but outside the virtual fastest-k
                continue;
            }
            if self.grad_buf[resp.worker].replace(resp.grad).is_none() {
                got += 1;
            }
        }
        // Aggregate in selection order (the simulator's), decoding each
        // accepted gradient through the channel.
        core.zero_g();
        for &worker in &self.idx_buf[..self.k] {
            let grad = self.grad_buf[worker]
                .take()
                .expect("selected response gathered above");
            core.accept_into_g(worker, &grad);
        }

        // The shared round tail: mean-scale + SGD update + policy
        // feedback + recording, in exactly one place (engine/core.rs).
        self.k = core.finish_fastest_k_round(
            j,
            self.n,
            self.k,
            &mut *self.policy,
            &mut self.k_changes,
        );
        true
    }

    fn annotate(&mut self, run: &mut EngineRun) {
        run.late_responses = self.late;
        run.k_changes = std::mem::take(&mut self.k_changes);
    }
}

/// The cluster's fully-asynchronous discipline: the mirror of
/// [`StalenessGather`](crate::engine::StalenessGather) with the real
/// threads as the gradient source. The master applies responses in
/// *virtual* completion order from its own event queue (it computed
/// every injected delay at dispatch), buffering early real arrivals, so
/// the trajectory is thread-schedule-independent and bitwise the
/// simulator's.
struct ThreadedAsyncGather<'a> {
    job_txs: &'a [mpsc::Sender<Job>],
    resp_rx: &'a mpsc::Receiver<Response>,
    epoch: u64,
    damping: bool,
    /// Virtual completion times of outstanding jobs (FIFO among ties —
    /// the simulator's event-queue rule).
    queue: EventQueue<usize>,
    /// Early real arrivals buffered until their virtual turn.
    grad_buf: Vec<Option<Vec<f32>>>,
    /// Decode target for the per-worker model push.
    view_buf: Vec<f32>,
    /// Per-worker dispatch buffers, reused once the worker drops its
    /// previous job (memory-only, bitwise inert).
    w_cache: Vec<Option<Arc<Vec<f32>>>>,
    read_version: Vec<u64>,
    version: u64,
    staleness_sum: f64,
    diverged: bool,
}

impl GatherPolicy for ThreadedAsyncGather<'_> {
    fn initial_k(&self) -> usize {
        1
    }

    fn start(&mut self, core: &mut EngineCore) {
        // Workers know w0, so the initial dispatch carries no download
        // (mirrors StalenessGather::start, same draw order).
        let w0 = Arc::new(core.w.clone());
        for (i, tx) in self.job_txs.iter().enumerate() {
            let dt = core.cycle_delay(0, i, 0.0);
            tx.send(Job {
                epoch: self.epoch,
                generation: 0,
                w: Arc::clone(&w0),
                delay: dt,
            })
            .expect("worker died");
            self.queue.schedule_in(dt, i);
        }
    }

    fn step(&mut self, core: &mut EngineCore) -> bool {
        if core.steps >= core.cfg.max_steps {
            return false;
        }
        let ev = match self.queue.pop() {
            Some(e) => e,
            None => return false,
        };
        let i = ev.payload;
        // FIFO (or free) ingress: the upload that virtually arrived at
        // ev.time is applied once the master's NIC has served it.
        let t_apply = core.serve_ingress(i, ev.time);
        core.t = t_apply;
        if core.cfg.max_time > 0.0 && t_apply > core.cfg.max_time {
            return false;
        }
        // The worker's real compute: fetch its response (∇F_i at the
        // view it was dispatched), buffering any that arrive early.
        let grad = loop {
            if let Some(g) = self.grad_buf[i].take() {
                break g;
            }
            let resp = self.resp_rx.recv().expect("cluster closed");
            if resp.epoch != self.epoch {
                continue; // stale response from an earlier run: drop
            }
            self.grad_buf[resp.worker] = Some(resp.grad);
        };
        core.transmit(i, &grad);
        let staleness = self.version - self.read_version[i];
        let step = if self.damping {
            core.cfg.eta / (1.0 + staleness as f32)
        } else {
            core.cfg.eta
        };
        core.apply_decoded(step);
        self.version += 1;
        self.staleness_sum += staleness as f64;
        core.steps += 1;
        if core.trace_on() {
            core.trace_event(crate::trace::Event::Apply {
                step: core.steps,
                time: core.t,
                k: 1,
                staleness,
            });
        }
        if !core.model_is_finite() {
            self.diverged = true;
            core.record_diverged(core.steps, 1);
            return false;
        }

        // Restart the worker through the priced downlink (delta mode
        // replays one message per elapsed update, like the simulator).
        let replay = match core.downlink_mode() {
            DownlinkMode::Full => 1,
            DownlinkMode::Delta => staleness + 1,
        };
        let (_, down_delay) =
            core.push_model_to(i, &mut self.view_buf, replay);
        self.read_version[i] = self.version;
        let dt = core.cycle_delay(core.steps, i, down_delay);
        self.queue.schedule_at(t_apply + dt, i);
        let w = shared_model(self.w_cache[i].take(), &self.view_buf);
        self.w_cache[i] = Some(Arc::clone(&w));
        self.job_txs[i]
            .send(Job {
                epoch: self.epoch,
                generation: core.steps,
                w,
                delay: dt,
            })
            .expect("worker died");

        core.maybe_record(core.steps, 1);
        true
    }

    fn finish(&mut self, core: &mut EngineCore) {
        if !self.diverged {
            core.record_final(core.steps, 1);
        }
    }

    fn annotate(&mut self, run: &mut EngineRun) {
        run.diverged = self.diverged;
        run.mean_staleness = if run.steps > 0 {
            self.staleness_sum / run.steps as f64
        } else {
            0.0
        };
    }
}

impl Drop for ThreadedCluster {
    fn drop(&mut self) {
        self.job_txs.clear(); // close job channels; workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    _id: usize,
    x: Matrix,
    y: Vec<f32>,
    rx: mpsc::Receiver<Job>,
    tx: mpsc::Sender<Response>,
    time_scale: f64,
) {
    let s = x.rows();
    let d = x.cols();
    let mut resid = vec![0.0f32; s];
    let id = _id;
    while let Ok(job) = rx.recv() {
        // Real compute: partial gradient of this worker's shard.
        let mut grad = vec![0.0f32; d];
        gemv(1.0, &x, &job.w, 0.0, &mut resid);
        for (r, yv) in resid.iter_mut().zip(&y) {
            *r -= *yv;
        }
        gemv_t(1.0 / s as f32, &x, &resid, 0.0, &mut grad);
        // Injected straggling.
        if job.delay > 0.0 && time_scale > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(job.delay * time_scale));
        }
        if tx
            .send(Response {
                epoch: job.epoch,
                generation: job.generation,
                worker: id,
                grad,
            })
            .is_err()
        {
            break; // master gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticConfig, SyntheticDataset};
    use crate::model::LinRegProblem;
    use crate::policy::FixedK;

    #[test]
    fn threaded_training_descends() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 8, ..Default::default() },
            21,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 6);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(3);
        let cfg = ThreadedConfig {
            eta: 0.002,
            max_iterations: 150,
            time_scale: 1e-5,
            seed: 5,
            record_stride: 25,
            intra_jobs: 1,
        };
        let run = cluster.run_fastest_k(
            &mut policy,
            &vec![0.0; 8],
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 0.05, "{first} -> {last}");
        assert!(run.virtual_time > 0.0);
        assert!(run.real_time > 0.0);
    }

    #[test]
    fn threaded_async_training_descends_and_reports_staleness() {
        use crate::straggler::ExponentialDelays;
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 8, ..Default::default() },
            25,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 6);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-6);
        let delays = ExponentialDelays::new(1.0);
        let cfg = AsyncConfig {
            eta: 0.001,
            max_updates: 900,
            max_time: 0.0,
            seed: 9,
            record_stride: 150,
            staleness_damping: true,
            intra_jobs: 1,
        };
        let run = cluster.run_async(
            &delays,
            &vec![0.0; 8],
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(last < first * 0.05, "{first} -> {last}");
        // 6 concurrent workers → mean staleness ≈ 5.
        assert!(run.mean_staleness > 2.0, "{}", run.mean_staleness);
        assert!(!run.diverged);
        // Async applies everything — nothing is "late".
        assert_eq!(run.late_responses, 0);
        assert!(run.k_changes.is_empty());
    }

    #[test]
    #[should_panic(expected = "processor")]
    fn threaded_async_rejects_ps_ingress() {
        use crate::comm::{IngressDiscipline, IngressModel};
        use crate::straggler::ExponentialDelays;
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 40, d: 4, ..Default::default() },
            26,
        );
        let shards = Shards::partition(&ds, 4);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-6);
        let delays = ExponentialDelays::new(1.0);
        let mut channel = CommChannel::dense(4).with_ingress(
            IngressModel::with_discipline(32.0, IngressDiscipline::Ps),
        );
        let cfg = AsyncConfig {
            eta: 0.001,
            max_updates: 10,
            ..Default::default()
        };
        let problem = LinRegProblem::new(&ds);
        cluster.run_async_comm(
            &delays,
            &mut channel,
            &vec![0.0; 4],
            &cfg,
            &mut |w| problem.error(w),
        );
    }

    #[test]
    fn late_responses_are_discarded_not_applied() {
        // k=1 of 4: three responses per round arrive late and must be
        // counted as waste.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 40, d: 4, ..Default::default() },
            22,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 4);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(1);
        let cfg = ThreadedConfig {
            eta: 0.001,
            max_iterations: 30,
            time_scale: 1e-5,
            seed: 6,
            record_stride: 10,
            intra_jobs: 1,
        };
        let run = cluster.run_fastest_k(
            &mut policy,
            &vec![0.0; 4],
            &cfg,
            &mut |w| problem.error(w),
        );
        assert!(
            run.late_responses > 0,
            "with k=1 of 4, late responses are inevitable"
        );
    }

    #[test]
    fn bidirectional_channel_slows_the_virtual_clock_on_the_live_cluster() {
        use crate::comm::{
            Broadcast, CommChannel, Dense, DownlinkMode, IngressModel,
            LinkModel,
        };
        use crate::straggler::ExponentialDelays;
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 40, d: 4, ..Default::default() },
            24,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 4);
        let delays = ExponentialDelays::new(1.0);
        let cfg = ThreadedConfig {
            eta: 0.001,
            max_iterations: 40,
            time_scale: 1e-5,
            seed: 8,
            record_stride: 10,
            intra_jobs: 1,
        };
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(2);
        // d=4 -> 32-byte messages both ways; downlink 32 B/t (+1.0 per
        // round per worker) and ingress 32 B/t (+1.0 serialization per
        // accepted upload).
        let mut channel = CommChannel::dense(4)
            .with_broadcast(Broadcast::new(
                Box::new(Dense::new()),
                LinkModel::uniform(4, 32.0, 0.0),
                DownlinkMode::Full,
            ))
            .with_ingress(IngressModel::new(32.0));
        let run = cluster.run_with_comm(
            &delays,
            &mut channel,
            &mut policy,
            &vec![0.0; 4],
            &cfg,
            &mut |w| problem.error(w),
        );
        // Deterministic accounting regardless of thread scheduling:
        // every round all 4 workers download one 32-byte model at 1.0
        // each, and every round's clock is at least download (1.0) +
        // two serialized ingress services (2.0).
        assert_eq!(run.bytes_down, 40 * 4 * 32);
        assert!((run.down_time - 40.0 * 4.0).abs() < 1e-9);
        assert!(
            run.virtual_time >= 40.0 * 3.0 - 1e-9,
            "congested clock too small: {}",
            run.virtual_time
        );
    }

    #[test]
    fn comm_channel_meters_bytes_and_decodes_on_the_live_cluster() {
        use crate::comm::{CommChannel, LinkModel, TopK};
        use crate::straggler::ExponentialDelays;
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 8, ..Default::default() },
            23,
        );
        let problem = LinRegProblem::new(&ds);
        let shards = Shards::partition(&ds, 6);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-5);
        let mut policy = FixedK::new(3);
        let cfg = ThreadedConfig {
            eta: 0.002,
            max_iterations: 200,
            time_scale: 1e-5,
            seed: 7,
            record_stride: 50,
            intra_jobs: 1,
        };
        let delays = ExponentialDelays::new(1.0);
        let mut channel = CommChannel::new(
            Box::new(TopK::new(0.5)),
            LinkModel::uniform(6, 1000.0, 0.0),
            true,
        );
        let run = cluster.run_with_comm(
            &delays,
            &mut channel,
            &mut policy,
            &vec![0.0; 8],
            &cfg,
            &mut |w| problem.error(w),
        );
        // top-4-of-8 as (index,value) pairs: 16 + 32 = 48 bytes/message.
        assert_eq!(run.bytes_sent, 200 * 3 * 48);
        assert!(run.comm_time > 0.0);
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        // Compression slows convergence vs the dense cluster test; the
        // point here is that feedback keeps it descending.
        assert!(last < first * 0.2, "{first} -> {last}");
    }
}
