//! Real-thread execution mode.
//!
//! The simulator advances a virtual clock; this module actually runs the
//! cluster: one OS thread per worker, channel-based broadcast/gather, and
//! injected sleep delays (drawn from the same [`DelayModel`] streams, so a
//! threaded run and a simulated run of the same seed follow the same
//! straggler pattern). It demonstrates the coordinator semantics the paper
//! assumes:
//!
//! * the master broadcasts `w_j` to **all** workers,
//! * workers compute their *real* partial gradients (native linalg),
//! * the master returns after the fastest k responses; late responses are
//!   discarded by generation tag (wasted work — exactly the cost the
//!   fastest-k scheme accepts to avoid the straggler tail).

//! The module also hosts [`ThreadPool`], the generic job pool the sweep
//! layer ([`crate::sweep`]) fans independent experiments out on, plus
//! the deterministic intra-round parallelism layer: scoped fork–join on
//! the pool ([`ThreadPool::scope`] / [`ThreadPool::parallel_for`]), the
//! [`Parallelism`] budget token + fixed-partition slice helpers
//! ([`par`]), and the thread-keyed [`scratch`] arena that reuses hot
//! buffers across sweep specs.

mod cluster;
pub mod par;
mod pool;
pub mod scratch;

pub use cluster::{ThreadedCluster, ThreadedConfig, ThreadedRunStats};
pub use par::{
    for_each_block_mut, for_each_slot_mut, zip_block_mut, Parallelism,
    INTRA_BLOCK,
};
pub use pool::{Scope, ThreadPool};
