//! Deterministic intra-round parallelism: [`Parallelism`] + the shared
//! intra-op thread pool + fixed-partition slice helpers.
//!
//! The sweep layer fans *specs* out (`--jobs`); this module fans the
//! work *inside one round* out (`--intra-jobs`): the k responders'
//! partial gradients, and the d-dimensional merge/apply loops, split
//! into fixed blocks. The determinism argument is structural and does
//! not depend on the schedule:
//!
//! * the **partition is fixed** — block count and block boundaries are
//!   pure functions of the problem shape (`d`, [`INTRA_BLOCK`]) or the
//!   responder list, never of the thread count or claim order;
//! * every block writes a **disjoint slice** and reads only shared
//!   immutable inputs, so elementwise results are bitwise identical to
//!   the serial loop by float-association-free construction;
//! * any **reduction runs serially in fixed block order** on the
//!   calling thread after the join.
//!
//! Hence `--intra-jobs 1` ≡ `--intra-jobs N` byte-for-byte, and it
//! composes with sweep fan-out: all `parallel_for` helpers share ONE
//! process-global pool ([`intra_pool`]) sized to the machine, so
//! `--jobs J --intra-jobs I` never spawns `J × I` threads.
//!
//! `Parallelism::new(1)` (the default) short-circuits every entry point
//! to the exact serial loop — no pool is created, no new code runs.

use super::pool::ThreadPool;
use std::sync::OnceLock;

/// Fixed block width (f32 elements) for splitting d-dimensional
/// elementwise loops. A pure constant: the block partition of a vector
/// depends on its length alone, never on the worker count, so changing
/// `--intra-jobs` can never move an element across a block boundary.
/// 4096 f32 = 16 KiB per block — large enough that claim overhead
/// vanishes, small enough to load-balance the fig-2 shapes.
pub const INTRA_BLOCK: usize = 4096;

/// The process-global intra-op pool, shared by every engine and every
/// sweep worker (lazily created on first parallel use). One pool for
/// the whole process is what lets sweep-level fan-out compose with
/// intra-round fan-out without `jobs × intra_jobs` oversubscription.
fn intra_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        ThreadPool::new(n).expect("intra pool: available_parallelism >= 1")
    })
}

/// Resolved intra-round worker budget (a `Copy` token threaded through
/// the gradient hot path). `jobs == 1` means strictly serial — every
/// helper in this module degenerates to the plain loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    jobs: usize,
}

impl Parallelism {
    /// Strictly serial execution (the default, and today's behavior).
    pub const SERIAL: Parallelism = Parallelism { jobs: 1 };

    /// Resolve an `intra_jobs` config value: `0` = the machine's
    /// available parallelism (the `--jobs` convention), otherwise the
    /// given thread budget. The value never affects results, only
    /// wall-clock.
    pub fn new(intra_jobs: usize) -> Self {
        let jobs = if intra_jobs == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            intra_jobs
        };
        Self { jobs }
    }

    /// Resolved thread budget (≥ 1).
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// True when every loop runs inline on the calling thread.
    pub fn is_serial(&self) -> bool {
        self.jobs <= 1
    }

    /// Run `body(block)` for every block in `0..blocks`. Serial (in
    /// ascending block order) when the budget or the block count is 1;
    /// otherwise fork–join on the shared intra pool. `body` must write
    /// only block-disjoint state — the determinism contract above.
    pub fn run<F>(&self, blocks: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.jobs <= 1 || blocks <= 1 {
            for b in 0..blocks {
                body(b);
            }
        } else {
            intra_pool().parallel_for(self.jobs, blocks, body);
        }
    }
}

/// `*mut f32` that crosses the fork–join: the block protocol guarantees
/// disjoint access, which the type system cannot see through a raw
/// pointer.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: each block dereferences a disjoint element range (enforced by
// the fixed partition in the helpers below), so concurrent use is safe.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Number of [`INTRA_BLOCK`]-wide blocks covering `len` elements.
fn block_count(len: usize) -> usize {
    (len + INTRA_BLOCK - 1) / INTRA_BLOCK
}

/// Split `y` into fixed [`INTRA_BLOCK`] chunks and run
/// `f(offset, chunk)` on each, in parallel per `par`. The partition
/// depends on `y.len()` alone; `f` must be elementwise (no cross-chunk
/// state), which makes the result bitwise independent of `par`.
pub fn for_each_block_mut<F>(par: Parallelism, y: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let d = y.len();
    if par.is_serial() || d <= INTRA_BLOCK {
        if d > 0 {
            f(0, y);
        }
        return;
    }
    let ptr = SendPtr(y.as_mut_ptr());
    par.run(block_count(d), |b| {
        let lo = b * INTRA_BLOCK;
        let hi = (lo + INTRA_BLOCK).min(d);
        // SAFETY: blocks cover disjoint `[lo, hi)` ranges of `y`, and
        // the fork–join ends before `y`'s borrow does.
        let chunk =
            unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo), hi - lo) };
        f(lo, chunk);
    });
}

/// Like [`for_each_block_mut`] but pairing each mutable chunk of `y`
/// with the matching shared chunk of `x` (`f(offset, y_chunk,
/// x_chunk)`). Panics if the lengths differ.
pub fn zip_block_mut<F>(par: Parallelism, y: &mut [f32], x: &[f32], f: F)
where
    F: Fn(usize, &mut [f32], &[f32]) + Sync,
{
    assert_eq!(y.len(), x.len(), "zip_block_mut: length mismatch");
    let d = y.len();
    if par.is_serial() || d <= INTRA_BLOCK {
        if d > 0 {
            f(0, y, x);
        }
        return;
    }
    let ptr = SendPtr(y.as_mut_ptr());
    par.run(block_count(d), |b| {
        let lo = b * INTRA_BLOCK;
        let hi = (lo + INTRA_BLOCK).min(d);
        // SAFETY: as in `for_each_block_mut` — disjoint ranges, borrow
        // outlives the join.
        let chunk =
            unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo), hi - lo) };
        f(lo, chunk, &x[lo..hi]);
    });
}

/// Split `out` into `count` fixed-width `width` slices and run
/// `f(i, slice_i)` on each — the per-responder gradient arena pattern:
/// slice `i` belongs to responder `i` alone, and the caller reduces the
/// slices serially in responder order afterwards. Panics unless
/// `out.len() == count * width`.
pub fn for_each_slot_mut<F>(
    par: Parallelism,
    out: &mut [f32],
    count: usize,
    width: usize,
    f: F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(
        out.len(),
        count * width,
        "for_each_slot_mut: arena shape mismatch"
    );
    if par.is_serial() || count <= 1 || width == 0 {
        for (i, slot) in out.chunks_exact_mut(width.max(1)).enumerate() {
            f(i, slot);
        }
        return;
    }
    let ptr = SendPtr(out.as_mut_ptr());
    par.run(count, |i| {
        // SAFETY: slot `i` is the disjoint range
        // `[i * width, (i+1) * width)`; the borrow outlives the join.
        let slot = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(i * width), width)
        };
        f(i, slot);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_token_is_serial() {
        assert!(Parallelism::SERIAL.is_serial());
        assert_eq!(Parallelism::new(1), Parallelism::SERIAL);
        assert!(!Parallelism::new(4).is_serial());
        assert_eq!(Parallelism::new(4).jobs(), 4);
        assert!(Parallelism::new(0).jobs() >= 1);
    }

    #[test]
    fn run_visits_every_block_once_in_any_mode() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for jobs in [1usize, 3, 16] {
            let par = Parallelism::new(jobs);
            let hits: Vec<AtomicUsize> =
                (0..9).map(|_| AtomicUsize::new(0)).collect();
            par.run(9, |b| {
                hits[b].fetch_add(1, Ordering::SeqCst);
            });
            assert!(hits
                .iter()
                .all(|h| h.load(Ordering::SeqCst) == 1));
        }
    }

    /// The determinism contract, concretely: the block split of an
    /// elementwise op is bitwise-identical to the serial loop for every
    /// worker budget, including catastrophic-cancellation values.
    #[test]
    fn block_helpers_are_bitwise_equal_to_the_serial_loop() {
        let prime = 10_007usize;
        for d in [0usize, 1, INTRA_BLOCK - 1, INTRA_BLOCK, INTRA_BLOCK + 1, prime]
        {
            let x: Vec<f32> = (0..d)
                .map(|i| {
                    let sign = if i % 2 == 0 { 1.0f32 } else { -1.0 };
                    sign * (1.0e8 + i as f32) + 1.0e-6 * i as f32
                })
                .collect();
            let mut y_ref: Vec<f32> =
                (0..d).map(|i| 3.0e7 - i as f32 * 0.5).collect();
            let y0 = y_ref.clone();
            for (yv, xv) in y_ref.iter_mut().zip(&x) {
                *yv = *yv * 0.3 + *xv;
            }
            for jobs in [1usize, 3, 4, 16] {
                let par = Parallelism::new(jobs);
                let mut y = y0.clone();
                zip_block_mut(par, &mut y, &x, |_, yc, xc| {
                    for (yv, xv) in yc.iter_mut().zip(xc) {
                        *yv = *yv * 0.3 + *xv;
                    }
                });
                assert_eq!(bits(&y), bits(&y_ref), "d={d} jobs={jobs}");

                let mut z = y0.clone();
                for_each_block_mut(par, &mut z, |off, zc| {
                    for (i, zv) in zc.iter_mut().enumerate() {
                        *zv *= (off + i) as f32 + 0.25;
                    }
                });
                let mut z_ref = y0.clone();
                for (i, zv) in z_ref.iter_mut().enumerate() {
                    *zv *= i as f32 + 0.25;
                }
                assert_eq!(bits(&z), bits(&z_ref), "d={d} jobs={jobs}");
            }
        }
    }

    #[test]
    fn slot_split_writes_each_responder_slice() {
        let (count, width) = (7usize, 33usize);
        for jobs in [1usize, 4] {
            let mut arena = vec![0.0f32; count * width];
            for_each_slot_mut(
                Parallelism::new(jobs),
                &mut arena,
                count,
                width,
                |i, slot| {
                    for (j, s) in slot.iter_mut().enumerate() {
                        *s = (i * 1000 + j) as f32;
                    }
                },
            );
            for i in 0..count {
                for j in 0..width {
                    assert_eq!(
                        arena[i * width + j],
                        (i * 1000 + j) as f32
                    );
                }
            }
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
