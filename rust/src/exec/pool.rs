//! Minimal fixed-size thread pool (no rayon/tokio offline).
//!
//! Used by benches and the Monte-Carlo order-statistic estimator for
//! embarrassingly-parallel jobs; the training cluster uses dedicated
//! per-worker threads (`cluster.rs`) instead, because workers own state.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` threads.
    pub fn new(size: usize) -> Self {
        assert!(size > 0, "pool needs at least one thread");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let handles = (0..size)
            .map(|_| {
                let rx = Arc::clone(&receiver);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("pool lock poisoned");
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // all senders dropped
                    }
                })
            })
            .collect();
        Self { sender: Some(sender), handles }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Map `f` over `0..jobs` in parallel, collecting results in order.
    pub fn map<T: Send + 'static>(
        &self,
        jobs: usize,
        f: impl Fn(usize) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        for i in 0..jobs {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let _ = tx.send((i, f(i)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..jobs).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter().map(|v| v.expect("job dropped")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.sender.take(); // close the channel
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }
}
