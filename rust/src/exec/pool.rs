//! Minimal fixed-size work-stealing thread pool (no rayon/tokio
//! offline).
//!
//! Used by the sweep executor ([`crate::sweep::SweepExecutor`]) and
//! benches for embarrassingly-parallel jobs; the training cluster uses
//! dedicated per-worker threads (`cluster.rs`) instead, because workers
//! own state.
//!
//! Scheduling: jobs are dealt round-robin onto per-worker deques at
//! submit time (chunked dispatch — a `map` over 0..jobs pre-spreads the
//! grid across workers with no contention on one shared queue), and an
//! idle worker that drains its own deque *steals* from the back of its
//! siblings' deques before parking. Skewed grids — one sweep cell 10×
//! the cost of the rest — therefore stop tail-blocking: the workers
//! that finish early take over the queue behind the slow cell. Where a
//! job *runs* is invisible to results by construction (the sweep layer
//! reassembles in spec order and derives per-spec rng seeds), so
//! `--jobs 1` ≡ `--jobs N` byte-for-byte survives stealing; the
//! skewed-grid pin lives in `rust/tests/test_sched_determinism.rs`.
//!
//! Panic policy: a panicking job must never wedge the pool. Worker
//! threads catch job panics and keep serving their deques, and [`map`]
//! forwards the first panic (in job-index order) to the submitting
//! thread via `resume_unwind` — the alternative is a forever-blocked
//! result channel. Fire-and-forget [`execute`] jobs that panic are
//! caught and dropped.
//!
//! [`map`]: ThreadPool::map
//! [`execute`]: ThreadPool::execute

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Park-state guarded by [`Shared::lock`]: the queued-job counter and
/// the shutdown flag. Deque mutation and counter update happen under
/// separate locks, so a worker can pop a just-pushed job and decrement
/// *before* the pusher's increment — the counter must therefore be
/// signed and unsaturated: the transient -1 is cancelled exactly by the
/// late +1. (A saturating unsigned counter would swallow the decrement
/// and drift permanently positive, leaving workers busy-spinning over
/// empty deques and `Drop::join` hung on the `queued > 0` rescan loop.)
/// Parked workers still treat the counter as a rescan hint, never as
/// ground truth about *which* deque holds work.
struct Control {
    queued: isize,
    shutdown: bool,
}

/// State shared by the pool handle and every worker thread.
struct Shared {
    /// One deque per worker. Owners pop the front; thieves pop the
    /// back, so a stolen job is the one queued longest — the fairness
    /// order that un-blocks a skewed tail fastest.
    queues: Vec<Mutex<VecDeque<Job>>>,
    lock: Mutex<Control>,
    cv: Condvar,
}

impl Shared {
    /// Take one job: own deque front first, then steal from siblings'
    /// backs (scan order rotated so thieves spread instead of mobbing
    /// worker 0).
    fn grab(&self, me: usize) -> Option<Job> {
        let size = self.queues.len();
        for off in 0..size {
            let q = (me + off) % size;
            let job = {
                let mut deque =
                    self.queues[q].lock().expect("pool queue poisoned");
                if off == 0 { deque.pop_front() } else { deque.pop_back() }
            };
            if let Some(job) = job {
                let mut ctl = self.lock.lock().expect("pool lock poisoned");
                // May transiently reach -1 when this pop beat the
                // pusher's increment; never saturate (see `Control`).
                ctl.queued -= 1;
                return Some(job);
            }
        }
        None
    }

    /// Queue `job` on deque `q` and wake a parked worker.
    fn push(&self, q: usize, job: Job) {
        self.queues[q]
            .lock()
            .expect("pool queue poisoned")
            .push_back(job);
        let mut ctl = self.lock.lock().expect("pool lock poisoned");
        ctl.queued += 1;
        self.cv.notify_one();
    }

    /// Pop-and-run one queued job, if any — lets a thread *waiting* on
    /// a [`Scope`] drain the pool instead of parking, so a saturated
    /// pool cannot deadlock a scope against its own queued jobs.
    fn try_run_one(&self) -> bool {
        match self.grab(0) {
            Some(job) => {
                let _ = catch_unwind(AssertUnwindSafe(job));
                true
            }
            None => false,
        }
    }
}

/// Fixed pool of worker threads executing boxed jobs off per-worker
/// work-stealing deques.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Round-robin dispatch cursor.
    next: AtomicUsize,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` threads. `size == 0` is a config error, not a panic:
    /// callers resolve "0 = available parallelism" *before* building the
    /// pool (see `sweep::SweepExecutor::new`).
    pub fn new(size: usize) -> Result<Self, String> {
        if size == 0 {
            return Err(
                "exec: thread pool needs at least one worker (size 0; \
                 resolve jobs=0 to the available parallelism first)"
                    .into(),
            );
        }
        let shared = Arc::new(Shared {
            queues: (0..size).map(|_| Mutex::new(VecDeque::new())).collect(),
            lock: Mutex::new(Control { queued: 0, shutdown: false }),
            cv: Condvar::new(),
        });
        let handles = (0..size)
            .map(|me| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || loop {
                    // Drain: own deque, then steal.
                    while let Some(job) = shared.grab(me) {
                        // Catch panics so one bad job cannot kill the
                        // worker and strand everything queued behind it.
                        let _ = catch_unwind(AssertUnwindSafe(job));
                    }
                    // Park until new work arrives or shutdown drains dry
                    // (pending jobs are always run before exit).
                    let mut ctl =
                        shared.lock.lock().expect("pool lock poisoned");
                    loop {
                        if ctl.queued > 0 {
                            break; // rescan the deques
                        }
                        if ctl.shutdown {
                            return;
                        }
                        ctl = shared
                            .cv
                            .wait(ctl)
                            .expect("pool lock poisoned");
                    }
                })
            })
            .collect();
        Ok(Self { shared, next: AtomicUsize::new(0), handles })
    }

    /// Submit a fire-and-forget job (its panic, if any, is swallowed —
    /// use [`ThreadPool::map`] when the caller must observe failures).
    /// Jobs are dealt round-robin across the worker deques.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let q = self.next.fetch_add(1, Ordering::Relaxed)
            % self.shared.queues.len();
        self.shared.push(q, Box::new(f));
    }

    /// Map `f` over `0..jobs` in parallel, collecting results in job
    /// order. If any job panicked, the panic with the smallest job index
    /// is re-raised on the calling thread after all jobs finished.
    pub fn map<T: Send + 'static>(
        &self,
        jobs: usize,
        f: impl Fn(usize) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<T>)>();
        for i in 0..jobs {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let result = catch_unwind(AssertUnwindSafe(|| f(i)));
                let _ = tx.send((i, result));
            });
        }
        drop(tx);
        let mut out: Vec<Option<std::thread::Result<T>>> =
            (0..jobs).map(|_| None).collect();
        // Every job sends exactly one message (panics included, caught
        // above), so this drains without blocking on a dead worker.
        for (i, v) in rx {
            out[i] = Some(v);
        }
        let mut vals = Vec::with_capacity(jobs);
        for v in out {
            match v.expect("pool job vanished without reporting") {
                Ok(t) => vals.push(t),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        vals
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.shared.queues.len()
    }

    /// Fork–join loop over `0..blocks` with at most `threads`
    /// participating threads: the calling thread plus up to
    /// `min(threads - 1, blocks - 1, pool size)` helper jobs dealt onto
    /// the work-stealing deques. Blocks are claimed dynamically (an
    /// atomic cursor), but *which indices exist* is fixed by `blocks`
    /// alone — determinism comes from the caller giving every block a
    /// fixed slice of work and reducing in fixed block order, never
    /// from the claim schedule.
    ///
    /// The call returns only after every block's `body` has returned;
    /// it never depends on a helper actually being scheduled (the
    /// caller claims blocks too), so a saturated pool degrades to the
    /// serial loop instead of deadlocking. If any `body` panics, the
    /// first-recorded panic is re-raised here after all claimed blocks
    /// settle; remaining unclaimed blocks are skipped.
    pub fn parallel_for<F>(&self, threads: usize, blocks: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        let helpers = threads
            .saturating_sub(1)
            .min(blocks.saturating_sub(1))
            .min(self.size());
        if blocks == 0 {
            return;
        }
        if helpers == 0 {
            for b in 0..blocks {
                body(b);
            }
            return;
        }
        let fj = Arc::new(ForkJoin {
            // SAFETY (lifetime erasure): helper jobs need 'static, but
            // `body` borrows this frame. The pointer is only ever
            // dereferenced by a participant that claimed a block index
            // `< blocks` (see `ForkJoin::work`), and every claimed
            // block increments `done` exactly once after its body call
            // returns — so this frame's wait below (`done == blocks`)
            // cannot finish while any dereference is outstanding.
            // Helpers arriving later find the cursor exhausted and
            // touch only the Arc'd counters.
            body: unsafe {
                std::mem::transmute::<
                    &(dyn Fn(usize) + Sync),
                    &'static (dyn Fn(usize) + Sync),
                >(&body)
            } as *const (dyn Fn(usize) + Sync),
            cursor: AtomicUsize::new(0),
            blocks,
            lock: Mutex::new(ForkJoinState { done: 0, panic: None }),
            cv: Condvar::new(),
            poisoned: AtomicBool::new(false),
        });
        for _ in 0..helpers {
            let fj = Arc::clone(&fj);
            self.execute(move || fj.work());
        }
        // The caller participates: progress never waits on a helper
        // getting scheduled.
        fj.work();
        let mut st = fj.lock.lock().expect("pool fork-join poisoned");
        while st.done < blocks {
            st = fj.cv.wait(st).expect("pool fork-join poisoned");
        }
        if let Some(payload) = st.panic.take() {
            drop(st);
            std::panic::resume_unwind(payload);
        }
    }

    /// Scoped fork–join: jobs spawned through the [`Scope`] may borrow
    /// from the enclosing stack frame, and `scope` does not return (or
    /// unwind) until every spawned job has finished. While waiting, the
    /// calling thread helps drain the pool's deques, so a saturated
    /// pool cannot deadlock a scope against its own queued jobs.
    ///
    /// Panic policy matches [`ThreadPool::map`]: a panicking spawned
    /// job never wedges the pool — workers keep serving their deques —
    /// and the first-recorded job panic (or the closure's own panic,
    /// which takes precedence) is re-raised here after all jobs settle.
    pub fn scope<'scope, F, R>(&'scope self, f: F) -> R
    where
        F: FnOnce(&Scope<'scope>) -> R,
    {
        let scope = Scope {
            pool: self,
            state: Arc::new(ScopeShared {
                lock: Mutex::new(ScopeState { outstanding: 0, panic: None }),
                cv: Condvar::new(),
            }),
            _marker: PhantomData,
        };
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // Settle every spawned job before returning OR unwinding: the
        // jobs borrow this frame. Help run queued work rather than
        // blocking while the deques still hold jobs.
        loop {
            {
                let st =
                    scope.state.lock.lock().expect("pool scope poisoned");
                if st.outstanding == 0 {
                    break;
                }
            }
            if !self.shared.try_run_one() {
                let mut st =
                    scope.state.lock.lock().expect("pool scope poisoned");
                // Re-check under the lock, then park: completions
                // notify `cv`, so no wakeup can be missed.
                if st.outstanding > 0 {
                    let _ = scope
                        .state
                        .cv
                        .wait(st)
                        .expect("pool scope poisoned");
                }
            }
        }
        let job_panic = scope
            .state
            .lock
            .lock()
            .expect("pool scope poisoned")
            .panic
            .take();
        match result {
            Err(payload) => std::panic::resume_unwind(payload),
            Ok(r) => {
                if let Some(payload) = job_panic {
                    std::panic::resume_unwind(payload);
                }
                r
            }
        }
    }
}

/// Shared state of one [`ThreadPool::parallel_for`] call. `body` is the
/// caller's closure with its lifetime erased; see the SAFETY note at
/// the construction site for why every dereference is sound.
struct ForkJoin {
    body: *const (dyn Fn(usize) + Sync),
    /// Next unclaimed block index (may run past `blocks`; claimants
    /// seeing `>= blocks` stop without touching `body`).
    cursor: AtomicUsize,
    blocks: usize,
    lock: Mutex<ForkJoinState>,
    cv: Condvar,
    /// Set on the first body panic: later claimants account their
    /// blocks without executing them, so the join finishes fast.
    poisoned: AtomicBool,
}

// SAFETY: `body` is `Sync` (shared calls are safe) and the protocol
// above guarantees it outlives every dereference.
unsafe impl Send for ForkJoin {}
unsafe impl Sync for ForkJoin {}

struct ForkJoinState {
    /// Blocks accounted for (executed, skipped-poisoned, or panicked).
    done: usize,
    /// First recorded body panic, re-raised by the submitting thread.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl ForkJoin {
    /// Claim and run blocks until the cursor is exhausted.
    fn work(&self) {
        loop {
            let b = self.cursor.fetch_add(1, Ordering::Relaxed);
            if b >= self.blocks {
                return;
            }
            if !self.poisoned.load(Ordering::Relaxed) {
                // SAFETY: `b < blocks` was claimed and not yet counted,
                // so the submitting frame is still alive (see the
                // construction-site SAFETY note).
                let body = unsafe { &*self.body };
                if let Err(payload) =
                    catch_unwind(AssertUnwindSafe(|| body(b)))
                {
                    self.poisoned.store(true, Ordering::Relaxed);
                    let mut st =
                        self.lock.lock().expect("pool fork-join poisoned");
                    if st.panic.is_none() {
                        st.panic = Some(payload);
                    }
                }
            }
            let mut st =
                self.lock.lock().expect("pool fork-join poisoned");
            st.done += 1;
            if st.done == self.blocks {
                self.cv.notify_all();
            }
        }
    }
}

/// Spawn handle passed to the closure of [`ThreadPool::scope`]. Jobs
/// spawned here may borrow anything that outlives the `scope` call.
pub struct Scope<'scope> {
    pool: &'scope ThreadPool,
    state: Arc<ScopeShared>,
    /// Invariant over `'scope` so the borrow checker cannot shrink the
    /// spawned jobs' lifetime below the scope's wait.
    _marker: PhantomData<&'scope mut &'scope ()>,
}

struct ScopeShared {
    lock: Mutex<ScopeState>,
    cv: Condvar,
}

struct ScopeState {
    /// Spawned jobs not yet finished.
    outstanding: usize,
    /// First recorded job panic.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl<'scope> Scope<'scope> {
    /// Queue `job` on the pool. It runs at most once; the enclosing
    /// [`ThreadPool::scope`] call waits for it before returning. A
    /// panic inside `job` is caught (the pool survives) and re-raised
    /// from the `scope` call.
    pub fn spawn<F>(&self, job: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        self.state
            .lock
            .lock()
            .expect("pool scope poisoned")
            .outstanding += 1;
        let state = Arc::clone(&self.state);
        let boxed: Box<dyn FnOnce() + Send + 'scope> = Box::new(job);
        // SAFETY (lifetime erasure): the pool's Job type is 'static,
        // but `scope` waits for `outstanding == 0` before its frame
        // (and anything `job` borrows) can go away — on the normal and
        // the unwinding path both.
        let boxed: Job = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'scope>,
                Box<dyn FnOnce() + Send + 'static>,
            >(boxed)
        };
        self.pool.execute(move || {
            let result = catch_unwind(AssertUnwindSafe(boxed));
            let mut st = state.lock.lock().expect("pool scope poisoned");
            if let Err(payload) = result {
                if st.panic.is_none() {
                    st.panic = Some(payload);
                }
            }
            st.outstanding -= 1;
            if st.outstanding == 0 {
                state.cv.notify_all();
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut ctl =
                self.shared.lock.lock().expect("pool lock poisoned");
            ctl.shutdown = true;
            self.shared.cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4).unwrap();
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8).unwrap();
        let out = pool.map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_size_is_a_config_error() {
        let err = ThreadPool::new(0).unwrap_err();
        assert!(err.contains("at least one worker"), "{err}");
    }

    #[test]
    #[should_panic(expected = "job 2 exploded")]
    fn map_propagates_job_panics_to_the_submitter() {
        // Regression: a panicking job used to kill its worker thread and
        // leave `map` blocked on the result channel forever (single-
        // thread pool) or panic with an opaque "job dropped".
        let pool = ThreadPool::new(1).unwrap();
        let _ = pool.map(5, |i| {
            if i == 2 {
                panic!("job 2 exploded");
            }
            i
        });
    }

    #[test]
    fn pool_survives_panicking_execute_jobs() {
        let pool = ThreadPool::new(2).unwrap();
        for _ in 0..4 {
            pool.execute(|| panic!("fire-and-forget failure"));
        }
        // The workers must still be alive to serve useful jobs.
        let out = pool.map(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn racing_push_and_grab_never_wedges_the_counter() {
        use std::time::Duration;
        // Regression: `grab` used to decrement `queued` with
        // `saturating_sub`. A worker popping a just-pushed job before
        // the pusher's increment saturated the decrement away, leaving
        // `queued` over-counted forever — workers busy-spun over empty
        // deques and `Drop::join` hung. Hammer many tiny jobs (maximum
        // pop-vs-increment overlap) across repeated pool lifetimes and
        // require the drop/join to finish under a watchdog.
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            for _ in 0..50 {
                let pool = ThreadPool::new(4).unwrap();
                let hits = Arc::new(AtomicUsize::new(0));
                for _ in 0..200 {
                    let hits = Arc::clone(&hits);
                    pool.execute(move || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    });
                }
                drop(pool); // join — hangs if the counter drifted
                assert_eq!(hits.load(Ordering::SeqCst), 200);
            }
            tx.send(()).unwrap();
        });
        rx.recv_timeout(Duration::from_secs(60))
            .expect("pool drop hung: queued counter drifted");
    }

    #[test]
    fn parallel_for_runs_every_block_exactly_once() {
        let pool = ThreadPool::new(4).unwrap();
        for (threads, blocks) in
            [(1usize, 7usize), (4, 1), (4, 64), (16, 5), (3, 0)]
        {
            let hits: Vec<AtomicUsize> =
                (0..blocks).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(threads, blocks, |b| {
                hits[b].fetch_add(1, Ordering::SeqCst);
            });
            for (b, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "block {b}");
            }
        }
    }

    #[test]
    fn parallel_for_borrows_and_writes_disjoint_stack_data() {
        let pool = ThreadPool::new(4).unwrap();
        let mut out = vec![0usize; 33];
        {
            let cells: Vec<Mutex<&mut usize>> =
                out.iter_mut().map(Mutex::new).collect();
            pool.parallel_for(4, cells.len(), |b| {
                **cells[b].lock().unwrap() = b * b;
            });
        }
        let want: Vec<usize> = (0..33).map(|b| b * b).collect();
        assert_eq!(out, want);
    }

    #[test]
    #[should_panic(expected = "block 3 exploded")]
    fn parallel_for_propagates_a_body_panic() {
        let pool = ThreadPool::new(2).unwrap();
        pool.parallel_for(2, 8, |b| {
            if b == 3 {
                panic!("block 3 exploded");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicking_parallel_for() {
        let pool = ThreadPool::new(2).unwrap();
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(2, 8, |b| {
                if b == 0 {
                    panic!("first block dies");
                }
            });
        }));
        assert!(r.is_err());
        // Workers must still be alive and the deques drained.
        let out = pool.map(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn scope_waits_for_borrowing_jobs() {
        let pool = ThreadPool::new(3).unwrap();
        let mut a = 0u64;
        let mut b = 0u64;
        pool.scope(|s| {
            s.spawn(|| a = 11);
            s.spawn(|| b = 22);
        });
        assert_eq!((a, b), (11, 22));
    }

    #[test]
    fn scope_on_a_saturated_pool_makes_progress() {
        // One worker, blocked on a barrier the *scope waiter* must
        // release by draining the deque itself (try_run_one).
        let pool = ThreadPool::new(1).unwrap();
        let barrier = Arc::new(Barrier::new(2));
        let b = Arc::clone(&barrier);
        pool.execute(move || {
            b.wait();
        });
        let hits = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    hits.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Open the barrier from the submitting thread's helper
            // loop or the worker, whichever gets there first.
            s.spawn(move || {
                barrier.wait();
            });
        });
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn skewed_jobs_get_stolen_instead_of_tail_blocking() {
        use std::sync::Barrier;
        use std::time::Duration;
        // Two workers; job 0 blocks its worker on a barrier that only
        // opens once every *other* job has run. Round-robin without
        // stealing would strand jobs 2 and 4 behind job 0 on worker 0's
        // deque forever; with stealing, worker 1 takes them and the
        // barrier opens.
        let pool = ThreadPool::new(2).unwrap();
        let barrier = Arc::new(Barrier::new(2));
        let done = Arc::new(AtomicUsize::new(0));
        let out = {
            let barrier = Arc::clone(&barrier);
            let done = Arc::clone(&done);
            pool.map(5, move |i| {
                if i == 0 {
                    barrier.wait();
                } else {
                    if done.fetch_add(1, Ordering::SeqCst) == 3 {
                        barrier.wait();
                    }
                    // Give the straggler room to demonstrate overlap.
                    std::thread::sleep(Duration::from_millis(1));
                }
                i * 10
            })
        };
        assert_eq!(out, vec![0, 10, 20, 30, 40]);
    }
}
