//! Minimal fixed-size work-stealing thread pool (no rayon/tokio
//! offline).
//!
//! Used by the sweep executor ([`crate::sweep::SweepExecutor`]) and
//! benches for embarrassingly-parallel jobs; the training cluster uses
//! dedicated per-worker threads (`cluster.rs`) instead, because workers
//! own state.
//!
//! Scheduling: jobs are dealt round-robin onto per-worker deques at
//! submit time (chunked dispatch — a `map` over 0..jobs pre-spreads the
//! grid across workers with no contention on one shared queue), and an
//! idle worker that drains its own deque *steals* from the back of its
//! siblings' deques before parking. Skewed grids — one sweep cell 10×
//! the cost of the rest — therefore stop tail-blocking: the workers
//! that finish early take over the queue behind the slow cell. Where a
//! job *runs* is invisible to results by construction (the sweep layer
//! reassembles in spec order and derives per-spec rng seeds), so
//! `--jobs 1` ≡ `--jobs N` byte-for-byte survives stealing; the
//! skewed-grid pin lives in `rust/tests/test_sched_determinism.rs`.
//!
//! Panic policy: a panicking job must never wedge the pool. Worker
//! threads catch job panics and keep serving their deques, and [`map`]
//! forwards the first panic (in job-index order) to the submitting
//! thread via `resume_unwind` — the alternative is a forever-blocked
//! result channel. Fire-and-forget [`execute`] jobs that panic are
//! caught and dropped.
//!
//! [`map`]: ThreadPool::map
//! [`execute`]: ThreadPool::execute

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Park-state guarded by [`Shared::lock`]: the queued-job counter and
/// the shutdown flag. Deque mutation and counter update happen under
/// separate locks, so a worker can pop a just-pushed job and decrement
/// *before* the pusher's increment — the counter must therefore be
/// signed and unsaturated: the transient -1 is cancelled exactly by the
/// late +1. (A saturating unsigned counter would swallow the decrement
/// and drift permanently positive, leaving workers busy-spinning over
/// empty deques and `Drop::join` hung on the `queued > 0` rescan loop.)
/// Parked workers still treat the counter as a rescan hint, never as
/// ground truth about *which* deque holds work.
struct Control {
    queued: isize,
    shutdown: bool,
}

/// State shared by the pool handle and every worker thread.
struct Shared {
    /// One deque per worker. Owners pop the front; thieves pop the
    /// back, so a stolen job is the one queued longest — the fairness
    /// order that un-blocks a skewed tail fastest.
    queues: Vec<Mutex<VecDeque<Job>>>,
    lock: Mutex<Control>,
    cv: Condvar,
}

impl Shared {
    /// Take one job: own deque front first, then steal from siblings'
    /// backs (scan order rotated so thieves spread instead of mobbing
    /// worker 0).
    fn grab(&self, me: usize) -> Option<Job> {
        let size = self.queues.len();
        for off in 0..size {
            let q = (me + off) % size;
            let job = {
                let mut deque =
                    self.queues[q].lock().expect("pool queue poisoned");
                if off == 0 { deque.pop_front() } else { deque.pop_back() }
            };
            if let Some(job) = job {
                let mut ctl = self.lock.lock().expect("pool lock poisoned");
                // May transiently reach -1 when this pop beat the
                // pusher's increment; never saturate (see `Control`).
                ctl.queued -= 1;
                return Some(job);
            }
        }
        None
    }

    /// Queue `job` on deque `q` and wake a parked worker.
    fn push(&self, q: usize, job: Job) {
        self.queues[q]
            .lock()
            .expect("pool queue poisoned")
            .push_back(job);
        let mut ctl = self.lock.lock().expect("pool lock poisoned");
        ctl.queued += 1;
        self.cv.notify_one();
    }
}

/// Fixed pool of worker threads executing boxed jobs off per-worker
/// work-stealing deques.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Round-robin dispatch cursor.
    next: AtomicUsize,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` threads. `size == 0` is a config error, not a panic:
    /// callers resolve "0 = available parallelism" *before* building the
    /// pool (see `sweep::SweepExecutor::new`).
    pub fn new(size: usize) -> Result<Self, String> {
        if size == 0 {
            return Err(
                "exec: thread pool needs at least one worker (size 0; \
                 resolve jobs=0 to the available parallelism first)"
                    .into(),
            );
        }
        let shared = Arc::new(Shared {
            queues: (0..size).map(|_| Mutex::new(VecDeque::new())).collect(),
            lock: Mutex::new(Control { queued: 0, shutdown: false }),
            cv: Condvar::new(),
        });
        let handles = (0..size)
            .map(|me| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || loop {
                    // Drain: own deque, then steal.
                    while let Some(job) = shared.grab(me) {
                        // Catch panics so one bad job cannot kill the
                        // worker and strand everything queued behind it.
                        let _ = catch_unwind(AssertUnwindSafe(job));
                    }
                    // Park until new work arrives or shutdown drains dry
                    // (pending jobs are always run before exit).
                    let mut ctl =
                        shared.lock.lock().expect("pool lock poisoned");
                    loop {
                        if ctl.queued > 0 {
                            break; // rescan the deques
                        }
                        if ctl.shutdown {
                            return;
                        }
                        ctl = shared
                            .cv
                            .wait(ctl)
                            .expect("pool lock poisoned");
                    }
                })
            })
            .collect();
        Ok(Self { shared, next: AtomicUsize::new(0), handles })
    }

    /// Submit a fire-and-forget job (its panic, if any, is swallowed —
    /// use [`ThreadPool::map`] when the caller must observe failures).
    /// Jobs are dealt round-robin across the worker deques.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let q = self.next.fetch_add(1, Ordering::Relaxed)
            % self.shared.queues.len();
        self.shared.push(q, Box::new(f));
    }

    /// Map `f` over `0..jobs` in parallel, collecting results in job
    /// order. If any job panicked, the panic with the smallest job index
    /// is re-raised on the calling thread after all jobs finished.
    pub fn map<T: Send + 'static>(
        &self,
        jobs: usize,
        f: impl Fn(usize) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<T>)>();
        for i in 0..jobs {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let result = catch_unwind(AssertUnwindSafe(|| f(i)));
                let _ = tx.send((i, result));
            });
        }
        drop(tx);
        let mut out: Vec<Option<std::thread::Result<T>>> =
            (0..jobs).map(|_| None).collect();
        // Every job sends exactly one message (panics included, caught
        // above), so this drains without blocking on a dead worker.
        for (i, v) in rx {
            out[i] = Some(v);
        }
        let mut vals = Vec::with_capacity(jobs);
        for v in out {
            match v.expect("pool job vanished without reporting") {
                Ok(t) => vals.push(t),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        vals
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut ctl =
                self.shared.lock.lock().expect("pool lock poisoned");
            ctl.shutdown = true;
            self.shared.cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4).unwrap();
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8).unwrap();
        let out = pool.map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_size_is_a_config_error() {
        let err = ThreadPool::new(0).unwrap_err();
        assert!(err.contains("at least one worker"), "{err}");
    }

    #[test]
    #[should_panic(expected = "job 2 exploded")]
    fn map_propagates_job_panics_to_the_submitter() {
        // Regression: a panicking job used to kill its worker thread and
        // leave `map` blocked on the result channel forever (single-
        // thread pool) or panic with an opaque "job dropped".
        let pool = ThreadPool::new(1).unwrap();
        let _ = pool.map(5, |i| {
            if i == 2 {
                panic!("job 2 exploded");
            }
            i
        });
    }

    #[test]
    fn pool_survives_panicking_execute_jobs() {
        let pool = ThreadPool::new(2).unwrap();
        for _ in 0..4 {
            pool.execute(|| panic!("fire-and-forget failure"));
        }
        // The workers must still be alive to serve useful jobs.
        let out = pool.map(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn racing_push_and_grab_never_wedges_the_counter() {
        use std::time::Duration;
        // Regression: `grab` used to decrement `queued` with
        // `saturating_sub`. A worker popping a just-pushed job before
        // the pusher's increment saturated the decrement away, leaving
        // `queued` over-counted forever — workers busy-spun over empty
        // deques and `Drop::join` hung. Hammer many tiny jobs (maximum
        // pop-vs-increment overlap) across repeated pool lifetimes and
        // require the drop/join to finish under a watchdog.
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            for _ in 0..50 {
                let pool = ThreadPool::new(4).unwrap();
                let hits = Arc::new(AtomicUsize::new(0));
                for _ in 0..200 {
                    let hits = Arc::clone(&hits);
                    pool.execute(move || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    });
                }
                drop(pool); // join — hangs if the counter drifted
                assert_eq!(hits.load(Ordering::SeqCst), 200);
            }
            tx.send(()).unwrap();
        });
        rx.recv_timeout(Duration::from_secs(60))
            .expect("pool drop hung: queued counter drifted");
    }

    #[test]
    fn skewed_jobs_get_stolen_instead_of_tail_blocking() {
        use std::sync::Barrier;
        use std::time::Duration;
        // Two workers; job 0 blocks its worker on a barrier that only
        // opens once every *other* job has run. Round-robin without
        // stealing would strand jobs 2 and 4 behind job 0 on worker 0's
        // deque forever; with stealing, worker 1 takes them and the
        // barrier opens.
        let pool = ThreadPool::new(2).unwrap();
        let barrier = Arc::new(Barrier::new(2));
        let done = Arc::new(AtomicUsize::new(0));
        let out = {
            let barrier = Arc::clone(&barrier);
            let done = Arc::clone(&done);
            pool.map(5, move |i| {
                if i == 0 {
                    barrier.wait();
                } else {
                    if done.fetch_add(1, Ordering::SeqCst) == 3 {
                        barrier.wait();
                    }
                    // Give the straggler room to demonstrate overlap.
                    std::thread::sleep(Duration::from_millis(1));
                }
                i * 10
            })
        };
        assert_eq!(out, vec![0, 10, 20, 30, 40]);
    }
}
