//! Minimal fixed-size thread pool (no rayon/tokio offline).
//!
//! Used by the sweep executor ([`crate::sweep::SweepExecutor`]) and
//! benches for embarrassingly-parallel jobs; the training cluster uses
//! dedicated per-worker threads (`cluster.rs`) instead, because workers
//! own state.
//!
//! Panic policy: a panicking job must never wedge the pool. Worker
//! threads catch job panics and keep serving the queue, and [`map`]
//! forwards the first panic (in job-index order) to the submitting
//! thread via `resume_unwind` — the alternative is a forever-blocked
//! result channel. Fire-and-forget [`execute`] jobs that panic are
//! caught and dropped.
//!
//! [`map`]: ThreadPool::map
//! [`execute`]: ThreadPool::execute

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` threads. `size == 0` is a config error, not a panic:
    /// callers resolve "0 = available parallelism" *before* building the
    /// pool (see `sweep::SweepExecutor::new`).
    pub fn new(size: usize) -> Result<Self, String> {
        if size == 0 {
            return Err(
                "exec: thread pool needs at least one worker (size 0; \
                 resolve jobs=0 to the available parallelism first)"
                    .into(),
            );
        }
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let handles = (0..size)
            .map(|_| {
                let rx = Arc::clone(&receiver);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("pool lock poisoned");
                        guard.recv()
                    };
                    match job {
                        // Catch panics so one bad job cannot kill the
                        // worker and strand everything queued behind it.
                        Ok(job) => {
                            let _ = catch_unwind(AssertUnwindSafe(job));
                        }
                        Err(_) => break, // all senders dropped
                    }
                })
            })
            .collect();
        Ok(Self { sender: Some(sender), handles })
    }

    /// Submit a fire-and-forget job (its panic, if any, is swallowed —
    /// use [`ThreadPool::map`] when the caller must observe failures).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Map `f` over `0..jobs` in parallel, collecting results in job
    /// order. If any job panicked, the panic with the smallest job index
    /// is re-raised on the calling thread after all jobs finished.
    pub fn map<T: Send + 'static>(
        &self,
        jobs: usize,
        f: impl Fn(usize) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<T>)>();
        for i in 0..jobs {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let result = catch_unwind(AssertUnwindSafe(|| f(i)));
                let _ = tx.send((i, result));
            });
        }
        drop(tx);
        let mut out: Vec<Option<std::thread::Result<T>>> =
            (0..jobs).map(|_| None).collect();
        // Every job sends exactly one message (panics included, caught
        // above), so this drains without blocking on a dead worker.
        for (i, v) in rx {
            out[i] = Some(v);
        }
        let mut vals = Vec::with_capacity(jobs);
        for v in out {
            match v.expect("pool job vanished without reporting") {
                Ok(t) => vals.push(t),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        vals
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.sender.take(); // close the channel
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4).unwrap();
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8).unwrap();
        let out = pool.map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_size_is_a_config_error() {
        let err = ThreadPool::new(0).unwrap_err();
        assert!(err.contains("at least one worker"), "{err}");
    }

    #[test]
    #[should_panic(expected = "job 2 exploded")]
    fn map_propagates_job_panics_to_the_submitter() {
        // Regression: a panicking job used to kill its worker thread and
        // leave `map` blocked on the result channel forever (single-
        // thread pool) or panic with an opaque "job dropped".
        let pool = ThreadPool::new(1).unwrap();
        let _ = pool.map(5, |i| {
            if i == 2 {
                panic!("job 2 exploded");
            }
            i
        });
    }

    #[test]
    fn pool_survives_panicking_execute_jobs() {
        let pool = ThreadPool::new(2).unwrap();
        for _ in 0..4 {
            pool.execute(|| panic!("fire-and-forget failure"));
        }
        // The workers must still be alive to serve useful jobs.
        let out = pool.map(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }
}
