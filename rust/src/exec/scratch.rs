//! `ScratchArena` — thread-keyed, shape-bucketed f32 buffer reuse.
//!
//! The gradient hot path wants short-lived d- and k·d-sized buffers
//! (per-shard residuals, per-responder gradient arenas). Allocating
//! them per [`RunSpec`](crate::sweep::RunSpec) — or worse, per round —
//! puts the allocator on the hot path; a sweep over hundreds of specs
//! re-pays the same allocations hundreds of times. This arena keeps
//! returned buffers in a **thread-local** free list bucketed by
//! capacity: sweep-pool worker threads persist across specs, so a
//! buffer released when one spec's backend drops is picked up by the
//! next spec that runs on the same worker.
//!
//! Thread-local (not global) keying is what keeps this invisible to
//! results: no cross-thread state, no locks, no ordering — a take is a
//! `BTreeMap` lookup and the returned buffer is **zero-filled to the
//! requested length**, so its history (which thread, which spec, which
//! capacity bucket) can never reach a computed byte. The fill is a
//! `memset` — the same cost a fresh `vec![0.0; len]` pays — so reuse
//! strictly saves the allocator round-trip.

use std::cell::RefCell;
use std::collections::BTreeMap;

thread_local! {
    /// Free buffers keyed by capacity; each bucket is a LIFO stack so
    /// the most recently used (cache-warm) buffer is taken first.
    static FREE_F32: RefCell<BTreeMap<usize, Vec<Vec<f32>>>> =
        const { RefCell::new(BTreeMap::new()) };
}

/// Take a zero-filled `Vec<f32>` of length `len`, reusing the smallest
/// pooled buffer whose capacity fits (best-fit), else allocating fresh.
pub fn take_f32(len: usize) -> Vec<f32> {
    let reused = FREE_F32.with(|free| {
        let mut free = free.borrow_mut();
        let key = free.range(len..).next().map(|(k, _)| *k);
        let key = key?;
        let bucket = free.get_mut(&key)?;
        let buf = bucket.pop();
        if bucket.is_empty() {
            free.remove(&key);
        }
        buf
    });
    match reused {
        Some(mut buf) => {
            buf.clear();
            buf.resize(len, 0.0);
            buf
        }
        None => vec![0.0; len],
    }
}

/// Return a buffer to the calling thread's pool for later reuse.
/// Zero-capacity buffers are dropped (nothing to reuse).
pub fn give_f32(buf: Vec<f32>) {
    let cap = buf.capacity();
    if cap == 0 {
        return;
    }
    FREE_F32.with(|free| {
        free.borrow_mut().entry(cap).or_default().push(buf);
    });
}

/// Number of buffers pooled on the calling thread (test support).
pub fn pooled_f32_buffers() -> usize {
    FREE_F32.with(|free| free.borrow().values().map(Vec::len).sum())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reuse_is_best_fit() {
        // Isolate from other tests sharing this thread's pool.
        FREE_F32.with(|f| f.borrow_mut().clear());
        let mut a = take_f32(100);
        a.iter_mut().for_each(|v| *v = f32::NAN);
        let cap_a = a.capacity();
        give_f32(a);
        let mut b = take_f32(400);
        b.iter_mut().for_each(|v| *v = 7.0);
        let cap_b = b.capacity();
        give_f32(b);
        assert_eq!(pooled_f32_buffers(), 2);

        // len=50 best-fits the 100-cap buffer, not the 400-cap one,
        // and arrives zeroed despite the NaN history.
        let c = take_f32(50);
        assert_eq!(c.capacity(), cap_a);
        assert!(c.iter().all(|v| v.to_bits() == 0));
        assert_eq!(pooled_f32_buffers(), 1);

        // len=200 fits only the 400-cap buffer.
        let d = take_f32(200);
        assert_eq!(d.capacity(), cap_b);
        assert!(d.iter().all(|v| v.to_bits() == 0));
        assert_eq!(pooled_f32_buffers(), 0);

        // Nothing pooled: a fresh allocation, still zeroed.
        let e = take_f32(1000);
        assert_eq!(e.len(), 1000);
        assert!(e.iter().all(|v| v.to_bits() == 0));
        give_f32(c);
        give_f32(d);
        give_f32(e);
        assert_eq!(pooled_f32_buffers(), 3);
        FREE_F32.with(|f| f.borrow_mut().clear());
    }

    #[test]
    fn zero_len_and_zero_cap_are_harmless() {
        let z = take_f32(0);
        assert!(z.is_empty());
        give_f32(Vec::new()); // dropped, not pooled
    }
}
