//! Gradient computation backends.
//!
//! The coordinator is backend-agnostic: a [`GradBackend`] produces worker
//! `i`'s partial gradient `∇F(S_i, w) = X_iᵀ(X_i w − y_i)/s` for the
//! current model. Two implementations:
//!
//! * [`NativeBackend`] — the pure-Rust linalg path. No artifacts needed,
//!   any shape; used by simulation sweeps and property tests.
//! * [`XlaBackend`](crate::runtime::XlaBackend) — the production path: the
//!   AOT-compiled JAX/Pallas artifact executed through PJRT. Defined next
//!   to the runtime so all PJRT types stay in one module.
//!
//! Both must agree numerically; `rust/tests/test_runtime.rs` asserts parity.

mod native;

pub use native::NativeBackend;

use crate::exec::Parallelism;

/// A source of per-shard partial gradients.
///
/// Not `Send`: the PJRT-backed implementation holds thread-affine client
/// handles; the master loop is single-threaded by design (the threaded
/// executor gives each worker thread its own state instead of sharing a
/// backend).
pub trait GradBackend {
    /// Compute worker `shard`'s partial gradient at `w` into `out` (len d).
    fn partial_grad(&mut self, shard: usize, w: &[f32], out: &mut [f32]);

    /// Compute the partial gradients of every shard in `shards` into the
    /// row-major `(shards.len(), d)` arena `out` — slot `i` receives
    /// shard `shards[i]`'s gradient. `par` is a **wall-clock hint
    /// only**: implementations must produce bitwise-identical bytes for
    /// every budget (the intra-round determinism contract, asserted by
    /// `test_sched_determinism`). Default: the serial [`partial_grad`]
    /// loop in slot order, ignoring `par` — correct for any backend,
    /// including thread-affine (non-`Send`) ones.
    ///
    /// [`partial_grad`]: GradBackend::partial_grad
    fn partial_grads(
        &mut self,
        shards: &[usize],
        w: &[f32],
        out: &mut [f32],
        par: Parallelism,
    ) {
        let _ = par;
        let d = self.dim();
        assert_eq!(
            out.len(),
            shards.len() * d,
            "partial_grads: arena shape mismatch"
        );
        for (slot, &i) in out.chunks_exact_mut(d.max(1)).zip(shards.iter()) {
            self.partial_grad(i, w, slot);
        }
    }

    /// Hook called by the master at the start of iteration `j` — backends
    /// whose per-worker data rotates across iterations (e.g. transformer
    /// microbatches) advance their cursor here. Default: no-op.
    fn on_iteration(&mut self, _j: u64) {}

    /// Whether [`GradBackend::all_grads`] is available (lets the master
    /// choose the batched path by k without a trial call).
    fn supports_all_grads(&self) -> bool {
        false
    }

    /// Batched fast path: compute ALL n shard gradients at `w` into `out`
    /// (row-major `(n, d)`), returning `true` if supported. The master
    /// prefers this for large k — one PJRT dispatch per iteration instead
    /// of k (§Perf: 196 µs for all 50 shards vs 15 µs per single dispatch
    /// ⇒ crossover near k = n/4). Semantically faithful: in the cluster
    /// every worker computes each iteration; the master just ignores
    /// straggler results. Default: unsupported.
    fn all_grads(&mut self, _w: &[f32], _out: &mut [f32]) -> bool {
        false
    }

    /// Feature dimension d.
    fn dim(&self) -> usize;

    /// Number of shards n.
    fn n_shards(&self) -> usize;

    /// Backend label for logs/benches.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
    use crate::model::full_gradient;

    #[test]
    fn native_partials_average_to_full_gradient() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 120, d: 8, ..Default::default() },
            5,
        );
        let shards = Shards::partition(&ds, 6);
        let mut backend = NativeBackend::new(shards);
        let w: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();

        let mut avg = vec![0.0f32; 8];
        let mut g = vec![0.0f32; 8];
        for i in 0..6 {
            backend.partial_grad(i, &w, &mut g);
            for j in 0..8 {
                avg[j] += g[j] / 6.0;
            }
        }
        let mut full = vec![0.0f32; 8];
        full_gradient(&ds.x, &ds.y, &w, &mut full);
        for j in 0..8 {
            let rel = (avg[j] - full[j]).abs() / full[j].abs().max(1.0);
            assert!(rel < 1e-4, "j={j}: {} vs {}", avg[j], full[j]);
        }
    }
}
