//! Pure-Rust gradient backend over the linalg substrate.
//!
//! Mirrors the fused Pallas kernel: one residual GEMV + one transposed
//! GEMV per shard, reusing a preallocated residual buffer (no allocation
//! on the iteration hot path — see EXPERIMENTS.md §Perf).

use super::GradBackend;
use crate::data::Shards;
use crate::linalg::{gemv, gemv_t};

/// Native (linalg) partial-gradient backend.
pub struct NativeBackend {
    shards: Shards,
    d: usize,
    /// Scratch residual, sized to the largest shard.
    resid: Vec<f32>,
}

impl NativeBackend {
    /// Wrap a sharded dataset.
    pub fn new(shards: Shards) -> Self {
        let d = shards.x[0].cols();
        let max_s = shards.x.iter().map(|m| m.rows()).max().unwrap_or(0);
        Self { shards, d, resid: vec![0.0; max_s] }
    }

    /// Borrow the shards (used by the exec mode to size worker state).
    pub fn shards(&self) -> &Shards {
        &self.shards
    }
}

impl GradBackend for NativeBackend {
    fn partial_grad(&mut self, shard: usize, w: &[f32], out: &mut [f32]) {
        let x = &self.shards.x[shard];
        let y = &self.shards.y[shard];
        let s = x.rows();
        let r = &mut self.resid[..s];
        // r = X_i w − y_i
        gemv(1.0, x, w, 0.0, r);
        for (ri, yi) in r.iter_mut().zip(y.iter()) {
            *ri -= *yi;
        }
        // out = X_iᵀ r / s
        gemv_t(1.0 / s as f32, x, r, 0.0, out);
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn n_shards(&self) -> usize {
        self.shards.n()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticConfig, SyntheticDataset};

    #[test]
    fn zero_residual_gives_zero_gradient() {
        // Construct a shard where y = X w exactly.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 20, d: 4, ..Default::default() },
            11,
        );
        let mut shards = Shards::partition(&ds, 2);
        let w = [1.0f32, 2.0, 3.0, 4.0];
        for i in 0..2 {
            for r in 0..shards.x[i].rows() {
                let dot: f32 = shards.x[i]
                    .row(r)
                    .iter()
                    .zip(&w)
                    .map(|(a, b)| a * b)
                    .sum();
                shards.y[i][r] = dot;
            }
        }
        let mut backend = NativeBackend::new(shards);
        let mut g = vec![1.0f32; 4];
        backend.partial_grad(0, &w, &mut g);
        for v in g {
            assert!(v.abs() < 1e-3, "{v}");
        }
    }

    #[test]
    fn shards_of_different_sizes_are_handled() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 11, d: 3, ..Default::default() },
            12,
        );
        let shards = Shards::partition_uneven(&ds, 3);
        let mut backend = NativeBackend::new(shards);
        let w = [0.5f32, -0.5, 1.0];
        let mut g = vec![0.0f32; 3];
        for i in 0..3 {
            backend.partial_grad(i, &w, &mut g);
            assert!(g.iter().all(|v| v.is_finite()));
        }
    }
}
