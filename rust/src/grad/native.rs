//! Pure-Rust gradient backend over the linalg substrate.
//!
//! Mirrors the fused Pallas kernel: one residual GEMV + one transposed
//! GEMV per shard, reusing a preallocated residual buffer (no allocation
//! on the iteration hot path — see EXPERIMENTS.md §Perf).

use super::GradBackend;
use crate::data::Shards;
use crate::exec::{for_each_block_mut, for_each_slot_mut, scratch, Parallelism};
use crate::linalg::{gemv, gemv_t, gemv_t_cols, Matrix};

/// One shard's partial gradient with caller-provided residual scratch:
/// `out ← X_iᵀ (X_i w − y_i) / s`. The same kernel sequence as
/// [`NativeBackend::partial_grad`], factored free of `&mut self` so
/// intra-round workers can run it concurrently, each with per-thread
/// scratch.
fn grad_into(
    x: &Matrix,
    y: &[f32],
    w: &[f32],
    resid: &mut [f32],
    out: &mut [f32],
) {
    let s = x.rows();
    let r = &mut resid[..s];
    // r = X_i w − y_i
    gemv(1.0, x, w, 0.0, r);
    for (ri, yi) in r.iter_mut().zip(y.iter()) {
        *ri -= *yi;
    }
    // out = X_iᵀ r / s
    gemv_t(1.0 / s as f32, x, r, 0.0, out);
}

/// Native (linalg) partial-gradient backend.
pub struct NativeBackend {
    shards: Shards,
    d: usize,
    /// Scratch residual, sized to the largest shard.
    resid: Vec<f32>,
}

impl NativeBackend {
    /// Wrap a sharded dataset.
    pub fn new(shards: Shards) -> Self {
        let d = shards.x[0].cols();
        let max_s = shards.x.iter().map(|m| m.rows()).max().unwrap_or(0);
        Self { shards, d, resid: vec![0.0; max_s] }
    }

    /// Borrow the shards (used by the exec mode to size worker state).
    pub fn shards(&self) -> &Shards {
        &self.shards
    }
}

impl GradBackend for NativeBackend {
    fn partial_grad(&mut self, shard: usize, w: &[f32], out: &mut [f32]) {
        grad_into(
            &self.shards.x[shard],
            &self.shards.y[shard],
            w,
            &mut self.resid,
            out,
        );
    }

    /// Intra-round parallel override. Multiple responders split by
    /// responder (each slot a disjoint arena slice, per-thread residual
    /// scratch from [`scratch`]); a single responder splits the
    /// back-projection `X_iᵀ r` by column block instead
    /// ([`gemv_t_cols`]). Both are bitwise-identical to the serial loop:
    /// every output element is accumulated in the same ascending-row
    /// order regardless of how columns or responders are partitioned.
    fn partial_grads(
        &mut self,
        shards: &[usize],
        w: &[f32],
        out: &mut [f32],
        par: Parallelism,
    ) {
        let d = self.d;
        assert_eq!(
            out.len(),
            shards.len() * d,
            "partial_grads: arena shape mismatch"
        );
        if par.is_serial() || shards.is_empty() {
            for (slot, &i) in
                out.chunks_exact_mut(d.max(1)).zip(shards.iter())
            {
                self.partial_grad(i, w, slot);
            }
        } else if shards.len() == 1 {
            let x = &self.shards.x[shards[0]];
            let y = &self.shards.y[shards[0]];
            let s = x.rows();
            let r = &mut self.resid[..s];
            gemv(1.0, x, w, 0.0, r);
            for (ri, yi) in r.iter_mut().zip(y.iter()) {
                *ri -= *yi;
            }
            let r = &self.resid[..s];
            let alpha = 1.0 / s as f32;
            for_each_block_mut(par, out, |col0, panel| {
                gemv_t_cols(alpha, x, r, 0.0, panel, col0);
            });
        } else {
            let data = &self.shards;
            for_each_slot_mut(par, out, shards.len(), d, |slot_i, slot| {
                let i = shards[slot_i];
                let x = &data.x[i];
                let mut resid = scratch::take_f32(x.rows());
                grad_into(x, &data.y[i], w, &mut resid, slot);
                scratch::give_f32(resid);
            });
        }
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn n_shards(&self) -> usize {
        self.shards.n()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticConfig, SyntheticDataset};

    #[test]
    fn zero_residual_gives_zero_gradient() {
        // Construct a shard where y = X w exactly.
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 20, d: 4, ..Default::default() },
            11,
        );
        let mut shards = Shards::partition(&ds, 2);
        let w = [1.0f32, 2.0, 3.0, 4.0];
        for i in 0..2 {
            for r in 0..shards.x[i].rows() {
                let dot: f32 = shards.x[i]
                    .row(r)
                    .iter()
                    .zip(&w)
                    .map(|(a, b)| a * b)
                    .sum();
                shards.y[i][r] = dot;
            }
        }
        let mut backend = NativeBackend::new(shards);
        let mut g = vec![1.0f32; 4];
        backend.partial_grad(0, &w, &mut g);
        for v in g {
            assert!(v.abs() < 1e-3, "{v}");
        }
    }

    #[test]
    fn shards_of_different_sizes_are_handled() {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 11, d: 3, ..Default::default() },
            12,
        );
        let shards = Shards::partition_uneven(&ds, 3);
        let mut backend = NativeBackend::new(shards);
        let w = [0.5f32, -0.5, 1.0];
        let mut g = vec![0.0f32; 3];
        for i in 0..3 {
            backend.partial_grad(i, &w, &mut g);
            assert!(g.iter().all(|v| v.is_finite()));
        }
    }

    /// The responder-parallel and panel-parallel paths must be bitwise
    /// equal to the serial loop — the intra-round determinism contract
    /// at the backend level. NaN-poisoned output arenas double as a
    /// regression check that beta=0 kernels overwrite.
    #[test]
    fn partial_grads_is_bitwise_jobs_invariant() {
        use crate::exec::Parallelism;
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 64, d: 33, ..Default::default() },
            21,
        );
        let shards = Shards::partition_uneven(&ds, 5);
        let mut backend = NativeBackend::new(shards);
        let w: Vec<f32> =
            (0..33).map(|i| (i as f32 - 16.0) * 0.37).collect();

        let resp = [4usize, 0, 2];
        let mut serial = vec![f32::NAN; 3 * 33];
        backend.partial_grads(&resp, &w, &mut serial, Parallelism::SERIAL);
        for jobs in [2usize, 4, 16] {
            let mut parallel = vec![f32::NAN; 3 * 33];
            backend.partial_grads(
                &resp,
                &w,
                &mut parallel,
                Parallelism::new(jobs),
            );
            assert_eq!(bits(&parallel), bits(&serial), "jobs={jobs}");
        }

        // A single responder takes the column-panel path instead.
        let mut one_serial = vec![f32::NAN; 33];
        backend.partial_grads(&[3], &w, &mut one_serial, Parallelism::SERIAL);
        let mut one_par = vec![f32::NAN; 33];
        backend.partial_grads(&[3], &w, &mut one_par, Parallelism::new(4));
        assert_eq!(bits(&one_par), bits(&one_serial));
        assert!(one_par.iter().all(|v| v.is_finite()));
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
