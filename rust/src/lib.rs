//! # adasgd — Adaptive Distributed Fastest-k SGD
//!
//! Production-shaped reproduction of *“Adaptive Distributed Stochastic
//! Gradient Descent for Minimizing Delay in the Presence of Stragglers”*
//! (Kas Hanna, Bitar, Parag, Dasari, El Rouayheb — ICASSP 2020).
//!
//! The library is the Layer-3 Rust coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — fastest-k master loop, adaptive-k policies
//!   (Algorithm 1's Pflug test, Theorem 1's bound-optimal schedule),
//!   straggler simulation, gradient communication model ([`comm`]:
//!   compression, error feedback, per-worker uplink costs), async-SGD
//!   baseline, metrics, CLI.
//! * **L2/L1 (build-time Python)** — JAX models + Pallas kernels, AOT
//!   lowered to HLO text in `artifacts/`, executed through the PJRT
//!   runtime in `runtime` (behind the `pjrt` feature). Python never runs
//!   at training time.
//!
//! ## Driver architecture
//!
//! All training drivers are thin adapters over one event-driven
//! simulation core, [`engine`]: an [`engine::EngineCore`] owns the
//! per-round mechanics (broadcast pricing, delay sampling, uplink
//! transmit, ingress clocks, the SGD apply, metric recording — each in
//! exactly one place), and an [`engine::GatherPolicy`] plugs in the
//! gather discipline. [`master::run_fastest_k_comm`] runs
//! [`engine::FastestKGather`] (the paper's sync round),
//! [`async_sgd::run_async_comm`] runs [`engine::StalenessGather`]
//! (Dutta et al.'s async comparator, with exact processor-sharing
//! ingress via completion events), [`coding::run_coded_comm`] runs
//! [`engine::CodedGather`] (below), and
//! [`exec::ThreadedCluster::run_with_comm`] /
//! [`exec::ThreadedCluster::run_async_comm`] feed the same engine from
//! real OS threads — deterministically, since the threaded master
//! decides by *virtual* time, so the live cluster reproduces the
//! simulator bit for bit. Default-channel trajectories are bit-for-bit
//! the pre-engine drivers' (asserted by
//! `rust/tests/test_engine_equivalence.rs`); a new discipline is one
//! more `GatherPolicy` impl, not a new driver.
//!
//! ## Gradient coding
//!
//! [`coding`] is a placement/execution split: a [`coding::CodingScheme`]
//! (fractional repetition, cyclic windows, or a seeded random r-regular
//! "Bernoulli" placement) describes which `r` shards each worker holds
//! and how a responder set decodes into a shard cover, while
//! [`engine::CodedGather`] executes any such scheme through the engine —
//! the k policy adapts the *wait target*, the round extends along the
//! arrival order to the first decodable responder set, and each round
//! applies the **exact** full gradient at `r ×` compute (and `r ×`
//! straggler tolerance). Because it rides the engine, coded GD is priced
//! on the same clock as fastest-k: broadcast downlink, uplink
//! compression + error feedback, and shared-ingress contention all
//! apply (`benches/fig_coding.rs` sweeps scheme × r × k-policy ×
//! ingress). `coding::run_coded_gd` keeps the legacy compute-only
//! interface as a shim; `rust/tests/test_coded_equivalence.rs` holds
//! the loop-vs-engine and `r = 1` ≡ fastest-k bitwise contracts.
//!
//! ## Communication model
//!
//! Every driver ships gradients through a [`comm::CommChannel`]. The
//! default channel ([`comm::CommChannel::dense`]) is dense f32 over a
//! zero-cost link, which reproduces the paper's compute-only timing
//! exactly; swapping in [`comm::TopK`]/[`comm::QuantizeQsgd`]/
//! [`comm::RandK`] over a finite-bandwidth [`comm::LinkModel`] adds a
//! per-worker virtual upload delay to each response time *before* the
//! fastest-k gather, and [`comm::ErrorFeedback`] carries the compression
//! residual so convergence is preserved. The link is bidirectional: a
//! [`comm::Broadcast`] prices the master's model downlink (dense, or
//! compressed model deltas with a master-side residual), and a
//! [`comm::IngressModel`] makes a round's accepted uploads contend on
//! the master's shared ingress (FIFO) instead of arriving independently.
//! See `benches/fig_comm_tradeoff` and `benches/fig_bidirectional`.
//!
//! ## Experiment sweeps
//!
//! Figures and comparators are grids of thousands of *independent*
//! simulations, and [`sweep`] executes all of them: a
//! [`sweep::SweepGrid`] expands cartesian products of config edits into
//! ordered [`sweep::RunSpec`]s, and a [`sweep::SweepExecutor`] fans them
//! out over [`exec::ThreadPool`] (`--jobs` / `[run] jobs`; `0` = all
//! cores). The layer's determinism rule: every spec's RNG streams derive
//! from its own seed, pinned at grid-build time
//! ([`sweep::derive_seed`]), and outputs are reassembled in spec order —
//! so `jobs = 1` and `jobs = N` are **byte-identical**, CSVs included
//! (`rust/tests/test_sweep_equivalence.rs`). The coordinator's figure
//! generators, `run_repeated`, and every `benches/fig_*.rs` grid run
//! through this layer; CSV emission is unified through
//! [`metrics::write_csv_with_header`] with the scenario axes as
//! run-header meta lines ([`sweep::write_sweep_csv`]).
//!
//! ## Event tracing
//!
//! [`trace`] is the observability spine: with tracing enabled
//! (`EngineCore::enable_trace`, the `[trace]` TOML section, or
//! `--trace <dir>`), the engine records every broadcast, per-worker
//! compute sample, uplink transmit, ingress service, gradient apply,
//! and adaptive k-change into a versioned binary [`trace::Trace`] —
//! under all four gather disciplines. The trace is a standalone
//! artifact: [`trace::ReplayDelays`] re-drives the engine from it and
//! reproduces the original model trajectory, clock, and recorder
//! samples *bitwise* (the `trace replay` CLI command asserts this);
//! [`trace::TraceAnalysis`] computes per-worker utilization, ingress
//! queueing, staleness histograms, and per-round wait decomposition
//! without re-running anything (`trace analyze`); and
//! [`straggler::TraceDelays::from_event_trace`] mines the recorded
//! delay sequence into a replayable straggler scenario for *new*
//! experiments. Tracing is off by default and observationally free:
//! enabling it changes no RNG draw, clock value, or output byte.
//!
//! ## Perf
//!
//! Performance work is measured, recorded, and diffable: `cargo bench
//! --bench perf_hotpath` times the hot paths and writes
//! `results/BENCH_hotpath.json`, and `-- --baseline <prior.json>`
//! prints per-entry median deltas against an earlier report (CI smoke
//! diffs against the committed repo-root `BENCH_hotpath.json` snapshot
//! and then against its own first run). Four structural optimizations
//! carry the scale story:
//!
//! * **Order-statistics fastpath** ([`engine::FastpathGather`] over
//!   [`stats::ClassOrderSampler`], opt-in via `[run] fastpath` /
//!   `--fastpath`). A synchronous fastest-k round normally draws all n
//!   response times and selects the k fastest; for closed-form delay
//!   models the first-k arrivals can be sampled *directly* from the
//!   order-statistics law (Rényi spacings for exponential, conditional
//!   inverse-CDF recursion otherwise), making n = 10⁶ rounds
//!   practical. The class-merge argument extends this to
//!   class-heterogeneous priced fleets: partition workers into
//!   homogeneous (delay law × uplink constant) classes; each class's
//!   ascending arrival stream shifted by its per-worker-constant
//!   upload delay keeps ascending order, so each class head is its
//!   minimum remaining response time and the argmin over heads is the
//!   next global order statistic — a k-way merge in O(k · classes),
//!   independent of n. The merged prefix then flows through the same
//!   O(k) FIFO ingress chain and uniform download constant the
//!   exhaustive engine prices, so byte meters and `CommStats` agree
//!   exactly. The contract is **distributional, not bitwise**: a
//!   fastpath run is a different — equally valid — draw of the same
//!   stochastic process (`rust/tests/test_fastpath_stats.rs`), so it
//!   is OFF by default and every default trajectory stays
//!   bit-identical.
//! * **Allocation-free rounds** — per-round buffers (engine gather
//!   state, the fastpath's arrival/partial buffers, the threaded
//!   cluster's shared-model `Arc`) are allocated once and reused, so
//!   steady-state rounds do no heap allocation; the free-downlink
//!   broadcast scan is skipped outright (bitwise neutral, since it
//!   only ever adds exact zeros).
//! * **Work-stealing sweeps** — [`exec::ThreadPool`] deals jobs onto
//!   per-worker deques and lets idle workers steal from siblings'
//!   backs, so a skewed grid no longer tail-blocks behind its most
//!   expensive cell. Where a job runs never reaches results (pinned
//!   per-spec seeds + spec-order reassembly): `--jobs 1` ≡ `--jobs N`
//!   byte-for-byte (`rust/tests/test_sched_determinism.rs`).
//! * **Deterministic intra-round parallelism** (`[run] intra_jobs` /
//!   `--intra-jobs`, default 1 = exactly the serial path). One round
//!   forks on the *same* shared [`exec::ThreadPool`] via scoped
//!   fork–join ([`exec::ThreadPool::parallel_for`]): the k responders'
//!   partial gradients land in per-responder slices of a persistent
//!   scratch arena ([`exec::scratch`]) and reduce in fixed responder
//!   order, and the d-dimensional merge/apply loops split into fixed
//!   [`exec::INTRA_BLOCK`] column blocks. The determinism argument is
//!   structural, not scheduling-dependent: block boundaries are pure
//!   functions of the shape (never of thread count or claim order),
//!   every block writes a disjoint slice, and all reductions run
//!   serially in fixed order after the join — so no float operation is
//!   ever re-associated and `--intra-jobs 1` ≡ `--intra-jobs N`
//!   byte-for-byte, composing with `--jobs` on one pool (no `J × I`
//!   oversubscription). `transmit` stays strictly serial (it draws
//!   comm RNG in worker order). The kernels underneath got the same
//!   treatment: `gemv_t` walks fixed column panels
//!   ([`linalg::GEMV_T_PANEL`]) so the output stays cache-resident
//!   across rows — bitwise-identical to the row-walk because each
//!   output element still accumulates in ascending row order.
//!
//! ## Determinism rules
//!
//! The bitwise guarantees above (`--jobs 1` ≡ `--jobs N`, simulator ≡
//! threaded cluster, record ≡ replay) are protected at the source
//! level by an in-repo static-analysis pass, [`analysis`] (`adasgd
//! lint`, a CI gate). The rules, each a one-line promise:
//!
//! * **D001** — float orderings use `f64::total_cmp`, never
//!   `partial_cmp(..).unwrap()`: a NaN must reorder deterministically,
//!   not panic mid-run.
//! * **D002** — no `HashMap`/`HashSet` inside the deterministic
//!   modules (`engine`, `sweep`, `trace`, `sim`, `comm`, `coding`):
//!   hash iteration order is process-seeded and would leak into
//!   trajectories, CSVs, and traces.
//! * **D003** — no wall-clock reads (`Instant::now`, `SystemTime`)
//!   outside `bench_harness`: the engine's virtual clock is the only
//!   time source allowed to influence results.
//! * **D004** — no literal-seeded RNG construction: every stream
//!   derives from the run seed ([`engine::RngStreams`],
//!   [`sweep::derive_seed`]), so `--seed` reaches every draw.
//! * **D005** — no `println!`/`eprintln!` in library modules: output
//!   flows through [`metrics`]; stdout belongs to [`cli`] and benches.
//! * **D006** — no `thread::spawn` outside [`exec`]: all parallelism
//!   shares one pool, so sweep- and intra-round fan-out compose
//!   without oversubscription and every reduction stays fixed-order.
//! * **L001** — `use crate::X` edges must appear in the layering
//!   table (`analysis::ALLOWED_IMPORTS`): the engine stays embeddable
//!   and the dependency graph acyclic.
//! * **S001** — the CSV header constant and the trace `KIND_*` tags
//!   must match the registered schema versions: committed readers
//!   keep reading recorded artifacts.
//!
//! The escape hatch is an explicit inline pragma with a justification
//! (`// detlint: allow(D003)` on the offending or preceding line);
//! suppressed findings stay visible in the report and the CI
//! artifact. See [`analysis`] for the full scan scope.
//!
//! ## Quick start
//!
//! ```no_run
//! use adasgd::prelude::*;
//!
//! // Paper Fig. 2 setup: n = 50 workers, exp(1) response times.
//! let ds = SyntheticDataset::generate(SyntheticConfig::default(), 0);
//! let problem = LinRegProblem::new(&ds);
//! let mut backend = NativeBackend::new(Shards::partition(&ds, 50));
//! let delays = ExponentialDelays::new(1.0);
//! let mut policy = AdaptivePflug::new(50, PflugParams::default());
//! let cfg = MasterConfig { eta: 5e-4, max_time: 2500.0, ..Default::default() };
//! let run = run_fastest_k(
//!     &mut backend, &delays, &mut policy,
//!     &vec![0.0; problem.d()], &cfg,
//!     &mut |w| problem.error(w),
//! );
//! println!("reached error {:.3e}", run.recorder.last().unwrap().error);
//! ```

pub mod analysis;
pub mod async_sgd;
pub mod bench_harness;
pub mod cli;
pub mod coding;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod exec;
pub mod grad;
pub mod linalg;
pub mod master;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod proptest_lite;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod straggler;
pub mod sweep;
pub mod theory;
pub mod trace;
pub mod transformer;

/// One-stop imports for examples and benches.
pub mod prelude {
    pub use crate::async_sgd::{
        run_async, run_async_comm, run_async_comm_traced, AsyncConfig,
        AsyncRun,
    };
    pub use crate::comm::{
        Broadcast, CommChannel, CommStats, Compressor, Dense, DownlinkMode,
        ErrorFeedback, IngressDiscipline, IngressModel, LinkModel,
        QuantizeQsgd, RandK, TopK, WireFormat,
    };
    pub use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
    pub use crate::engine::{
        CodedGather, EngineConfig, EngineCore, EngineRun, FastestKGather,
        FastpathGather, GatherPolicy, RngStreams, RoundEngine,
        StalenessGather,
    };
    pub use crate::grad::{GradBackend, NativeBackend};
    pub use crate::master::{
        run_fastest_k, run_fastest_k_comm, run_fastest_k_comm_traced,
        FastestKRun, MasterConfig,
    };
    pub use crate::metrics::{write_csv, AsciiPlot, Recorder, Sample};
    pub use crate::model::LinRegProblem;
    pub use crate::policy::{
        AdaptivePflug, BoundOptimal, FixedK, KPolicy, PflugParams,
        TimeSchedule, VarianceTest, VarianceTestParams,
    };
    pub use crate::rng::{Pcg64, Rng};
    pub use crate::stats::{ClassOrderSampler, OrderStatSampler, OrderStats};
    pub use crate::coding::{
        run_coded_comm, run_coded_comm_traced, run_coded_gd, BernoulliScheme,
        CodedConfig, CodingScheme, CoverPart, CyclicRepetition, FrcScheme,
    };
    pub use crate::straggler::{
        BimodalDelays, DelayModel, ExponentialDelays, MarkovDelays,
        ParetoDelays, ShiftedExponentialDelays, TraceDelays, WeibullDelays,
    };
    pub use crate::sweep::{
        derive_seed, edit, sweep_meta, write_sweep_csv, CfgEdit, RunSpec,
        SweepExecutor, SweepGrid,
    };
    pub use crate::theory::{
        adaptive_envelope, switching_times, BoundParams, ErrorBound,
    };
    pub use crate::trace::{
        Discipline, Event, ReplayDelays, Trace, TraceAnalysis,
    };
}
