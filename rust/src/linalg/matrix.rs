//! Row-major dense `f32` matrix.

/// Row-major dense matrix of `f32`.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero-filled `rows x cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from an owned row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Self { rows, cols, data }
    }

    /// Build from row slices (test convenience).
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-major backing slice.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major backing slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// A new matrix holding rows `[lo, hi)` (a data shard).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.rows, "row range out of bounds");
        Matrix::from_vec(
            hi - lo,
            self.cols,
            self.data[lo * self.cols..hi * self.cols].to_vec(),
        )
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_round_trip() {
        let mut m = Matrix::zeros(3, 4);
        m[(2, 1)] = 5.0;
        assert_eq!(m[(2, 1)], 5.0);
        assert_eq!(m.row(2)[1], 5.0);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(2, 1)], 6.0);
    }

    #[test]
    fn slice_rows_shard() {
        let m = Matrix::from_rows(&[&[1.0], &[2.0], &[3.0], &[4.0]]);
        let shard = m.slice_rows(1, 3);
        assert_eq!(shard.rows(), 2);
        assert_eq!(shard[(0, 0)], 2.0);
        assert_eq!(shard[(1, 0)], 3.0);
    }

    #[test]
    #[should_panic(expected = "buffer/shape mismatch")]
    fn from_vec_checks_len() {
        Matrix::from_vec(2, 2, vec![0.0; 3]);
    }

    #[test]
    fn eye_is_identity() {
        let i = Matrix::eye(3);
        assert_eq!(i[(0, 0)], 1.0);
        assert_eq!(i[(0, 1)], 0.0);
        assert_eq!(i.frobenius(), 3.0f64.sqrt());
    }
}
