//! Dense linear-algebra substrate (no BLAS / ndarray available offline).
//!
//! Row-major `f32` matrices and the handful of operations the native
//! gradient backend and the theory module need: blocked GEMM, GEMV, axpy,
//! dot, norms, and a small Cholesky solver (used to compute the exact
//! optimum `w* = (XᵀX)⁻¹ Xᵀy` so experiments can report `F(w) − F*`).
//!
//! Perf notes (see EXPERIMENTS.md §Perf): `gemv`/`gemv_t` dominate the
//! native hot path; they are written as cache-friendly row walks with
//! 8-lane `chunks_exact` inner loops that LLVM auto-vectorizes, and both
//! switch to column-panel blocking past [`GEMV_PANEL`]/[`GEMV_T_PANEL`]
//! columns — bitwise identical to the row walks by construction. The
//! blocked `gemm` is only used in setup (normal equations), not
//! per-iteration.

mod matrix;
mod ops;
mod solve;

pub use matrix::Matrix;
pub use ops::{
    axpy, dot, dot_f32, gemm, gemv, gemv_blocked, gemv_rowwalk, gemv_t,
    gemv_t_blocked, gemv_t_cols, gemv_t_rowwalk, nrm2, scal, GEMV_PANEL,
    GEMV_T_PANEL,
};
pub use solve::{
    cholesky_solve, cholesky_solve_dense_f64, cholesky_solve_f64,
    CholeskyError,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_normal_equations() {
        // Solve a tiny least-squares problem exactly.
        // X = [[1,0],[0,1],[1,1]], y = [1, 2, 3.1]
        let x = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0], &[1.0, 1.0]]);
        let y = [1.0f32, 2.0, 3.1];
        // XtX and Xty
        let mut xtx = Matrix::zeros(2, 2);
        gemm(1.0, &x.transpose(), &x, 0.0, &mut xtx);
        let mut xty = vec![0.0f32; 2];
        gemv_t(1.0, &x, &y, 0.0, &mut xty);
        let w = cholesky_solve(&xtx, &xty).unwrap();
        // Residual should be tiny and symmetric: w ~ [1.033, 2.033]
        assert!((w[0] - 1.0333).abs() < 1e-3, "{w:?}");
        assert!((w[1] - 2.0333).abs() < 1e-3, "{w:?}");
    }
}
