//! BLAS-like kernels over [`Matrix`] and slices.
//!
//! The per-iteration native hot path of the coordinator is
//! `gemv` (residual `X w`) + `gemv_t` (back-projection `Xᵀ r`); both are
//! single-pass row walks so the shard matrix streams through cache once,
//! mirroring the fused Pallas kernel's single HBM pass.

use super::Matrix;

/// `y ← alpha * x + y`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut yc = y.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for l in 0..8 {
            yb[l] += alpha * xb[l];
        }
    }
    for (yi, &xi) in
        yc.into_remainder().iter_mut().zip(xc.remainder().iter())
    {
        *yi += alpha * xi;
    }
}

/// `x ← alpha * x`.
///
/// 8-lane chunked like [`axpy`]/[`dot_f32`] (it was the last hot kernel
/// still a plain scalar loop). Each element's update is independent —
/// one multiply, no accumulation — so chunking cannot change float
/// association and results are bitwise identical to the scalar loop
/// (test-asserted below).
#[inline]
pub fn scal(alpha: f32, x: &mut [f32]) {
    let mut xc = x.chunks_exact_mut(8);
    for xb in &mut xc {
        for l in 0..8 {
            xb[l] *= alpha;
        }
    }
    for xi in xc.into_remainder().iter_mut() {
        *xi *= alpha;
    }
}

/// Dot product with f64 accumulation (keeps the Pflug statistic stable for
/// long flat vectors).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    // chunks_exact gives the optimizer fixed-size slices (no bounds
    // checks), like dot_f32; the 4-term sum keeps dot's historical
    // float association, so results are bitwise unchanged.
    let xc = x.chunks_exact(4);
    let yc = y.chunks_exact(4);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    for (a, b) in xc.zip(yc) {
        acc += a[0] as f64 * b[0] as f64
            + a[1] as f64 * b[1] as f64
            + a[2] as f64 * b[2] as f64
            + a[3] as f64 * b[3] as f64;
    }
    for (a, b) in xr.iter().zip(yr) {
        acc += *a as f64 * *b as f64;
    }
    acc
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// f32 dot with 8-lane partial sums — the gemv inner loop. f32
/// accumulation matches the XLA kernel's numerics and lets LLVM emit
/// packed FMA; the f64 [`dot`] stays for the measurement/statistic paths.
/// (§Perf: switching gemv from f64-accumulating `dot` to this took the
/// 40×100 partial gradient from 3.3 µs to ~0.6 µs.)
#[inline]
pub fn dot_f32(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    // chunks_exact gives the optimizer fixed-size slices (no bounds
    // checks); 8 independent lanes vectorize to packed FMA with
    // target-cpu=native.
    let mut acc = [0.0f32; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    for (a, b) in xc.zip(yc) {
        for l in 0..8 {
            acc[l] += a[l] * b[l];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5])
        + (acc[2] + acc[6])
        + (acc[3] + acc[7]);
    for (a, b) in xr.iter().zip(yr) {
        s += a * b;
    }
    s
}

/// Column width of one [`gemv_blocked`] panel (a multiple of 8, so
/// panel edges never split an 8-lane chunk): 1024 f32 = 4 KiB of `x`
/// resident in L1 while a row tile streams past it.
pub const GEMV_PANEL: usize = 1024;

/// Rows per [`gemv_blocked`] tile: 64 × 8 lanes = 2 KiB of stack
/// accumulator, so each `x` panel is reloaded once per 64 rows instead
/// of once per row — and the round stays allocation-free.
const GEMV_ROW_TILE: usize = 64;

/// `y ← alpha * A x + beta * y` (A row-major).
///
/// Dispatches on shape: up to [`GEMV_PANEL`] columns, `x` already fits
/// in L1 and the plain [`gemv_rowwalk`] wins; wider inputs go through
/// [`gemv_blocked`] so `x` stops streaming through cache once per row.
/// Both paths accumulate every row with [`dot_f32`]'s exact 8-lane
/// association, so the dispatch is bitwise invisible (test-asserted
/// below).
///
/// `beta == 0.0` **overwrites** `y` (BLAS semantics) rather than
/// scaling it: `0.0 * NaN = NaN`, so the scale form would leak stale
/// NaN/∞ from an uninitialized or poisoned `y` into results — exactly
/// what breaks reusing dirty scratch buffers.
pub fn gemv(alpha: f32, a: &Matrix, x: &[f32], beta: f32, y: &mut [f32]) {
    if a.cols() > GEMV_PANEL {
        gemv_blocked(alpha, a, x, beta, y);
    } else {
        gemv_rowwalk(alpha, a, x, beta, y);
    }
}

/// The historical [`gemv`] loop: one [`dot_f32`] per row. Public so
/// `perf_hotpath` can race it against [`gemv_blocked`].
///
/// §Perf note: a 4-row-blocked variant (sharing `x` loads across four
/// accumulator lanes) was tried and measured ~35% *slower* at the fig-2
/// shard shape — the 4×8 accumulator tile spills; [`gemv_blocked`]
/// therefore keeps a single row's 8 lanes in the inner loop and shares
/// `x` across rows at the panel level instead.
pub fn gemv_rowwalk(
    alpha: f32,
    a: &Matrix,
    x: &[f32],
    beta: f32,
    y: &mut [f32],
) {
    assert_eq!(a.cols(), x.len(), "gemv: A.cols != x.len");
    assert_eq!(a.rows(), y.len(), "gemv: A.rows != y.len");
    if beta == 0.0 {
        for i in 0..a.rows() {
            y[i] = alpha * dot_f32(a.row(i), x);
        }
    } else {
        for i in 0..a.rows() {
            y[i] = alpha * dot_f32(a.row(i), x) + beta * y[i];
        }
    }
}

/// Cache-blocked [`gemv`]: walk `x` in [`GEMV_PANEL`]-column panels and
/// run a [`GEMV_ROW_TILE`]-row tile of 8-lane accumulators over each
/// panel, so the `x` panel stays L1-resident across the tile instead of
/// all of `x` streaming through cache once per row. Because the panel
/// width is a multiple of 8, every element hits the same lane in the
/// same order as [`dot_f32`] over the full row, and the tree reduction
/// plus serial tail are copied from it verbatim — results are bitwise
/// equal to [`gemv_rowwalk`].
pub fn gemv_blocked(
    alpha: f32,
    a: &Matrix,
    x: &[f32],
    beta: f32,
    y: &mut [f32],
) {
    assert_eq!(a.cols(), x.len(), "gemv: A.cols != x.len");
    assert_eq!(a.rows(), y.len(), "gemv: A.rows != y.len");
    let d = a.cols();
    let main = d - d % 8;
    let m = a.rows();
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + GEMV_ROW_TILE).min(m);
        let mut acc = [[0.0f32; 8]; GEMV_ROW_TILE];
        for p0 in (0..main).step_by(GEMV_PANEL) {
            let p1 = (p0 + GEMV_PANEL).min(main);
            let xp = &x[p0..p1];
            for i in i0..i1 {
                let lanes = &mut acc[i - i0];
                let ac = a.row(i)[p0..p1].chunks_exact(8);
                let xc = xp.chunks_exact(8);
                for (ab, xb) in ac.zip(xc) {
                    for l in 0..8 {
                        lanes[l] += ab[l] * xb[l];
                    }
                }
            }
        }
        for i in i0..i1 {
            let lanes = &acc[i - i0];
            let mut s = (lanes[0] + lanes[4]) + (lanes[1] + lanes[5])
                + (lanes[2] + lanes[6])
                + (lanes[3] + lanes[7]);
            for (av, xv) in a.row(i)[main..].iter().zip(&x[main..]) {
                s += av * xv;
            }
            y[i] = if beta == 0.0 {
                alpha * s
            } else {
                alpha * s + beta * y[i]
            };
        }
        i0 = i1;
    }
}

/// Column width of one [`gemv_t_blocked`] panel: 1024 f32 = 4 KiB of
/// resident accumulator, small enough to stay in L1 alongside the
/// streaming row segments.
pub const GEMV_T_PANEL: usize = 1024;

/// `y ← alpha * Aᵀ x + beta * y` without materializing Aᵀ.
///
/// Dispatches on shape: up to [`GEMV_T_PANEL`] columns the accumulator
/// already fits in cache and the plain [`gemv_t_rowwalk`] wins; wider
/// outputs go through [`gemv_t_blocked`] so `y` stops streaming through
/// cache once per row. Both paths accumulate each element in the same
/// ascending-row order, so the dispatch is bitwise invisible
/// (test-asserted below). `beta == 0.0` overwrites `y` — see [`gemv`].
pub fn gemv_t(alpha: f32, a: &Matrix, x: &[f32], beta: f32, y: &mut [f32]) {
    if y.len() > GEMV_T_PANEL {
        gemv_t_blocked(alpha, a, x, beta, y);
    } else {
        gemv_t_rowwalk(alpha, a, x, beta, y);
    }
}

/// The historical [`gemv_t`] loop: accumulate row-by-row
/// (`y += alpha * x[i] * A[i, :]`), keeping the row-major walk. Public
/// so `perf_hotpath` can race it against [`gemv_t_blocked`].
pub fn gemv_t_rowwalk(
    alpha: f32,
    a: &Matrix,
    x: &[f32],
    beta: f32,
    y: &mut [f32],
) {
    assert_eq!(a.rows(), x.len(), "gemv_t: A.rows != x.len");
    assert_eq!(a.cols(), y.len(), "gemv_t: A.cols != y.len");
    gemv_t_cols(alpha, a, x, beta, y, 0);
}

/// Cache-blocked [`gemv_t`]: walk `y` in [`GEMV_T_PANEL`]-column panels
/// and run the full row accumulation per panel, so the accumulator
/// stays resident instead of streaming all of `y` through cache once
/// per row. Per element the accumulation order is identical to the row
/// walk — rows ascending — so results are bitwise equal.
pub fn gemv_t_blocked(
    alpha: f32,
    a: &Matrix,
    x: &[f32],
    beta: f32,
    y: &mut [f32],
) {
    assert_eq!(a.rows(), x.len(), "gemv_t: A.rows != x.len");
    assert_eq!(a.cols(), y.len(), "gemv_t: A.cols != y.len");
    for (p, panel) in y.chunks_mut(GEMV_T_PANEL).enumerate() {
        gemv_t_cols(alpha, a, x, beta, panel, p * GEMV_T_PANEL);
    }
}

/// [`gemv_t`] restricted to the column range
/// `[col0, col0 + y_cols.len())`: `y_cols ← alpha * Aᵀ x + beta *
/// y_cols` over those columns of `A` only. The panel primitive behind
/// [`gemv_t_blocked`] and the engine's column-parallel back-projection
/// (each intra-round worker owns a disjoint panel). The per-row
/// `coeff != 0.0` skip matches the row walk exactly — it is observable
/// through Inf/NaN propagation (`0.0 * inf = NaN`), so both paths must
/// share it.
pub fn gemv_t_cols(
    alpha: f32,
    a: &Matrix,
    x: &[f32],
    beta: f32,
    y_cols: &mut [f32],
    col0: usize,
) {
    assert_eq!(a.rows(), x.len(), "gemv_t: A.rows != x.len");
    let hi = col0 + y_cols.len();
    assert!(hi <= a.cols(), "gemv_t_cols: panel exceeds A.cols");
    if beta == 0.0 {
        y_cols.fill(0.0);
    } else if beta != 1.0 {
        scal(beta, y_cols);
    }
    for i in 0..a.rows() {
        let coeff = alpha * x[i];
        if coeff != 0.0 {
            axpy(coeff, &a.row(i)[col0..hi], y_cols);
        }
    }
}

/// `C ← alpha * A B + beta * C`, blocked for cache reuse.
/// `beta == 0.0` overwrites `C` — see [`gemv`].
pub fn gemm(alpha: f32, a: &Matrix, b: &Matrix, beta: f32, c: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "gemm: inner dims");
    assert_eq!(c.rows(), a.rows(), "gemm: C rows");
    assert_eq!(c.cols(), b.cols(), "gemm: C cols");
    const BLK: usize = 64;
    if beta == 0.0 {
        c.as_mut_slice().fill(0.0);
    } else if beta != 1.0 {
        scal(beta, c.as_mut_slice());
    }
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    for i0 in (0..m).step_by(BLK) {
        let i1 = (i0 + BLK).min(m);
        for k0 in (0..k).step_by(BLK) {
            let k1 = (k0 + BLK).min(k);
            for j0 in (0..n).step_by(BLK) {
                let j1 = (j0 + BLK).min(n);
                // i-k-j order: B rows stream, C rows accumulate in cache.
                for i in i0..i1 {
                    for kk in k0..k1 {
                        let aik = alpha * a[(i, kk)];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b.row(kk)[j0..j1];
                        let crow = &mut c.row_mut(i)[j0..j1];
                        axpy(aik, brow, crow);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn rand_matrix(rng: &mut Pcg64, r: usize, c: usize) -> Matrix {
        let data: Vec<f32> =
            (0..r * c).map(|_| rng.next_f64() as f32 - 0.5).collect();
        Matrix::from_vec(r, c, data)
    }

    fn gemm_naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0f64;
                for k in 0..a.cols() {
                    acc += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn dot_f32_matches_f64_dot() {
        let mut rng = Pcg64::seed(8);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 100, 1000] {
            let x: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32 - 0.5).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32 - 0.5).collect();
            let a = dot_f32(&x, &y) as f64;
            let b = dot(&x, &y);
            assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "n={n}: {a} vs {b}");
        }
    }

    #[test]
    fn dot_matches_naive() {
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let y = [5.0f32, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&x, &y), 35.0);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0];
        let mut y = [10.0f32, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
    }

    #[test]
    fn gemv_matches_naive() {
        let mut rng = Pcg64::seed(1);
        let a = rand_matrix(&mut rng, 13, 7);
        let x: Vec<f32> = (0..7).map(|_| rng.next_f64() as f32).collect();
        let mut y = vec![0.0f32; 13];
        gemv(1.0, &a, &x, 0.0, &mut y);
        for i in 0..13 {
            let want: f64 =
                (0..7).map(|j| a[(i, j)] as f64 * x[j] as f64).sum();
            assert!((y[i] as f64 - want).abs() < 1e-5);
        }
    }

    #[test]
    fn gemv_t_matches_transpose_gemv() {
        let mut rng = Pcg64::seed(2);
        let a = rand_matrix(&mut rng, 9, 5);
        let x: Vec<f32> = (0..9).map(|_| rng.next_f64() as f32).collect();
        let mut y1 = vec![0.0f32; 5];
        gemv_t(1.0, &a, &x, 0.0, &mut y1);
        let at = a.transpose();
        let mut y2 = vec![0.0f32; 5];
        gemv(1.0, &at, &x, 0.0, &mut y2);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn gemv_beta_accumulates() {
        let a = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        let x = [3.0f32, 4.0];
        let mut y = [1.0f32, 1.0];
        gemv(2.0, &a, &x, 0.5, &mut y);
        assert_eq!(y, [6.5, 8.5]);
    }

    #[test]
    fn gemm_matches_naive_various_shapes() {
        let mut rng = Pcg64::seed(3);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (64, 64, 64), (65, 130, 67)] {
            let a = rand_matrix(&mut rng, m, k);
            let b = rand_matrix(&mut rng, k, n);
            let mut c = Matrix::zeros(m, n);
            gemm(1.0, &a, &b, 0.0, &mut c);
            let want = gemm_naive(&a, &b);
            for i in 0..m {
                for j in 0..n {
                    assert!(
                        (c[(i, j)] - want[(i, j)]).abs() < 1e-3,
                        "({m},{k},{n}) at ({i},{j}): {} vs {}",
                        c[(i, j)],
                        want[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_identity() {
        let mut rng = Pcg64::seed(4);
        let a = rand_matrix(&mut rng, 8, 8);
        let mut c = Matrix::zeros(8, 8);
        gemm(1.0, &a, &Matrix::eye(8), 0.0, &mut c);
        assert_eq!(c, a);
    }

    #[test]
    fn nrm2_pythagoras() {
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
    }

    /// beta == 0 must *overwrite* y: a stale NaN (or ∞) in the output
    /// buffer must not survive, since `0.0 * NaN = NaN` would leak it.
    #[test]
    fn gemv_beta_zero_overwrites_stale_nan() {
        let mut rng = Pcg64::seed(11);
        let a = rand_matrix(&mut rng, 6, 4);
        let x: Vec<f32> = (0..4).map(|_| rng.next_f64() as f32).collect();
        let mut clean = vec![0.0f32; 6];
        gemv(1.5, &a, &x, 0.0, &mut clean);
        let mut dirty = vec![f32::NAN; 6];
        dirty[2] = f32::INFINITY;
        gemv(1.5, &a, &x, 0.0, &mut dirty);
        assert_eq!(bits(&dirty), bits(&clean));
        assert!(dirty.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gemv_t_beta_zero_overwrites_stale_nan() {
        let mut rng = Pcg64::seed(12);
        let a = rand_matrix(&mut rng, 5, 9);
        let x: Vec<f32> = (0..5).map(|_| rng.next_f64() as f32).collect();
        let mut clean = vec![0.0f32; 9];
        gemv_t(0.5, &a, &x, 0.0, &mut clean);
        let mut dirty = vec![f32::NEG_INFINITY; 9];
        dirty[0] = f32::NAN;
        gemv_t(0.5, &a, &x, 0.0, &mut dirty);
        assert_eq!(bits(&dirty), bits(&clean));
        assert!(dirty.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gemm_beta_zero_overwrites_stale_nan() {
        let mut rng = Pcg64::seed(13);
        let a = rand_matrix(&mut rng, 4, 3);
        let b = rand_matrix(&mut rng, 3, 5);
        let mut clean = Matrix::zeros(4, 5);
        gemm(1.0, &a, &b, 0.0, &mut clean);
        let mut dirty =
            Matrix::from_vec(4, 5, vec![f32::NAN; 20]);
        gemm(1.0, &a, &b, 0.0, &mut dirty);
        assert_eq!(
            bits(dirty.as_slice()),
            bits(clean.as_slice())
        );
        assert!(dirty.as_slice().iter().all(|v| v.is_finite()));
    }

    /// The 8-lane chunked `scal` must be bitwise identical to the plain
    /// scalar loop: each element is an independent `x *= alpha`, so
    /// lane layout cannot change any result.
    #[test]
    fn scal_chunked_is_bitwise_equal_to_scalar_loop() {
        for n in [0usize, 1, 7, 8, 9, 16, 17, 100, 1001] {
            let base: Vec<f32> = (0..n)
                .map(|i| {
                    let sign = if i % 3 == 0 { -1.0f32 } else { 1.0 };
                    sign * (1.0e7 + i as f32) * 1.000_001f32.powi(i as i32)
                })
                .collect();
            for alpha in [0.0f32, 1.0, -2.5, 0.3333333, f32::MIN_POSITIVE] {
                let mut fast = base.clone();
                scal(alpha, &mut fast);
                let mut slow = base.clone();
                for v in slow.iter_mut() {
                    *v *= alpha;
                }
                assert_eq!(bits(&fast), bits(&slow), "n={n} alpha={alpha}");
            }
        }
    }

    /// Column-panel blocking must be bitwise invisible: the blocked and
    /// row-walk paths accumulate each element in the same ascending-row
    /// order, including across the dispatch threshold and with
    /// catastrophic-cancellation values.
    #[test]
    fn gemv_t_blocked_is_bitwise_equal_to_rowwalk() {
        let mut rng = Pcg64::seed(14);
        for d in [
            1usize,
            GEMV_T_PANEL - 1,
            GEMV_T_PANEL,
            GEMV_T_PANEL + 1,
            2 * GEMV_T_PANEL + 37,
        ] {
            let rows = 11usize;
            let data: Vec<f32> = (0..rows * d)
                .map(|i| {
                    let sign = if i % 2 == 0 { 1.0f32 } else { -1.0 };
                    sign * (1.0e8 + (i % 97) as f32)
                        + (rng.next_f64() as f32 - 0.5)
                })
                .collect();
            let a = Matrix::from_vec(rows, d, data);
            let x: Vec<f32> = (0..rows)
                .map(|i| {
                    if i == 3 {
                        0.0 // exercise the coeff == 0 row skip
                    } else {
                        rng.next_f64() as f32 - 0.5
                    }
                })
                .collect();
            for beta in [0.0f32, 1.0, -0.75] {
                let y0: Vec<f32> =
                    (0..d).map(|i| 2.0e7 - i as f32 * 0.25).collect();
                let mut y_walk = y0.clone();
                gemv_t_rowwalk(1.0, &a, &x, beta, &mut y_walk);
                let mut y_blk = y0.clone();
                gemv_t_blocked(1.0, &a, &x, beta, &mut y_blk);
                assert_eq!(bits(&y_blk), bits(&y_walk), "d={d} beta={beta}");
                let mut y_dispatch = y0;
                gemv_t(1.0, &a, &x, beta, &mut y_dispatch);
                assert_eq!(
                    bits(&y_dispatch),
                    bits(&y_walk),
                    "d={d} beta={beta}"
                );
            }
        }
    }

    /// Column-panel blocking of `gemv` must be bitwise invisible: the
    /// blocked path carries each row's 8 lane accumulators across
    /// panels (panel width is a multiple of 8), so every element lands
    /// on the same lane in the same order as the full-row [`dot_f32`],
    /// including across the dispatch threshold, row-tile edges, and
    /// with catastrophic-cancellation values.
    #[test]
    fn gemv_blocked_is_bitwise_equal_to_rowwalk() {
        let mut rng = Pcg64::seed(15);
        for d in [
            1usize,
            7,
            GEMV_PANEL - 1,
            GEMV_PANEL,
            GEMV_PANEL + 1,
            2 * GEMV_PANEL + 37,
        ] {
            // Rows straddle one GEMV_ROW_TILE boundary.
            let rows = 67usize;
            let data: Vec<f32> = (0..rows * d)
                .map(|i| {
                    let sign = if i % 2 == 0 { 1.0f32 } else { -1.0 };
                    sign * (1.0e8 + (i % 97) as f32)
                        + (rng.next_f64() as f32 - 0.5)
                })
                .collect();
            let a = Matrix::from_vec(rows, d, data);
            let x: Vec<f32> =
                (0..d).map(|_| rng.next_f64() as f32 - 0.5).collect();
            for beta in [0.0f32, 1.0, -0.75] {
                let y0: Vec<f32> =
                    (0..rows).map(|i| 2.0e7 - i as f32 * 0.25).collect();
                let mut y_walk = y0.clone();
                gemv_rowwalk(1.5, &a, &x, beta, &mut y_walk);
                let mut y_blk = y0.clone();
                gemv_blocked(1.5, &a, &x, beta, &mut y_blk);
                assert_eq!(bits(&y_blk), bits(&y_walk), "d={d} beta={beta}");
                let mut y_dispatch = y0;
                gemv(1.5, &a, &x, beta, &mut y_dispatch);
                assert_eq!(
                    bits(&y_dispatch),
                    bits(&y_walk),
                    "d={d} beta={beta}"
                );
            }
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
