//! BLAS-like kernels over [`Matrix`] and slices.
//!
//! The per-iteration native hot path of the coordinator is
//! `gemv` (residual `X w`) + `gemv_t` (back-projection `Xᵀ r`); both are
//! single-pass row walks so the shard matrix streams through cache once,
//! mirroring the fused Pallas kernel's single HBM pass.

use super::Matrix;

/// `y ← alpha * x + y`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut yc = y.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for l in 0..8 {
            yb[l] += alpha * xb[l];
        }
    }
    for (yi, &xi) in
        yc.into_remainder().iter_mut().zip(xc.remainder().iter())
    {
        *yi += alpha * xi;
    }
}

/// `x ← alpha * x`.
#[inline]
pub fn scal(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Dot product with f64 accumulation (keeps the Pflug statistic stable for
/// long flat vectors).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    // chunks_exact gives the optimizer fixed-size slices (no bounds
    // checks), like dot_f32; the 4-term sum keeps dot's historical
    // float association, so results are bitwise unchanged.
    let xc = x.chunks_exact(4);
    let yc = y.chunks_exact(4);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    for (a, b) in xc.zip(yc) {
        acc += a[0] as f64 * b[0] as f64
            + a[1] as f64 * b[1] as f64
            + a[2] as f64 * b[2] as f64
            + a[3] as f64 * b[3] as f64;
    }
    for (a, b) in xr.iter().zip(yr) {
        acc += *a as f64 * *b as f64;
    }
    acc
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// f32 dot with 8-lane partial sums — the gemv inner loop. f32
/// accumulation matches the XLA kernel's numerics and lets LLVM emit
/// packed FMA; the f64 [`dot`] stays for the measurement/statistic paths.
/// (§Perf: switching gemv from f64-accumulating `dot` to this took the
/// 40×100 partial gradient from 3.3 µs to ~0.6 µs.)
#[inline]
pub fn dot_f32(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    // chunks_exact gives the optimizer fixed-size slices (no bounds
    // checks); 8 independent lanes vectorize to packed FMA with
    // target-cpu=native.
    let mut acc = [0.0f32; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    for (a, b) in xc.zip(yc) {
        for l in 0..8 {
            acc[l] += a[l] * b[l];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5])
        + (acc[2] + acc[6])
        + (acc[3] + acc[7]);
    for (a, b) in xr.iter().zip(yr) {
        s += a * b;
    }
    s
}

/// `y ← alpha * A x + beta * y` (A row-major, row walk).
///
/// §Perf note: a 4-row-blocked variant (sharing `x` loads across four
/// accumulator lanes) was tried and measured ~35% *slower* at the fig-2
/// shard shape — the 4×8 accumulator tile spills; reverted to the simple
/// row walk over [`dot_f32`].
pub fn gemv(alpha: f32, a: &Matrix, x: &[f32], beta: f32, y: &mut [f32]) {
    assert_eq!(a.cols(), x.len(), "gemv: A.cols != x.len");
    assert_eq!(a.rows(), y.len(), "gemv: A.rows != y.len");
    for i in 0..a.rows() {
        y[i] = alpha * dot_f32(a.row(i), x) + beta * y[i];
    }
}

/// `y ← alpha * Aᵀ x + beta * y` without materializing Aᵀ: accumulate
/// row-by-row (`y += alpha * x[i] * A[i, :]`), keeping the row-major walk.
pub fn gemv_t(alpha: f32, a: &Matrix, x: &[f32], beta: f32, y: &mut [f32]) {
    assert_eq!(a.rows(), x.len(), "gemv_t: A.rows != x.len");
    assert_eq!(a.cols(), y.len(), "gemv_t: A.cols != y.len");
    if beta != 1.0 {
        scal(beta, y);
    }
    for i in 0..a.rows() {
        let coeff = alpha * x[i];
        if coeff != 0.0 {
            axpy(coeff, a.row(i), y);
        }
    }
}

/// `C ← alpha * A B + beta * C`, blocked for cache reuse.
pub fn gemm(alpha: f32, a: &Matrix, b: &Matrix, beta: f32, c: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "gemm: inner dims");
    assert_eq!(c.rows(), a.rows(), "gemm: C rows");
    assert_eq!(c.cols(), b.cols(), "gemm: C cols");
    const BLK: usize = 64;
    if beta != 1.0 {
        scal(beta, c.as_mut_slice());
    }
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    for i0 in (0..m).step_by(BLK) {
        let i1 = (i0 + BLK).min(m);
        for k0 in (0..k).step_by(BLK) {
            let k1 = (k0 + BLK).min(k);
            for j0 in (0..n).step_by(BLK) {
                let j1 = (j0 + BLK).min(n);
                // i-k-j order: B rows stream, C rows accumulate in cache.
                for i in i0..i1 {
                    for kk in k0..k1 {
                        let aik = alpha * a[(i, kk)];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b.row(kk)[j0..j1];
                        let crow = &mut c.row_mut(i)[j0..j1];
                        axpy(aik, brow, crow);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn rand_matrix(rng: &mut Pcg64, r: usize, c: usize) -> Matrix {
        let data: Vec<f32> =
            (0..r * c).map(|_| rng.next_f64() as f32 - 0.5).collect();
        Matrix::from_vec(r, c, data)
    }

    fn gemm_naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0f64;
                for k in 0..a.cols() {
                    acc += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn dot_f32_matches_f64_dot() {
        let mut rng = Pcg64::seed(8);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 100, 1000] {
            let x: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32 - 0.5).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32 - 0.5).collect();
            let a = dot_f32(&x, &y) as f64;
            let b = dot(&x, &y);
            assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "n={n}: {a} vs {b}");
        }
    }

    #[test]
    fn dot_matches_naive() {
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let y = [5.0f32, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&x, &y), 35.0);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0];
        let mut y = [10.0f32, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
    }

    #[test]
    fn gemv_matches_naive() {
        let mut rng = Pcg64::seed(1);
        let a = rand_matrix(&mut rng, 13, 7);
        let x: Vec<f32> = (0..7).map(|_| rng.next_f64() as f32).collect();
        let mut y = vec![0.0f32; 13];
        gemv(1.0, &a, &x, 0.0, &mut y);
        for i in 0..13 {
            let want: f64 =
                (0..7).map(|j| a[(i, j)] as f64 * x[j] as f64).sum();
            assert!((y[i] as f64 - want).abs() < 1e-5);
        }
    }

    #[test]
    fn gemv_t_matches_transpose_gemv() {
        let mut rng = Pcg64::seed(2);
        let a = rand_matrix(&mut rng, 9, 5);
        let x: Vec<f32> = (0..9).map(|_| rng.next_f64() as f32).collect();
        let mut y1 = vec![0.0f32; 5];
        gemv_t(1.0, &a, &x, 0.0, &mut y1);
        let at = a.transpose();
        let mut y2 = vec![0.0f32; 5];
        gemv(1.0, &at, &x, 0.0, &mut y2);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn gemv_beta_accumulates() {
        let a = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        let x = [3.0f32, 4.0];
        let mut y = [1.0f32, 1.0];
        gemv(2.0, &a, &x, 0.5, &mut y);
        assert_eq!(y, [6.5, 8.5]);
    }

    #[test]
    fn gemm_matches_naive_various_shapes() {
        let mut rng = Pcg64::seed(3);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (64, 64, 64), (65, 130, 67)] {
            let a = rand_matrix(&mut rng, m, k);
            let b = rand_matrix(&mut rng, k, n);
            let mut c = Matrix::zeros(m, n);
            gemm(1.0, &a, &b, 0.0, &mut c);
            let want = gemm_naive(&a, &b);
            for i in 0..m {
                for j in 0..n {
                    assert!(
                        (c[(i, j)] - want[(i, j)]).abs() < 1e-3,
                        "({m},{k},{n}) at ({i},{j}): {} vs {}",
                        c[(i, j)],
                        want[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_identity() {
        let mut rng = Pcg64::seed(4);
        let a = rand_matrix(&mut rng, 8, 8);
        let mut c = Matrix::zeros(8, 8);
        gemm(1.0, &a, &Matrix::eye(8), 0.0, &mut c);
        assert_eq!(c, a);
    }

    #[test]
    fn nrm2_pythagoras() {
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
    }
}
