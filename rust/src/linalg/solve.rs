//! Cholesky factorization + solver for SPD systems.
//!
//! Used once per experiment to compute the exact least-squares optimum
//! `w* = (XᵀX)⁻¹ Xᵀ y` and hence `F*`, so every figure reports the paper's
//! error metric `F(w_t) − F*`. f64 internally — `XᵀX` for the paper's data
//! (entries in 1..=10, m=2000) has entries up to ~2·10⁵ and needs the
//! headroom.

use super::Matrix;

/// Failure modes of the SPD solve.
#[derive(Debug, PartialEq)]
pub enum CholeskyError {
    /// The matrix is not positive definite (or badly conditioned).
    NotPositiveDefinite(usize),
    /// Shape mismatch between the matrix and right-hand side.
    DimensionMismatch(usize, usize),
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholeskyError::NotPositiveDefinite(pivot) => {
                write!(f, "matrix not positive definite at pivot {pivot}")
            }
            CholeskyError::DimensionMismatch(n, len) => {
                write!(f, "dimension mismatch: matrix is {n}x{n}, rhs has len {len}")
            }
        }
    }
}

impl std::error::Error for CholeskyError {}

/// Solve `A x = b` for SPD `A` given as a dense row-major f64 buffer.
/// End-to-end f64: assembling `XᵀX` and then narrowing to f32 before the
/// factorization costs ~10⁻⁵ relative accuracy in `w*` — enough loss that
/// converged SGD iterates would *beat* the computed `F*`.
pub fn cholesky_solve_dense_f64(
    a: &[f64],
    n: usize,
    b: &[f64],
) -> Result<Vec<f64>, CholeskyError> {
    assert_eq!(a.len(), n * n, "matrix buffer must be n*n");
    if b.len() != n {
        return Err(CholeskyError::DimensionMismatch(n, b.len()));
    }

    // Factor in f64.
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(CholeskyError::NotPositiveDefinite(i));
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }

    // Forward substitution: L z = b.
    let mut z = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * n + k] * z[k];
        }
        z[i] = sum / l[i * n + i];
    }

    // Back substitution: Lᵀ x = z.
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = z[i];
        for k in i + 1..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }

    Ok(x)
}

/// [`cholesky_solve_dense_f64`] over an f32 [`Matrix`] and rhs (widened on
/// entry), returning f64.
pub fn cholesky_solve_f64(
    a: &Matrix,
    b: &[f32],
) -> Result<Vec<f64>, CholeskyError> {
    let n = a.rows();
    assert_eq!(a.cols(), n, "cholesky_solve requires a square matrix");
    let a64: Vec<f64> = a.as_slice().iter().map(|&v| v as f64).collect();
    let b64: Vec<f64> = b.iter().map(|&v| v as f64).collect();
    cholesky_solve_dense_f64(&a64, n, &b64)
}

/// [`cholesky_solve_f64`] narrowed to f32 (convenience for f32 pipelines).
pub fn cholesky_solve(a: &Matrix, b: &[f32]) -> Result<Vec<f32>, CholeskyError> {
    Ok(cholesky_solve_f64(a, b)?.into_iter().map(|v| v as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;
    use crate::rng::{Pcg64, Rng};

    #[test]
    fn solves_diagonal() {
        let a = Matrix::from_rows(&[&[4.0, 0.0], &[0.0, 9.0]]);
        let x = cholesky_solve(&a, &[8.0, 27.0]).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-6);
        assert!((x[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn solves_random_spd() {
        let mut rng = Pcg64::seed(10);
        let n = 20;
        // A = B Bᵀ + n*I is SPD.
        let b = Matrix::from_vec(
            n,
            n,
            (0..n * n).map(|_| rng.next_f64() as f32 - 0.5).collect(),
        );
        let mut a = Matrix::zeros(n, n);
        gemm(1.0, &b, &b.transpose(), 0.0, &mut a);
        for i in 0..n {
            a[(i, i)] += n as f32;
        }
        let x_true: Vec<f32> = (0..n).map(|i| i as f32 / 7.0 - 1.0).collect();
        let mut rhs = vec![0.0f32; n];
        crate::linalg::gemv(1.0, &a, &x_true, 0.0, &mut rhs);
        let x = cholesky_solve(&a, &rhs).unwrap();
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-3, "{got} vs {want}");
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eig −1, 3
        assert_eq!(
            cholesky_solve(&a, &[1.0, 1.0]),
            Err(CholeskyError::NotPositiveDefinite(1))
        );
    }

    #[test]
    fn rejects_dim_mismatch() {
        let a = Matrix::eye(3);
        assert_eq!(
            cholesky_solve(&a, &[1.0]),
            Err(CholeskyError::DimensionMismatch(3, 1))
        );
    }
}
