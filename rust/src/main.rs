//! `adasgd` — leader entrypoint / CLI.
//!
//! See `adasgd help` (or [`adasgd::cli::print_help`]) for the command map.

use adasgd::cli::{print_help, Args};
use adasgd::comm::IngressDiscipline;
use adasgd::config::{
    CodingSchemeSpec, CodingSpec, CompressorSpec, DelaySpec,
    ExperimentConfig, PolicySpec, WorkloadSpec,
};
use adasgd::coordinator::{
    fig1_jobs, fig2_jobs, fig3_jobs, replay_experiment, run_experiment,
    FigureOutput,
};
use adasgd::metrics::{
    write_csv_with_scalars, AsciiPlot, Recorder, RunScalars, Sample,
};
use adasgd::trace::{Event, Trace, TraceAnalysis};
use adasgd::policy::{FixedK, PflugParams};
use adasgd::theory::{switching_times, BoundParams, ErrorBound};
use std::path::Path;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_deref() {
        Some("fig1") => cmd_fig1(&args),
        Some("fig2") => cmd_figure(&args, 2),
        Some("fig3") => cmd_figure(&args, 3),
        Some("train") => cmd_train(&args),
        Some("train-transformer") => cmd_train_transformer(&args),
        Some("threaded") => cmd_threaded(&args),
        Some("list-artifacts") => cmd_list_artifacts(&args),
        Some("repeat") => cmd_repeat(&args),
        Some("trace") => cmd_trace(&args),
        Some("lint") => cmd_lint(&args),
        Some("switching-times") => cmd_switching_times(),
        Some("help") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}' (try `adasgd help`)");
            2
        }
    };
    std::process::exit(code);
}

fn emit(
    args: &Args,
    name: &str,
    runs: &[(&Recorder, RunScalars)],
    summary: &[String],
    meta: &[String],
) {
    let refs: Vec<&Recorder> = runs.iter().map(|(r, _)| *r).collect();
    if !args.has("quiet") {
        let plot = AsciiPlot::new(
            format!("{name}: error vs wall-clock (log y)"),
            96,
            24,
        );
        println!("{}", plot.render(&refs));
    }
    for line in summary {
        println!("  {line}");
    }
    let default_out = format!("results/{name}.csv");
    let out = args.get("out").unwrap_or(&default_out);
    if let Err(e) = write_csv_with_scalars(Path::new(out), runs, meta) {
        eprintln!("warning: could not write {out}: {e}");
    } else {
        println!("  series written to {out}");
    }
}

/// The sweep worker count: `--jobs N`, default 0 = all cores (pure
/// wall-clock — results are byte-identical for every value).
fn jobs_flag(args: &Args) -> usize {
    args.get_parse::<usize>("jobs", 0).unwrap_or(0)
}

fn cmd_fig1(args: &Args) -> i32 {
    let points = args.get_parse::<usize>("points", 400).unwrap_or(400);
    if points < 2 {
        eprintln!("config error: --points {points} must be >= 2");
        return 2;
    }
    let out = fig1_jobs(points, jobs_flag(args));
    let mut runs: Vec<(&Recorder, RunScalars)> =
        out.fixed.iter().map(|r| (r, RunScalars::default())).collect();
    runs.push((&out.adaptive, RunScalars::default()));
    emit(args, "fig1", &runs, &out.summary, &[]);
    0
}

fn cmd_figure(args: &Args, which: u8) -> i32 {
    let seed = args.get_parse::<u64>("seed", 0).unwrap_or(0);
    let default_t = if which == 2 { 6500.0 } else { 2500.0 };
    let max_time =
        args.get_parse::<f64>("max-time", default_t).unwrap_or(default_t);
    let FigureOutput { name, runs, summary } = if which == 2 {
        fig2_jobs(seed, max_time, jobs_flag(args))
    } else {
        fig3_jobs(seed, max_time, jobs_flag(args))
    };
    let refs: Vec<(&Recorder, RunScalars)> =
        runs.iter().map(|r| (r, RunScalars::default())).collect();
    emit(args, &name, &refs, &summary, &[]);
    0
}

/// Parse one compression-scheme flag triple (shared by the uplink
/// `--comm`/`--comm-levels`/`--comm-frac` and the downlink
/// `--downlink`/`--down-levels`/`--down-frac` families).
fn parse_scheme_flag(
    args: &Args,
    flag: &str,
    levels_flag: &str,
    frac_flag: &str,
) -> Result<CompressorSpec, String> {
    Ok(match args.get(flag).unwrap_or("dense") {
        "dense" => CompressorSpec::Dense,
        "qsgd" => CompressorSpec::Qsgd {
            levels: args.get_parse(levels_flag, 4u32).unwrap_or(4),
        },
        "topk" => CompressorSpec::TopK {
            frac: args.get_parse(frac_flag, 0.1f64).unwrap_or(0.1),
        },
        "randk" => CompressorSpec::RandK {
            frac: args.get_parse(frac_flag, 0.1f64).unwrap_or(0.1),
        },
        other => {
            return Err(format!("unknown --{flag} scheme '{other}'"))
        }
    })
}

fn cmd_train(args: &Args) -> i32 {
    let mut cfg = if let Some(path) = args.get("config") {
        match std::fs::read_to_string(path)
            .map_err(|e| e.to_string())
            .and_then(|t| ExperimentConfig::from_toml(&t))
        {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        }
    } else {
        // Assemble from flags.
        let mut cfg = ExperimentConfig::default();
        cfg.seed = args.get_parse("seed", cfg.seed).unwrap_or(cfg.seed);
        cfg.n = args.get_parse("n", cfg.n).unwrap_or(cfg.n);
        cfg.eta = args.get_parse("eta", cfg.eta).unwrap_or(cfg.eta);
        cfg.max_time =
            args.get_parse("max-time", cfg.max_time).unwrap_or(cfg.max_time);
        cfg.max_iterations = args
            .get_parse("max-iterations", cfg.max_iterations)
            .unwrap_or(cfg.max_iterations);
        let m = args.get_parse("m", 2000usize).unwrap_or(2000);
        let d = args.get_parse("d", 100usize).unwrap_or(100);
        cfg.workload = WorkloadSpec::LinReg { m, d };
        let lambda = args.get_parse("lambda", 1.0f64).unwrap_or(1.0);
        cfg.delays = DelaySpec::Exponential { lambda };
        cfg.comm.scheme =
            match parse_scheme_flag(args, "comm", "comm-levels", "comm-frac")
            {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("config error: {e}");
                    return 2;
                }
            };
        cfg.comm.downlink = match parse_scheme_flag(
            args,
            "downlink",
            "down-levels",
            "down-frac",
        ) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        };
        cfg.comm.error_feedback = !args.has("no-error-feedback");
        cfg.comm.bandwidth =
            args.get_parse("bandwidth", 0.0f64).unwrap_or(0.0);
        cfg.comm.latency =
            args.get_parse("link-latency", 0.0f64).unwrap_or(0.0);
        cfg.comm.slow_workers =
            args.get_parse("slow-workers", 0usize).unwrap_or(0);
        cfg.comm.slow_factor =
            args.get_parse("slow-factor", 1.0f64).unwrap_or(1.0);
        cfg.comm.down_bandwidth =
            args.get_parse("down-bandwidth", 0.0f64).unwrap_or(0.0);
        if let Some(list) = args.get("down-bandwidths") {
            match list
                .split(',')
                .map(|t| t.trim().parse::<f64>())
                .collect::<Result<Vec<f64>, _>>()
            {
                Ok(v) => cfg.comm.down_bandwidths = v,
                Err(_) => {
                    eprintln!(
                        "config error: --down-bandwidths expects \
                         comma-separated numbers, got '{list}'"
                    );
                    return 2;
                }
            }
        }
        cfg.comm.down_latency =
            args.get_parse("down-latency", 0.0f64).unwrap_or(0.0);
        cfg.comm.ingress_bw =
            args.get_parse("ingress-bw", 0.0f64).unwrap_or(0.0);
        cfg.comm.ingress = match args.get("ingress") {
            None | Some("fifo") => IngressDiscipline::Fifo,
            Some("ps") => IngressDiscipline::Ps,
            Some(other) => {
                eprintln!(
                    "config error: --ingress must be fifo or ps, got \
                     '{other}'"
                );
                return 2;
            }
        };
        if let Some(scheme) = args.get("coding") {
            let scheme = match scheme {
                "frc" => CodingSchemeSpec::Frc,
                "cyclic" => CodingSchemeSpec::Cyclic,
                "bernoulli" => CodingSchemeSpec::Bernoulli,
                other => {
                    eprintln!(
                        "config error: unknown --coding scheme '{other}' \
                         (frc | cyclic | bernoulli)"
                    );
                    return 2;
                }
            };
            // Strict parse: a malformed r must not silently run a
            // different code than the user asked for.
            let r = match args.get_parse("replication", 2usize) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("config error: {e}");
                    return 2;
                }
            };
            cfg.coding = Some(CodingSpec { scheme, r });
        }
        cfg.policy = if args.has("async") {
            PolicySpec::Async
        } else if let Some(kstr) = args.get("k") {
            PolicySpec::Fixed { k: kstr.parse().unwrap_or(10) }
        } else {
            PolicySpec::Adaptive(PflugParams {
                k0: args.get_parse("k0", 10).unwrap_or(10),
                step: args.get_parse("step", 10).unwrap_or(10),
                thresh: args.get_parse("thresh", 10).unwrap_or(10),
                burnin: args.get_parse("burnin", 200).unwrap_or(200),
                k_max: args.get_parse("k-max", cfg.n).unwrap_or(cfg.n),
            })
        };
        cfg.label = format!("train(seed={})", cfg.seed);
        cfg
    };
    // --trace overrides the config's `[trace] dir` (tracing is purely
    // observational; every other output is byte-identical either way).
    if let Some(dir) = args.get("trace") {
        cfg.trace = Some(dir.to_string());
    }
    // --fastpath opts into O(k) order-statistics rounds (also `[run]
    // fastpath`); validate() inside run_experiment rejects configs the
    // fast path cannot represent.
    if args.has("fastpath") {
        cfg.fastpath = true;
    }
    // --intra-jobs overrides the config's `[run] intra_jobs` (intra-round
    // fork–join width; pure wall-clock — the trajectory is byte-identical
    // for every value; 0 = all cores).
    cfg.intra_jobs = args
        .get_parse("intra-jobs", cfg.intra_jobs)
        .unwrap_or(cfg.intra_jobs);

    match run_experiment(&cfg) {
        Ok(out) => {
            let summary = vec![
                format!(
                    "{}: {} steps, t={:.1}, final error {:.4e}, min {:.4e}",
                    cfg.label,
                    out.steps,
                    out.total_time,
                    out.recorder.last().map(|s| s.error).unwrap_or(f64::NAN),
                    out.recorder.min_error().unwrap_or(f64::NAN),
                ),
                format!(
                    "k switches: {}",
                    out.k_changes
                        .iter()
                        .map(|(j, t, k)| format!("(iter {j}, t={t:.0}) → k={k}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                ),
                format!(
                    "comm: {} bytes up ({:.1} upload time), {} bytes down \
                     ({:.1} download time)",
                    out.bytes_sent, out.comm_time, out.bytes_down,
                    out.down_time
                ),
            ];
            // The CSV run-header records what produced the series; the
            // coding line is what downstream plots key scheme/r off.
            let meta: Vec<String> = cfg
                .coding
                .iter()
                .map(|c| format!("coding: scheme={} r={}", c.scheme, c.r))
                .collect();
            if let Some(dir) = &cfg.trace {
                println!(
                    "  event trace written to {}/{}.trace",
                    dir,
                    adasgd::trace::sanitize_label(&cfg.label)
                );
            }
            let scalars = RunScalars {
                late_responses: out.late_responses,
                mean_staleness: out.mean_staleness,
            };
            emit(args, "train", &[(&out.recorder, scalars)], &summary, &meta);
            0
        }
        Err(e) => {
            eprintln!("run error: {e}");
            1
        }
    }
}

#[cfg(feature = "pjrt")]
fn open_runtime(args: &Args) -> Option<std::sync::Arc<adasgd::runtime::Runtime>> {
    use adasgd::runtime::Runtime;
    let res = match args.get("artifacts") {
        Some(dir) => Runtime::open(dir),
        None => Runtime::open_default(),
    };
    match res {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("runtime error: {e}");
            None
        }
    }
}

/// Friendly failure for commands that need the PJRT runtime in a build
/// without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
fn pjrt_unavailable(cmd: &str) -> i32 {
    eprintln!(
        "runtime error: `{cmd}` needs the PJRT artifact runtime; rebuild \
         with `cargo build --features pjrt` (and real xla_extension \
         bindings in place of rust/vendor/xla)"
    );
    1
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_transformer(_args: &Args) -> i32 {
    pjrt_unavailable("train-transformer")
}

#[cfg(feature = "pjrt")]
fn cmd_train_transformer(args: &Args) -> i32 {
    use adasgd::master::{run_fastest_k, MasterConfig};
    use adasgd::policy::AdaptivePflug;
    use adasgd::transformer::TransformerBackend;
    let Some(runtime) = open_runtime(args) else { return 1 };
    let tag = args.get("tag").unwrap_or("tiny").to_string();
    let steps = args.get_parse::<u64>("steps", 200).unwrap_or(200);
    let workers = args.get_parse::<usize>("workers", 8).unwrap_or(8);
    let seed = args.get_parse::<u64>("seed", 0).unwrap_or(0);
    let k0 = args.get_parse::<usize>("k0", workers / 4).unwrap_or(2).max(1);

    let session =
        match adasgd::transformer::TransformerSession::new(&runtime, &tag, seed)
        {
            Ok(s) => s,
            Err(e) => {
                eprintln!("session error: {e}");
                return 1;
            }
        };
    let mut backend =
        match TransformerBackend::new(&runtime, &tag, workers, seed) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("backend error: {e}");
                return 1;
            }
        };
    let params0 = match session.init_params(seed as i32) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("init error: {e}");
            return 1;
        }
    };
    println!(
        "transformer '{tag}': {} params, {workers} workers, {steps} steps",
        backend.params()
    );

    let delays = adasgd::straggler::ExponentialDelays::new(1.0);
    let mut policy = AdaptivePflug::new(
        workers,
        PflugParams {
            k0,
            step: (workers / 4).max(1),
            thresh: 5,
            burnin: 20,
            k_max: workers,
        },
    );
    let cfg = MasterConfig {
        eta: 0.05,
        momentum: 0.0,
        max_iterations: steps,
        max_time: 0.0,
        seed,
        record_stride: (steps / 20).max(1),
        intra_jobs: 1,
    };
    let eval_backend =
        TransformerBackend::new(&runtime, &tag, workers, seed).unwrap();
    let run = run_fastest_k(
        &mut backend,
        &delays,
        &mut policy,
        &params0,
        &cfg,
        &mut |p| eval_backend.eval_loss(p).unwrap_or(f32::NAN) as f64,
    );
    let summary = vec![
        format!(
            "loss {:.4} -> {:.4} over {} fastest-k iterations (virtual t={:.1})",
            run.recorder.samples()[0].error,
            run.recorder.last().unwrap().error,
            run.iterations,
            run.total_time
        ),
        format!("k switches: {:?}", run.k_changes),
    ];
    let scalars = RunScalars {
        late_responses: run.late_responses,
        mean_staleness: run.mean_staleness,
    };
    emit(args, "transformer", &[(&run.recorder, scalars)], &summary, &[]);
    0
}

fn cmd_threaded(args: &Args) -> i32 {
    use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
    use adasgd::exec::{ThreadedCluster, ThreadedConfig};
    use adasgd::model::LinRegProblem;

    let workers = args.get_parse::<usize>("workers", 10).unwrap_or(10);
    let k = args.get_parse::<usize>("k", workers / 2).unwrap_or(5);
    let time_scale =
        args.get_parse::<f64>("time-scale", 1e-3).unwrap_or(1e-3);
    let seed = args.get_parse::<u64>("seed", 0).unwrap_or(0);

    let m = 2000 - (2000 % workers);
    let ds = SyntheticDataset::generate(
        SyntheticConfig { m, d: 100, ..Default::default() },
        seed,
    );
    let problem = LinRegProblem::new(&ds);
    let shards = Shards::partition(&ds, workers);
    let mut cluster = ThreadedCluster::spawn(&shards, time_scale);
    let mut policy = FixedK::new(k.clamp(1, workers));
    let cfg = ThreadedConfig {
        eta: 5e-4,
        max_iterations: args.get_parse("max-iterations", 300).unwrap_or(300),
        time_scale,
        seed,
        record_stride: 20,
        intra_jobs: args.get_parse("intra-jobs", 1).unwrap_or(1),
    };
    let run = cluster.run_fastest_k(
        &mut policy,
        &vec![0.0; 100],
        &cfg,
        &mut |w| problem.error(w),
    );
    println!(
        "threaded cluster: {} workers, k={k}: error {:.4e} -> {:.4e}",
        workers,
        run.recorder.samples()[0].error,
        run.recorder.last().unwrap().error
    );
    println!(
        "  virtual time {:.1}, real time {:.2}s, late responses {}",
        run.virtual_time, run.real_time, run.late_responses
    );
    0
}

#[cfg(not(feature = "pjrt"))]
fn cmd_list_artifacts(_args: &Args) -> i32 {
    pjrt_unavailable("list-artifacts")
}

#[cfg(feature = "pjrt")]
fn cmd_list_artifacts(args: &Args) -> i32 {
    let Some(runtime) = open_runtime(args) else { return 1 };
    println!("artifact registry:");
    for e in runtime.manifest().entries() {
        let ins: Vec<String> = e
            .inputs
            .iter()
            .map(|t| format!("{:?}{:?}", t.dtype, t.shape))
            .collect();
        println!(
            "  {:<28} {:<32} inputs: {}",
            e.name,
            e.file,
            ins.join(", ")
        );
    }
    0
}

fn cmd_repeat(args: &Args) -> i32 {
    use adasgd::coordinator::run_repeated_jobs;
    let Some(path) = args.get("config") else {
        eprintln!("repeat requires --config exp.toml");
        return 2;
    };
    let mut cfg = match std::fs::read_to_string(path)
        .map_err(|e| e.to_string())
        .and_then(|t| ExperimentConfig::from_toml(&t))
    {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    // --intra-jobs overrides `[run] intra_jobs` inside every repetition
    // (pure wall-clock, byte-identical for every value).
    cfg.intra_jobs = args
        .get_parse("intra-jobs", cfg.intra_jobs)
        .unwrap_or(cfg.intra_jobs);
    let reps = args.get_parse::<usize>("steps", 5).unwrap_or(5); // repetitions
    let seed0 = args.get_parse::<u64>("seed", 100).unwrap_or(100);
    let points = args.get_parse::<usize>("points", 24).unwrap_or(24);
    // --jobs overrides the config's `[run] jobs` (both mean: threads for
    // the repetition fan-out; results are identical for every value).
    let jobs = args.get_parse::<usize>("jobs", cfg.jobs).unwrap_or(cfg.jobs);
    match run_repeated_jobs(&cfg, seed0, reps, points, jobs) {
        Ok(agg) => {
            println!(
                "{} - mean +/- std over {} seeds ({}..{}):",
                agg.label,
                agg.reps,
                seed0,
                seed0 + reps as u64 - 1
            );
            println!("{:>10} {:>14} {:>14}", "t", "mean error", "std");
            for i in 0..agg.times.len() {
                println!(
                    "{:>10.0} {:>14.4e} {:>14.2e}",
                    agg.times[i], agg.mean[i], agg.std[i]
                );
            }
            0
        }
        Err(e) => {
            eprintln!("repeat error: {e}");
            1
        }
    }
}

/// `trace analyze|dump|replay` — post-hoc tools over recorded binary
/// event traces (see [`adasgd::trace`]).
fn cmd_trace(args: &Args) -> i32 {
    let usage = "usage: adasgd trace <analyze|dump|replay> FILE.trace \
                 [--limit N] [--config exp.toml]";
    let Some(sub) = args.positional.first().map(|s| s.as_str()) else {
        eprintln!("{usage}");
        return 2;
    };
    let Some(path) = args.positional.get(1) else {
        eprintln!("trace {sub} requires a trace file\n{usage}");
        return 2;
    };
    let trace = match Trace::load(Path::new(path)) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("trace error: {e}");
            return 1;
        }
    };
    match sub {
        "analyze" => {
            let analysis = TraceAnalysis::from_trace(&trace);
            println!("{}", analysis.report(&trace));
            0
        }
        "dump" => {
            // --limit N caps the listed events (0 = all; default 40).
            let limit = match args.get_parse::<usize>("limit", 40) {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let limit = if limit == 0 { None } else { Some(limit) };
            print!("{}", trace.dump(limit));
            0
        }
        "replay" => cmd_trace_replay(args, &trace),
        other => {
            eprintln!("unknown trace subcommand '{other}'\n{usage}");
            2
        }
    }
}

/// Re-drive the experiment from the trace's recorded delay draws and
/// verify the replayed recorder series is *bitwise* the recorded one.
/// Exit 0 = identical, 1 = diverged (or the config doesn't match the
/// recording).
fn cmd_trace_replay(args: &Args, trace: &Trace) -> i32 {
    let Some(path) = args.get("config") else {
        eprintln!(
            "trace replay requires --config exp.toml (the exact \
             configuration of the recorded run)"
        );
        return 2;
    };
    let cfg = match std::fs::read_to_string(path)
        .map_err(|e| e.to_string())
        .and_then(|t| ExperimentConfig::from_toml(&t))
    {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let out = match replay_experiment(&cfg, trace) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("replay error: {e}");
            return 1;
        }
    };
    let recorded: Vec<Sample> = trace
        .events
        .iter()
        .filter_map(|e| match *e {
            Event::Sample {
                iteration,
                time,
                k,
                error,
                bytes,
                comm_time,
                bytes_down,
                down_time,
            } => Some(Sample {
                iteration,
                time,
                k: k as usize,
                error,
                bytes,
                comm_time,
                bytes_down,
                down_time,
            }),
            _ => None,
        })
        .collect();
    let replayed = out.recorder.samples();
    if recorded.len() != replayed.len() {
        eprintln!(
            "replay DIVERGED: {} recorded samples vs {} replayed",
            recorded.len(),
            replayed.len()
        );
        return 1;
    }
    let mut mismatches = 0usize;
    for (i, (a, b)) in recorded.iter().zip(replayed).enumerate() {
        let same = a.iteration == b.iteration
            && a.time.to_bits() == b.time.to_bits()
            && a.k == b.k
            && a.error.to_bits() == b.error.to_bits()
            && a.bytes == b.bytes
            && a.comm_time.to_bits() == b.comm_time.to_bits()
            && a.bytes_down == b.bytes_down
            && a.down_time.to_bits() == b.down_time.to_bits();
        if !same {
            if mismatches == 0 {
                eprintln!("first mismatch at sample {i}:");
                eprintln!("  recorded: {a:?}");
                eprintln!("  replayed: {b:?}");
            }
            mismatches += 1;
        }
    }
    if mismatches > 0 {
        eprintln!(
            "replay DIVERGED: {mismatches}/{} samples differ",
            recorded.len()
        );
        return 1;
    }
    println!(
        "replay OK: {} samples bitwise-identical (discipline {}, {} \
         workers, final t={:.6})",
        recorded.len(),
        trace.discipline,
        trace.n_workers,
        out.total_time
    );
    0
}

/// `adasgd lint` — run the detlint determinism & layering pass over
/// the repo (see [`adasgd::analysis`]). Exit 0 when every finding is
/// covered by an explicit pragma, 1 otherwise — the CI gate.
fn cmd_lint(args: &Args) -> i32 {
    use adasgd::analysis::{lint_root, RULES};
    if args.has("rules") {
        for r in RULES {
            println!("{}  {}", r.id, r.summary);
            println!("      protects: {}", r.protects);
        }
        return 0;
    }
    let root = args.get("root").unwrap_or(".");
    let report = match lint_root(Path::new(root)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("lint error: cannot scan {root}: {e}");
            return 1;
        }
    };
    match args.get("format").unwrap_or("text") {
        "text" => print!("{}", report.render_text()),
        "json" => print!("{}", report.render_json()),
        other => {
            eprintln!("unknown --format '{other}' (text | json)");
            return 2;
        }
    }
    if report.active_count() == 0 {
        0
    } else {
        1
    }
}

fn cmd_switching_times() -> i32 {
    let bound = ErrorBound::new(
        BoundParams::example1(),
        adasgd::stats::OrderStats::exponential(5, 5.0),
    );
    println!("Example 1 (n=5, exp(5), eta=1e-3, sigma2=10, E0=100):");
    for s in switching_times(&bound) {
        println!(
            "  switch to k={} at t = {:>8.1}  (bound error there: {:.4e})",
            s.k_next, s.time, s.error
        );
    }
    0
}
