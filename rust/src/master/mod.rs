//! The synchronous fastest-k master loop (paper Eq. 2).
//!
//! Per iteration j:
//!  1. broadcast `w_j` to all n workers (virtual),
//!  2. draw the n response times from the delay model; the iteration's
//!     wall-clock cost is the k-th order statistic, and the responding set
//!     `R_j` is the k fastest workers,
//!  3. average the k partial gradients into `ĝ_j`,
//!  4. `w_{j+1} = w_j − η ĝ_j`,
//!  5. feed the policy `⟨ĝ_j, ĝ_{j−1}⟩` and the clock; it returns k for
//!     the next iteration.
//!
//! The loop is generic over the gradient backend (native linalg or the
//! AOT/PJRT artifact) and the error evaluator, so the same coordinator
//! trains linear regression and the transformer. Wall-clock is *virtual*
//! (drawn from the delay model): DESIGN.md §3 substitutions. The threaded
//! executor (`exec`) replays the same draws with real OS threads.
//!
//! Step 2 is communication-aware: each response time is compute delay
//! plus the virtual upload delay of the worker's encoded gradient (see
//! [`crate::comm`]); with the default dense zero-cost channel the upload
//! term is identically zero and the loop is exactly the paper's.

mod sync;

pub use sync::{
    fastest_k_select, run_fastest_k, run_fastest_k_comm,
    run_fastest_k_comm_traced, FastestKRun, MasterConfig,
};
